"""The paper's experiment (Figure 1), miniaturized for CPU: compare
{Local SGD, Adam global/local, OASIS global/local} on heterogeneous federated
classification with the main-class partitioning protocol (30/50/70%).

  PYTHONPATH=src python examples/federated_heterogeneity.py [--frac 0.5]

Beyond the paper, ``--het-model`` adds SYSTEMS heterogeneity on top of the
statistical kind (DESIGN.md §5): per-client step times drawn from a
lognormal-straggler or device-tier model, the budgeted per-client local-step
vector H_m (stragglers do fewer local steps instead of stretching the
barrier), and optionally ``--async-buffer B`` for the staleness-buffered
server:

  PYTHONPATH=src python examples/federated_heterogeneity.py \
      --het-model lognormal --async-buffer 4

CIFAR-10/ResNet18 of the paper is replaced by a synthetic same-shape image
dataset + MLP (no downloads in this container); the partitioning protocol,
client count (10), momentum (0.9), scaling momentum (0.999) follow the paper.
Writes results/fig1_example.csv with loss/accuracy per communication round.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsyncSpec, PrecondConfig, SavicConfig, savic
from repro.data import (ClassificationData, FederatedLoader,
                        heterogeneity_score, main_class_partition)
from repro.data.federated import (SYSTEMS_MODELS, local_steps_from_times,
                                  sample_step_times, simulated_round_time)

ap = argparse.ArgumentParser()
ap.add_argument("--frac", type=float, default=0.5)
ap.add_argument("--rounds", type=int, default=20)
ap.add_argument("--h-local", type=int, default=6)
ap.add_argument("--het-model", default="uniform", choices=list(SYSTEMS_MODELS),
                help="systems-heterogeneity model for per-client H_m")
ap.add_argument("--het-sigma", type=float, default=0.6)
ap.add_argument("--async-buffer", type=int, default=0,
                help="server staleness buffer depth B (0 = synchronous)")
args = ap.parse_args()

data = ClassificationData.make(n=8000, n_classes=10, seed=0)
xte, yte = jnp.asarray(data.x[-1000:]), jnp.asarray(data.y[-1000:])
parts = main_class_partition(data.y[:-1000], 10, args.frac, seed=0)
print(f"main-class fraction {args.frac}: heterogeneity score "
      f"{heterogeneity_score(data.y[:-1000], parts):.3f}")

local_steps = None
asy = AsyncSpec(buffer_rounds=args.async_buffer)
step_times = sample_step_times(args.het_model, 10, seed=0,
                               sigma=args.het_sigma)
if args.het_model != "uniform":
    local_steps = tuple(int(h) for h in
                        local_steps_from_times(step_times, args.h_local))
    t_sync = simulated_round_time(step_times, [args.h_local] * 10)
    t_here = simulated_round_time(step_times, local_steps, barrier="async",
                                  buffer_rounds=args.async_buffer) \
        if args.async_buffer else simulated_round_time(step_times, local_steps)
    print(f"systems model {args.het_model}: H_m={list(local_steps)} "
          f"simulated round time {t_here:.2f} vs uniform-sync {t_sync:.2f}")

D = data.x.shape[1]


def init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (D, 128)) * D ** -0.5,
            "b1": jnp.zeros((128,)),
            "w2": jax.random.normal(k2, (128, 10)) * 128 ** -0.5,
            "b2": jnp.zeros((10,))}


def loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
    return (logz - gold).mean()


def accuracy(params):
    h = jax.nn.relu(xte @ params["w1"] + params["b1"])
    return float((jnp.argmax(h @ params["w2"] + params["b2"], -1)
                  == yte).mean())


METHODS = {"SGD": ("identity", "global"),
           "Adam global": ("adam", "global"),
           "Adam local": ("adam", "local"),
           "OASIS global": ("oasis", "global"),
           "OASIS local": ("oasis", "local")}

rows = []
for name, (kind, scaling) in METHODS.items():
    pc = PrecondConfig(kind=kind, alpha=1e-2, beta2=0.999)
    sv = SavicConfig(gamma=0.002, beta1=0.9, scaling=scaling,
                     local_steps=local_steps, asynchrony=asy)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    state = savic.init_state(jax.random.PRNGKey(0), init, pc, sv, 10)
    loader = FederatedLoader(data.x[:-1000], data.y[:-1000].astype(np.int32),
                             parts, batch_size=64, seed=0)
    key = jax.random.PRNGKey(1)
    for r in range(args.rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(args.h_local)), k)
        rows.append((name, r, float(met["loss"]),
                     accuracy(savic.average_params(state))))
    print(f"{name:14s} final loss {rows[-1][2]:.4f} acc {rows[-1][3]:.3f}")

import os
os.makedirs("results", exist_ok=True)
with open("results/fig1_example.csv", "w") as f:
    f.write("method,round,loss,test_acc\n")
    for r in rows:
        f.write(",".join(map(str, r)) + "\n")
print("wrote results/fig1_example.csv")
