"""Batched serving example: prefill-cache reuse + greedy decode on any arch.

  PYTHONPATH=src python examples/serve_batched.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_batched.py --arch qwen3-4b \
      --decode-window 16     # sliding-window decode (long_500k-style cache)
  PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
      --no-greedy --seed 3   # categorical sampling (Gumbel-max)

Runs the REDUCED config on CPU by default (--full for the paper config); on
TPU the same serve path lowers the full configs across the production mesh
(launch/steps.build_prefill_step / build_serve_step).
"""
import argparse

from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2-1.3b")
ap.add_argument("--full", action="store_true",
                help="serve the full (paper-scale) config instead of reduced")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen-len", type=int, default=32)
ap.add_argument("--decode-window", type=int, default=0)
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--no-greedy", action="store_true",
                help="sample categorically instead of greedy argmax")
args = ap.parse_args()

res = serve(args.arch, reduced=not args.full, batch=args.batch,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            decode_window=args.decode_window, seed=args.seed,
            greedy=not args.no_greedy)
print("generated token ids (first sequence):", res.tokens[0].tolist())
print("timings:", {k: round(v, 4) for k, v in res.timings.items()})
