"""Batched serving example: prefill + greedy decode on any assigned arch.

  PYTHONPATH=src python examples/serve_batched.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_batched.py --arch qwen3-4b \
      --decode-window 16     # sliding-window decode (long_500k-style cache)

Runs the REDUCED config on CPU; on TPU the same serve path lowers the full
configs across the production mesh (launch/steps.build_serve_step).
"""
import argparse

from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2-1.3b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen-len", type=int, default=32)
ap.add_argument("--decode-window", type=int, default=0)
args = ap.parse_args()

tokens = serve(args.arch, reduced=True, batch=args.batch,
               prompt_len=args.prompt_len, gen_len=args.gen_len,
               decode_window=args.decode_window)
print("generated token ids (first sequence):", tokens[0].tolist())
