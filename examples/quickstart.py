"""Quickstart: SAVIC (Local SGD + Adam scaling) on a strongly-convex problem.

Runs in ~20s on CPU. Shows the public API end to end: preconditioner config,
round-step builder, state init, the training loop, and the theory predictors.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecondConfig, SavicConfig, savic, theory
from repro.data import QuadraticLoader, QuadraticProblem

# 1. a distributed problem: M=8 clients, heterogeneous quadratics
problem = QuadraticProblem.make(d=32, M=8, mu=0.5, L=8.0, sigma=0.5,
                                heterogeneity=2.0, seed=0)
Q = jnp.asarray(problem.Q, jnp.float32)
b = jnp.asarray(problem.b, jnp.float32)


def loss_fn(params, micro):
    x = params["x"]
    Qm, bm = Q[micro["cid"]], b[micro["cid"]]
    return 0.5 * (x - bm) @ Qm @ (x - bm) + micro["z"] @ x


# 2. SAVIC: Adam-style preconditioner, global scaling (Algorithm 1)
pc = PrecondConfig(kind="adam", alpha=1e-2)
sv = SavicConfig(gamma=0.005, beta1=0.9, scaling="global")
round_step = jax.jit(savic.build_round_step(loss_fn, pc, sv))
state = savic.init_state(jax.random.PRNGKey(0),
                         lambda k: {"x": jnp.zeros(32)}, pc, sv, n_clients=8)

# 3. train: H=8 local steps per communication round
loader = QuadraticLoader(problem, seed=1)
key = jax.random.PRNGKey(2)
xstar = jnp.asarray(problem.x_star(), jnp.float32)
for r in range(40):
    key, k = jax.random.split(key)
    batch = jax.tree.map(jnp.asarray, loader.round_batch(H=8))
    state, met = round_step(state, batch, k)
    if r % 10 == 0 or r == 39:
        x = savic.average_params(state)["x"]
        print(f"round {r:3d}  loss {float(met['loss']):8.4f}  "
              f"|x-x*|^2 {float(jnp.sum((x - xstar) ** 2)):.4f}  "
              f"client-drift {float(met['client_drift']):.2e}")

# 4. what the theory says
spec = theory.ProblemSpec(mu=0.5, L=8.0, sigma2=0.25, alpha=1e-6, Gamma=1.0,
                          M=8, H=8)
print(f"\nTheorem-1 contraction/step (Γ=1 scale): "
      f"{theory.thm1_rate(spec, 0.05):.5f}")
print("Done — see examples/federated_heterogeneity.py for the paper's Fig.1 "
      "experiment and examples/train_lm.py for a ~100M-param LM run.")
