"""End-to-end driver: train a ~100M-parameter qwen2-family LM.

  PYTHONPATH=src python examples/train_lm.py                  # full ~100M
  PYTHONPATH=src python examples/train_lm.py --tiny           # CPU-quick
  PYTHONPATH=src python examples/train_lm.py --tiny --method local-adam

Thin wrapper over the production driver (repro.launch.train): registers a
custom ~100M config into the registry, picks size-appropriate defaults, and
forwards everything else — ``--method`` selects any of the six engine
methods, and unknown flags (``--mesh``, ``--compression``, ...) pass through
to the driver verbatim.

The full config is a 12-layer, d=768 qwen2-style decoder (~100M params
excluding embeddings) trained on the synthetic Markov token stream;
--tiny shrinks it for smoke use. Restart is deterministic: rerunning with
the same --ckpt resumes at the saved round and replays the same per-round
keys and round-addressable data, bitwise (DESIGN.md §9).
"""
import argparse

from repro.configs import ModelConfig, register
import repro.configs  # noqa
import sys, types

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--rounds", type=int, default=0)
ap.add_argument("--method", default="savic",
                help="engine method (savic | fedavg | fedadagrad | fedadam "
                     "| fedyogi | local-adam)")
ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
args, passthrough = ap.parse_known_args()

# register a custom ~100M arch into the config registry
CONFIG = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab_size=8192, qkv_bias=True,
    tie_embeddings=True, source="examples/train_lm.py",
)
REDUCED = CONFIG.replace(name="lm-100m-tiny", n_layers=2, d_model=128,
                         n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512)
mod = types.ModuleType("repro.configs.lm_100m")
mod.CONFIG, mod.REDUCED = CONFIG, REDUCED
sys.modules["repro.configs.lm_100m"] = mod
register("lm-100m", "lm_100m")

print(f"params (full): {CONFIG.param_count()/1e6:.0f}M")

from repro.launch import train as train_mod   # noqa: E402

rounds = args.rounds or (5 if args.tiny else 300)
train_args = ["--arch", "lm-100m", "--rounds", str(rounds),
              "--method", args.method,
              "--h-local", "4", "--clients", "4",
              "--batch", "4" if args.tiny else "8",
              "--seq", "64" if args.tiny else "256",
              "--preconditioner", "adam", "--gamma", "3e-3",
              "--ckpt", args.ckpt, "--ckpt-every", "25",
              "--log", "results/train_lm_log.json"]
if args.tiny:
    train_args.append("--reduced")
log = train_mod.main(train_args + passthrough)
print(f"final loss {log[-1]['loss']:.4f} (round {log[-1]['round']})")
