"""Cross-PR benchmark diff: join two schema-v1 BENCH documents on their
axis coordinates and print per-metric deltas (DESIGN.md §11).

Semantics:

  * rows are joined on the full coordinate tuple (doc axes order of A; both
    documents must share the same axis set).  A point present in one file
    but not the other is SURFACED (``only_in_a`` / ``only_in_b``) — never
    silently dropped — and counts as a difference under --check.
  * delta sign convention: ``delta = b - a`` (positive means B is larger),
    ``rel = delta / |a|``.  Whether larger is worse is metric-specific; the
    diff reports magnitude and direction, it does not editorialize.
  * wall-clock metrics (``matrix.is_timing_metric``: *_ms*, us_*, *_s
    phase timings, wall-derived tok/s ...) are classified as ``timing`` —
    reported separately and never counted as regressions; simulated clocks
    (sim_*), byte counts, round counts and losses at fixed seeds are
    ``comparable``.  Two runs of the same rev at the same seeds must show
    zero comparable deltas.

CLI::

  python benchmarks/diff.py A.json B.json [--rtol R] [--atol A] [--check]

--check exits non-zero when any comparable metric differs beyond tolerance
or any row/metric is missing from one side (CI runs a fresh result against
itself and requires a clean pass).
"""
from __future__ import annotations

import json
import math
import os
import sys

if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks import matrix
else:
    from . import matrix


def _key(row, axes):
    return tuple(str(row["coords"][a]) for a in axes)


def _close(a, b, rtol, atol):
    return math.isclose(a, b, rel_tol=rtol, abs_tol=atol)


def diff_docs(doc_a, doc_b, rtol=0.0, atol=0.0):
    """Structured diff of two validated BENCH documents.  Returns::

      {"bench", "axes", "git_rev_a", "git_rev_b",
       "only_in_a": [coords...], "only_in_b": [coords...],
       "rows": [{"coords", "deltas": {metric: {"a","b","delta","rel","kind",
                                               "changed"}},
                 "metrics_only_in_a": [...], "metrics_only_in_b": [...]}],
       "n_comparable_deltas", "n_timing_deltas", "n_missing"}
    """
    matrix.assert_valid(doc_a)
    matrix.assert_valid(doc_b)
    if doc_a["bench"] != doc_b["bench"]:
        raise ValueError(f"bench mismatch: {doc_a['bench']!r} vs "
                         f"{doc_b['bench']!r}")
    if set(doc_a["axes"]) != set(doc_b["axes"]):
        raise ValueError(f"axis mismatch: {doc_a['axes']} vs {doc_b['axes']}")
    axes = list(doc_a["axes"])
    rows_a = {_key(r, axes): r for r in doc_a["rows"]}
    rows_b = {_key(r, axes): r for r in doc_b["rows"]}
    only_a = [rows_a[k]["coords"] for k in rows_a if k not in rows_b]
    only_b = [rows_b[k]["coords"] for k in rows_b if k not in rows_a]
    out_rows, n_cmp, n_tim, n_missing_metrics = [], 0, 0, 0
    for key in rows_a:
        if key not in rows_b:
            continue
        ra, rb = rows_a[key], rows_b[key]
        ma, mb = ra["metrics"], rb["metrics"]
        deltas = {}
        for m in ma:
            if m not in mb:
                continue
            a, b = ma[m], mb[m]
            kind = "timing" if matrix.is_timing_metric(m) else "comparable"
            changed = not _close(a, b, rtol, atol)
            if changed:
                if kind == "comparable":
                    n_cmp += 1
                else:
                    n_tim += 1
            deltas[m] = {"a": a, "b": b, "delta": b - a,
                         "rel": (b - a) / abs(a) if a else
                         (0.0 if b == a else math.inf),
                         "kind": kind, "changed": changed}
        m_only_a = sorted(set(ma) - set(mb))
        m_only_b = sorted(set(mb) - set(ma))
        n_missing_metrics += len(m_only_a) + len(m_only_b)
        out_rows.append({"coords": ra["coords"], "deltas": deltas,
                         "metrics_only_in_a": m_only_a,
                         "metrics_only_in_b": m_only_b})
    return {
        "bench": doc_a["bench"], "axes": axes,
        "git_rev_a": doc_a["git_rev"], "git_rev_b": doc_b["git_rev"],
        "only_in_a": only_a, "only_in_b": only_b,
        "rows": out_rows,
        "n_comparable_deltas": n_cmp,
        "n_timing_deltas": n_tim,
        "n_missing": len(only_a) + len(only_b) + n_missing_metrics,
    }


def format_report(rep, verbose=False):
    lines = [f"bench {rep['bench']}: {rep['git_rev_a']} -> "
             f"{rep['git_rev_b']} (join on {'x'.join(rep['axes'])})"]
    for coords in rep["only_in_a"]:
        lines.append(f"  MISSING in B: {coords}")
    for coords in rep["only_in_b"]:
        lines.append(f"  MISSING in A: {coords}")
    for row in rep["rows"]:
        shown = {m: d for m, d in row["deltas"].items()
                 if d["changed"] or verbose}
        if not shown and not row["metrics_only_in_a"] \
                and not row["metrics_only_in_b"]:
            continue
        lines.append(f"  {row['coords']}")
        for m, d in shown.items():
            rel = f"{d['rel']:+.2%}" if math.isfinite(d["rel"]) else "inf"
            lines.append(f"    [{d['kind']:10s}] {m}: {d['a']} -> {d['b']} "
                         f"(delta {d['delta']:+g}, {rel})")
        for m in row["metrics_only_in_a"]:
            lines.append(f"    [missing   ] {m}: only in A")
        for m in row["metrics_only_in_b"]:
            lines.append(f"    [missing   ] {m}: only in B")
    lines.append(f"  {rep['n_comparable_deltas']} comparable delta(s), "
                 f"{rep['n_timing_deltas']} timing delta(s), "
                 f"{rep['n_missing']} missing row(s)/metric(s)")
    return "\n".join(lines)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="Diff two BENCH_*.json documents on axis coordinates")
    ap.add_argument("a")
    ap.add_argument("b")
    ap.add_argument("--rtol", type=float, default=0.0)
    ap.add_argument("--atol", type=float, default=0.0)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any comparable delta or missing "
                         "row/metric (timing deltas never fail)")
    ap.add_argument("--verbose", action="store_true",
                    help="print unchanged metrics too")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON")
    args = ap.parse_args(argv)
    rep = diff_docs(json.load(open(args.a)), json.load(open(args.b)),
                    rtol=args.rtol, atol=args.atol)
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        print(format_report(rep, verbose=args.verbose))
    if args.check and (rep["n_comparable_deltas"] or rep["n_missing"]):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
