"""Per-step collective bytes of the shard-mapped fused local step — the
rows of BENCH_kernels_sharded.json (DESIGN.md §7, §11).

Standalone subprocess (the matrix harness's ``kernels_sharded`` bench in
benchmarks/run.py spawns it once and fans its record out over the ``plan``
axis): the main benchmark process keeps 1 CPU device, this worker forces 8
host devices and
lowers ONE local step of the flat-buffer pipeline under model-/FSDP-/mixed-
sharded plans, three arms per plan:

  * sharded — flatten -> fused kernel -> unflatten, all inside shard_map over
    the plan's shard axes (the live fast path).  Per-step collective bytes
    MUST be 0: nothing may touch the flat buffers.
  * naive   — the same step through the single global flat view (what the
    pre-PR launch gate guarded against): GSPMD reshards the whole client
    state, so its per-step collective bytes are the measured blowup.
  * tree    — the unfused per-leaf elementwise update (the fallback the old
    gate forced): also 0 collective bytes, the baseline the fused path must
    not regress.

Collective bytes are parsed from the optimized HLO (utils/hlo.collective_bytes
— compiled.cost_analysis() carries no collective key on this backend); HBM
"bytes accessed" per arm comes from xla_cost_properties.  Prints one line of
JSON to stdout.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.engine import _shard_flat_ops
from repro.kernels import ref as kref
from repro.utils.flatten import FlatLayout, ShardedFlatPlan
from repro.utils.hlo import collective_bytes
from repro.utils.hlo_cost import xla_cost_properties

M = 4
# NB: tests/_fused_sharded_worker.py carries the same three-plan spec table
# and step builders on a smaller toy tree (its copy asserts, this one
# measures with leaves big enough that the naive reshard dominates); a
# change to the fused_step signature or the plan shapes must land in both.
# "bias" is the uneven (replicated-fallback) leaf
SHAPES = {"w1": (64, 512), "b1": (512,), "w2": (512, 256), "b2": (256,),
          "bias": (5,)}
PLANS = {
    "model": (None, ("model",),
              {"w1": P(None, "model"), "b1": P("model"),
               "w2": P("model", None), "b2": P("model"), "bias": P()}),
    "fsdp": (None, ("data", "model"),
             {"w1": P(None, ("data", "model")), "b1": P(("data", "model")),
              "w2": P(("data", "model"), None), "b2": P(("data", "model")),
              "bias": P()}),
    "mixed": (("data",), ("model",),
              {"w1": P(None, "model"), "b1": P("model"),
               "w2": P("model", None), "b2": P("model"), "bias": P()}),
}
KW = dict(gamma=0.01, beta1=0.9, weight_decay=0.0, alpha=1e-2, beta2=0.999,
          kind="adam", clip="max", schedule="const", update_d=True)


def _params(key):
    return {name: jax.random.normal(jax.random.fold_in(key, i), (M,) + shp)
            for i, (name, shp) in enumerate(SHAPES.items())}


def _measure(fn, args, in_sh, out_sh, mesh):
    with mesh:
        c = jax.jit(fn, in_shardings=in_sh,
                    out_shardings=out_sh).lower(*args).compile()
    coll, by_kind, _ = collective_bytes(c.as_text())
    cost = xla_cost_properties(c)
    return {"collective_bytes": int(coll),
            "collective_by_kind": {k: int(v) for k, v in by_kind.items()},
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         devices=jax.devices()[:8])
    params = _params(jax.random.key(7))
    out = {"n_devices": 8, "clients": M,
           "leaves": {k: list(v) for k, v in SHAPES.items()},
           "plans": {}}
    for plan_name, (client, axes, pspecs) in PLANS.items():
        leaf_specs = {k: P(client, *tuple(pspecs[k])) for k in SHAPES}
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        in_sh, out_sh = (ns(leaf_specs),), ns(leaf_specs)
        params_one = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params)
        plan = ShardedFlatPlan.build(mesh, params_one, pspecs, axes,
                                     client=client)
        lay = plan.layout
        t0 = jnp.zeros((M,), jnp.int32)

        def sharded_step(tree):
            p = lay.flatten(tree, mesh, lead=(client,))
            _, _, _, _, fused_step = _shard_flat_ops(plan, local=True)
            po, _, _ = fused_step(p, p * 0.9, p * 0.1, p * 0.5 + 1.0, None,
                                  t0, None, **KW)
            return lay.unflatten(po, mesh, lead=(client,))

        glay = FlatLayout.for_tree(params, batch_dims=1)

        def naive_step(tree):
            p = glay.flatten(tree, batch_dims=1)
            po, _, _ = kref.fused_step_ref(
                p, p * 0.9, p * 0.1, p * 0.5 + 1.0, None, None, None,
                **dict(KW, update_d=False))
            return glay.unflatten(po, batch_dims=1)

        def tree_step(tree):
            return jax.tree.map(
                lambda p: p - 0.01 * (0.9 * p * 0.9 + p * 0.1)
                / jnp.maximum(1e-2, jnp.sqrt(jnp.abs(p * 0.5 + 1.0))), tree)

        rec = {
            "sharded": _measure(sharded_step, (params,), in_sh, out_sh, mesh),
            "naive": _measure(naive_step, (params,), in_sh, out_sh, mesh),
            "tree": _measure(tree_step, (params,), in_sh, out_sh, mesh),
            "n_shards": lay.n_shards, "n_local": lay.n_local,
        }
        # no ratio column: sharded/tree are pinned at exactly 0 collective
        # bytes, so the naive arm's ABSOLUTE per-step bytes are the blowup
        # (any denominator would fabricate a multiplier)
        out["plans"][plan_name] = rec
    print(json.dumps(out))


if __name__ == "__main__":
    main()
