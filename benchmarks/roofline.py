"""§Roofline analysis: three-term roofline per (arch × shape × mesh) from the
dry-run artifacts in results/dryrun/.

Terms (TPU v5e):
  compute    = FLOPs_per_device / 197e12            [s]
  memory     = bytes_per_device / 819e9             [s]
  collective = collective_bytes_per_device / 50e9   [s]

All numerators are trip-count-corrected per-device values from the optimized
HLO (see utils/hlo_cost.py).  MODEL_FLOPS (useful work) per device:
  train:   6 · N_active · tokens_per_round / n_devices
  prefill: 2 · N_active · tokens / n_devices
  decode:  2 · N_active · batch  / n_devices   (1 new token per sequence)
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s/link

HBM_PER_CHIP = 16e9      # v5e


def model_flops_per_device(rec):
    n_act = rec["active_params"]
    n_dev = rec["n_devices"]
    kind = rec["kind"]
    shape = rec["shape"]
    from repro.configs import get_shape
    s = get_shape(shape)
    if kind == "train":
        tokens = s.global_batch * s.seq_len * rec.get("h_local", 8)
        return 6.0 * n_act * tokens / n_dev
    if kind == "prefill":
        return 2.0 * n_act * s.global_batch * s.seq_len / n_dev
    return 2.0 * n_act * s.global_batch / n_dev


def terms(rec):
    comp = rec["flops"] / PEAK_FLOPS
    memt = rec["bytes_accessed"] / HBM_BW
    coll = rec["collective_bytes"] / ICI_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec)
    return {
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
        # fraction of roofline: useful work time over the actual bound
        "roofline_frac": (mf / PEAK_FLOPS) / max(comp, memt, coll)
        if max(comp, memt, coll) else 0.0,
    }


def load(dirname="results/dryrun", mesh=None, tag=""):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        if "mesh" not in r:
            continue   # auxiliary perf-log records
        if mesh and r["mesh"] != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def table(recs, fmt="md"):
    rows = []
    for r in recs:
        t = terms(r)
        mem = r.get("memory", {})
        arg_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        tmp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "mode": r["mode"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "useful_ratio": t["useful_ratio"],
            "roofline_frac": t["roofline_frac"],
            "arg_GB": arg_gb, "temp_GB": tmp_gb,
            "fits": (arg_gb + tmp_gb) / (r["n_devices"] / (256 if "x16x" not in
                     r["mesh"] else 512)) <= HBM_PER_CHIP / 1e9,
            "compile_s": r["compile_s"],
        })
    if fmt == "md":
        hdr = ("| arch | shape | mode | compute s | memory s | coll s | "
               "dominant | useful | roofl.frac | arg+temp GB/dev |")
        sep = "|" + "---|" * 11
        lines = [hdr, sep]
        for w in rows:
            lines.append(
                f"| {w['arch']} | {w['shape']} | {w['mode']} "
                f"| {w['compute_s']:.3e} | {w['memory_s']:.3e} "
                f"| {w['collective_s']:.3e} | **{w['dominant']}** "
                f"| {w['useful_ratio']:.2f} | {w['roofline_frac']:.2f} "
                f"| {w['arg_GB'] + w['temp_GB']:.1f} |")
        return "\n".join(lines)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.dir, mesh=args.mesh, tag=args.tag)
    print(table(recs))
    print(f"\n{len(recs)} records; dominant terms:",
          {d: sum(1 for r in recs if terms(r)["dominant"] == d)
           for d in ("compute", "memory", "collective")})


if __name__ == "__main__":
    main()
