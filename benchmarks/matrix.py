"""Declarative benchmark-matrix harness (benchalot-style) — the single
contract every bench in this repo emits through (DESIGN.md §11).

A bench is a ``MatrixConfig`` (named axes × per-bench fixed params ×
samples/seed policy) plus a ``run(point, ctx) -> rows`` callable registered
in ``REGISTRY`` (benchmarks/run.py registers all of them at import).  One
runner expands the matrix deterministically, tags every row with its full
axis coordinates + ``git_rev`` + schema version, asserts the uniform row
schema at emit time, and writes both artifacts:

  BENCH_<name>.json        (repo root)     — the store of record
  results/bench/<name>.csv (derived)       — byte-identical function of the
                                             JSON rows; regenerable without
                                             re-running via ``update-output``

Uniform BENCH document, schema v1::

  {"schema_version": 1, "bench": "<name>", "git_rev": "<rev of the run>",
   "config": {...fixed params + runtime context...},
   "axes": ["method", "arm", ...],            # ordered coord keys
   "rows": [{"coords": {axis: scalar, ...},   # exactly the doc's axes
             "metrics": {name: number, ...},  # numeric only, never bool/NaN
             "info": {...},                   # optional non-numeric payload
             "git_rev": "<rev>"}, ...]}       # required per row

``benchmarks/diff.py`` joins two documents on the coordinate tuples and
prints per-metric deltas, so a cross-PR regression is a single diff.

CLI (``python -m benchmarks.matrix <cmd>``)::

  run --bench NAME [--select axis=v1,v2]... [--limit N] [--set k=v]...
      [--out-dir D] [--results-dir D]      expand + run + validate + emit
  update-output [--bench NAME | PATH...]   regenerate CSV/summary from the
                                           stored JSON without re-running
  validate PATH...                         schema-check BENCH documents
  expand --bench NAME                      print the deterministic points
  migrate [--write]                        one-shot legacy-artifact converter
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os
import re
import sys

SCHEMA_VERSION = 1

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(_REPO_ROOT, "results", "bench")

_GIT_REV = None


def git_rev():
    """Short git rev of the tree the numbers came from (benchmark hygiene:
    every emitted row is attributable to a commit). Cached; "unknown"
    outside a git checkout."""
    global _GIT_REV
    if _GIT_REV is None:
        import subprocess
        try:
            _GIT_REV = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=10,
            ).stdout.strip() or "unknown"
        except Exception:
            _GIT_REV = "unknown"
    return _GIT_REV


# --------------------------------------------------------------------------- #
# timing classification — which metrics are wall-clock noise, not regressions
# --------------------------------------------------------------------------- #

# Wall-clock metrics vary run-to-run on the same rev; diff.py reports them
# separately and never counts them as regressions.  Everything simulated
# (sim_*: the lognormal-trace clock is a spec constant), counted (rounds,
# bytes, launches) or converged (losses at fixed seeds) is comparable.
_TIMING_PATTERNS = (
    r"(^|_)ms(_|$)",            # round_ms_mean, us->ms families
    r"(^|_)us(_|$)",            # us_fused_oracle, kernel µs/call
    r"(^|_)wall",               # wall_tok_per_s, round_wall_s_mean
    r"(^|_)tok(ens)?_per_s($|_)",  # wall-derived throughput
    r"(^|_)s$",                 # ttft_s, decode_s, p99_token_s, compile_s
    r"^seconds$",
)
_TIMING_RE = re.compile("|".join(_TIMING_PATTERNS))


def is_timing_metric(name):
    """True when ``name`` is a wall-clock measurement (noise across runs of
    the same rev).  ``sim_*`` metrics are deterministic simulated clocks and
    are always comparable, whatever their suffix."""
    if name.startswith("sim_"):
        return False
    return bool(_TIMING_RE.search(name))


# --------------------------------------------------------------------------- #
# config model
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class MatrixConfig:
    """Declarative bench matrix: ordered axes (name -> value tuple), fixed
    per-bench params, and the samples/seed policy.  Expansion is a pure
    function of this object: same config -> identical point order."""
    name: str
    axes: tuple            # ((axis, (v1, v2, ...)), ...) — ordered
    fixed: tuple = ()      # ((key, value), ...) — per-bench fixed params
    row_axes: tuple = ()   # extra per-row coord keys the runner emits
                           # (e.g. "round" for per-round curves)
    samples: int = 1       # repeats per point; seeds seed0..seed0+samples-1
    seed0: int = 0

    @classmethod
    def make(cls, name, axes, fixed=None, row_axes=(), samples=1, seed0=0):
        return cls(name=name,
                   axes=tuple((a, tuple(vs)) for a, vs in dict(axes).items()),
                   fixed=tuple(dict(fixed or {}).items()),
                   row_axes=tuple(row_axes), samples=samples, seed0=seed0)

    def axes_dict(self):
        return dict(self.axes)

    def fixed_dict(self):
        return dict(self.fixed)

    def coord_keys(self):
        ks = [a for a, _ in self.axes]
        if self.samples > 1:
            ks.append("sample")
        return ks + list(self.row_axes)


@dataclasses.dataclass(frozen=True)
class Point:
    """One matrix point: its axis coordinates, the resolved fixed params
    (config fixed <- overrides, in that precedence), and its seed."""
    coords: dict
    fixed: dict
    seed: int


def expand(cfg, select=None, limit=None, overrides=None):
    """Deterministic matrix expansion: itertools.product in declared axis
    order (last axis fastest), samples innermost.  ``select`` subsets axis
    values ({axis: (v, ...)}), ``limit`` truncates to the first N points,
    ``overrides`` wins over cfg.fixed (fixed-param precedence: CLI --set >
    MatrixConfig.fixed)."""
    select = dict(select or {})
    for ax in select:
        if ax not in cfg.axes_dict():
            raise KeyError(f"--select axis {ax!r} not in matrix "
                           f"{sorted(cfg.axes_dict())}")
    names, domains = [], []
    for axis, values in cfg.axes:
        keep = select.get(axis)
        vals = tuple(v for v in values if keep is None or v in keep)
        if not vals:
            raise ValueError(f"selection emptied axis {axis!r}")
        names.append(axis)
        domains.append(vals)
    fixed = {**cfg.fixed_dict(), **dict(overrides or {})}
    points = []
    for combo in itertools.product(*domains):
        for s in range(cfg.samples):
            coords = dict(zip(names, combo))
            if cfg.samples > 1:
                coords["sample"] = s
            points.append(Point(coords=coords, fixed=dict(fixed),
                                seed=cfg.seed0 + s))
    if limit is not None:
        points = points[:limit]
    return points


# --------------------------------------------------------------------------- #
# rows and schema
# --------------------------------------------------------------------------- #


def _scalarize(v):
    """Coerce numpy scalars to python; leave everything else alone."""
    if hasattr(v, "item") and not isinstance(v, (str, bytes)):
        try:
            return v.item()
        except Exception:
            return v
    return v


def _is_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def make_row(coords, values, info=None, rev=None):
    """Build a schema-v1 row.  ``values`` is partitioned automatically:
    numeric (non-bool) entries become metrics, everything else joins
    ``info`` (loss curves, knob trajectories, tune dicts ...)."""
    metrics, extra = {}, dict(info or {})
    for k, v in values.items():
        v = _scalarize(v)
        if _is_number(v):
            metrics[k] = v
        else:
            extra[k] = v
    row = {"coords": {k: _scalarize(v) for k, v in coords.items()},
           "metrics": metrics,
           "git_rev": rev or git_rev()}
    if extra:
        row["info"] = extra
    return row


def validate_doc(doc):
    """The uniform-row schema validator (importable: the runner asserts it
    at emit time, tests/test_bench_schema.py runs it over every committed
    artifact).  Returns a list of error strings; empty means valid."""
    errs = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]

    def _req(key, typ, name=None):
        v = doc.get(key)
        if not isinstance(v, typ) or (typ is str and not v):
            errs.append(f"missing/invalid {name or key!r}")
            return None
        return v

    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    _req("bench", str)
    _req("git_rev", str, "document git_rev")
    _req("config", dict)
    axes = _req("axes", list)
    rows = _req("rows", list)
    if axes is not None:
        if not axes or len(set(axes)) != len(axes) \
                or not all(isinstance(a, str) and a for a in axes):
            errs.append("axes must be a non-empty list of unique names")
    if errs or rows is None or axes is None:
        return errs
    seen = {}
    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where}: not an object")
            continue
        coords = row.get("coords")
        if not isinstance(coords, dict) or set(coords) != set(axes):
            errs.append(f"{where}: coords keys {sorted(coords or {})} != "
                        f"axes {sorted(axes)} (coordinate completeness)")
        else:
            for a, v in coords.items():
                if v is None or not isinstance(v, (str, bool, int, float)):
                    errs.append(f"{where}: coord {a!r} is not a scalar")
            key = tuple(str(coords[a]) for a in axes)
            if key in seen:
                errs.append(f"{where}: duplicate coordinates {key} "
                            f"(first at rows[{seen[key]}])")
            seen.setdefault(key, i)
        metrics = row.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            errs.append(f"{where}: metrics must be a non-empty object")
        else:
            for m, v in metrics.items():
                if not _is_number(v):
                    errs.append(f"{where}: metric {m!r} is not numeric "
                                f"(got {type(v).__name__})")
                elif isinstance(v, float) and math.isnan(v):
                    errs.append(f"{where}: metric {m!r} is NaN")
        rev = row.get("git_rev")
        if not isinstance(rev, str) or not rev:
            errs.append(f"{where}: missing git_rev tag")
        if "info" in row and not isinstance(row["info"], dict):
            errs.append(f"{where}: info must be an object")
        unknown = set(row) - {"coords", "metrics", "info", "git_rev"}
        if unknown:
            errs.append(f"{where}: unknown keys {sorted(unknown)}")
    return errs


def assert_valid(doc):
    errs = validate_doc(doc)
    if errs:
        raise ValueError(
            f"BENCH_{doc.get('bench', '?')} fails schema v{SCHEMA_VERSION}:\n"
            + "\n".join("  - " + e for e in errs))
    return doc


# --------------------------------------------------------------------------- #
# CSV rendering — a pure, byte-deterministic function of the JSON document
# --------------------------------------------------------------------------- #


def _cell(v):
    return "" if v is None else str(v)


def render_csv(doc):
    """Columns: axes (declared order), then metric names in first-seen row
    order, then git_rev.  Missing metrics render as empty cells.  Pure
    function of the document — ``update-output`` regenerates the CSV
    byte-identically from the stored JSON."""
    axes = list(doc["axes"])
    metric_cols = []
    for row in doc["rows"]:
        for m in row["metrics"]:
            if m not in metric_cols:
                metric_cols.append(m)
    lines = [",".join(axes + metric_cols + ["git_rev"])]
    for row in doc["rows"]:
        cells = [_cell(row["coords"].get(a)) for a in axes]
        cells += [_cell(row["metrics"].get(m)) for m in metric_cols]
        cells.append(row["git_rev"])
        lines.append(",".join(cells))
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class BenchDef:
    """A registered bench: its matrix + the point runner.

    run(point, ctx) -> [row, ...] — rows built with make_row; coords must be
    point.coords plus any cfg.row_axes keys.  ``ctx`` is a plain dict shared
    across the points of one run_bench call (cross-point state: cached
    datasets, the sync arm's target_loss for the controller arm, per-arch
    serve results).  Runners may stash runtime config under
    ctx["config_extra"] (merged into the document config).
    post(rows, ctx) -> [row, ...] appends derived rows after all points
    (e.g. the train_lm full-shape projection).
    summary(doc) -> [(metric, value), ...] derives the stdout trajectory
    lines from the stored rows alone (so update-output never re-runs).
    """
    name: str
    config: MatrixConfig
    run: object
    summary: object = None
    post: object = None
    note: str = ""


REGISTRY = {}


def register(bench):
    if bench.name != bench.config.name:
        raise ValueError(f"bench {bench.name!r} != config {bench.config.name!r}")
    REGISTRY[bench.name] = bench
    return bench


def _registry():
    """REGISTRY, with benchmarks/run.py (the registration module) loaded."""
    if not REGISTRY:
        from benchmarks import run as _run  # noqa: F401
    return REGISTRY


# --------------------------------------------------------------------------- #
# runner
# --------------------------------------------------------------------------- #


def bench_paths(name, out_dir=None, results_dir=None):
    out_dir = out_dir or _REPO_ROOT
    results_dir = results_dir or RESULTS_DIR
    return (os.path.join(out_dir, f"BENCH_{name}.json"),
            os.path.join(results_dir, f"{name}.csv"))


def write_outputs(doc, out_dir=None, results_dir=None):
    """Validate + write both artifacts.  The JSON is the store of record;
    the CSV is derived from it (never from in-memory rows) so a later
    ``update-output`` reproduces it byte-identically."""
    assert_valid(doc)
    json_path, csv_path = bench_paths(doc["bench"], out_dir, results_dir)
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    os.makedirs(os.path.dirname(csv_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)
    stored = json.load(open(json_path))
    with open(csv_path, "w") as f:
        f.write(render_csv(stored))
    return json_path, csv_path


def run_bench(name, select=None, limit=None, overrides=None, out_dir=None,
              results_dir=None):
    """Expand the bench's matrix, run every point through its registered
    runner, tag rows, validate, and emit BENCH_<name>.json + <name>.csv.
    Returns the document."""
    bench = _registry()[name]
    points = expand(bench.config, select=select, limit=limit,
                    overrides=overrides)
    ctx = {}
    rows = []
    rev = git_rev()
    for point in points:
        got = bench.run(point, ctx)
        for row in got:
            row["git_rev"] = rev
        rows.extend(got)
    if bench.post is not None:
        extra = bench.post(rows, ctx)
        for row in extra:
            row["git_rev"] = rev
        rows.extend(extra)
    cfg = bench.config
    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": name,
        "git_rev": rev,
        "config": {**cfg.fixed_dict(), **dict(overrides or {}),
                   "samples": cfg.samples, "seed0": cfg.seed0,
                   **ctx.get("config_extra", {}),
                   **({"note": bench.note} if bench.note else {})},
        "axes": cfg.coord_keys(),
        "rows": rows,
    }
    write_outputs(doc, out_dir=out_dir, results_dir=results_dir)
    return doc


def summarize(doc):
    """Derive the stdout (bench, metric, value) trajectory lines from a
    stored document — registry summary when available, else nothing."""
    bench = _registry().get(doc["bench"])
    if bench is None or bench.summary is None:
        return []
    return list(bench.summary(doc))


def update_output(path, results_dir=None):
    """benchalot-style --update-output: regenerate the CSV and summary from
    the stored JSON rows without invoking any runner."""
    doc = assert_valid(json.load(open(path)))
    _, csv_path = bench_paths(doc["bench"], results_dir=results_dir)
    os.makedirs(os.path.dirname(csv_path), exist_ok=True)
    with open(csv_path, "w") as f:
        f.write(render_csv(doc))
    return doc, csv_path


# --------------------------------------------------------------------------- #
# one-shot legacy migration (pre-PR-9 artifact shapes -> schema v1)
# --------------------------------------------------------------------------- #

# Historical compression tags <-> the composite "compression" axis values.
COMPRESSION_VARIANTS = {
    "none": ("none", 1.0, False),
    "topk0.1": ("topk", 0.1, False),
    "topk0.1-ef": ("topk", 0.1, True),
    "randk0.1": ("randk", 0.1, False),
    "int8": ("int8-stochastic", 1.0, False),
}


def _legacy_tag_to_variant(op, k, ef):
    for tag, (o, kk, e) in COMPRESSION_VARIANTS.items():
        if (o, kk, e) == (op, k, ef):
            return tag
    raise KeyError(f"unknown compression case {(op, k, ef)}")


def _doc(name, config, axes, rows, rev):
    return {"schema_version": SCHEMA_VERSION, "bench": name,
            "git_rev": rev or "unknown", "config": config,
            "axes": list(axes), "rows": rows}


def _read_legacy_csv(path):
    lines = open(path).read().splitlines()
    hdr = lines[0].split(",")
    return [dict(zip(hdr, ln.split(","))) for ln in lines[1:] if ln]


def migrate(root=None, write=False):
    """Convert every committed pre-PR-9 artifact to schema v1.  Rows (and
    documents) that predate the git_rev tag are backfilled with
    ``git_rev: "unknown"`` — never emitted schema-invalid.  Returns
    {bench_name: doc}; with write=True also rewrites BENCH_<name>.json +
    results/bench/<name>.csv (and removes artifacts whose rows moved:
    controller.csv folds into the async document's arm axis)."""
    root = root or _REPO_ROOT
    docs = {}

    def _load(fname):
        p = os.path.join(root, fname)
        return json.load(open(p)) if os.path.exists(p) else None

    def _rows_from_mapping(mapping, axis, rev):
        rows = []
        for key, rec in mapping.items():
            rows.append(make_row({axis: key}, rec, rev=rev or "unknown"))
        return rows

    # engine: {"methods": {method: rec}}
    legacy = _load("BENCH_engine.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        docs["engine"] = _doc("engine", legacy.get("config", {}), ["method"],
                              _rows_from_mapping(legacy["methods"], "method",
                                                 rev), rev)

    # compression: {"entries": {"<method>__<tag>": rec}}
    legacy = _load("BENCH_compression.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        rows = []
        for tag, rec in legacy["entries"].items():
            method, case = tag.split("__", 1)
            case = {"none": "none", "topk_k0.1": "topk0.1",
                    "topk_k0.1_ef": "topk0.1-ef", "randk_k0.1": "randk0.1",
                    "int8-stochastic": "int8"}[case]
            op, k, ef = COMPRESSION_VARIANTS[case]
            rows.append(make_row({"method": method, "compression": case}, rec,
                                 info={"op": op, "k": k, "error_feedback": ef},
                                 rev=rev or "unknown"))
        docs["compression"] = _doc("compression", legacy.get("config", {}),
                                   ["method", "compression"], rows, rev)

    # async: {"methods": {method: {arm: rec}}} (controller arm optional)
    legacy = _load("BENCH_async.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        rows = []
        for method, arms in legacy["methods"].items():
            for arm, rec in arms.items():
                rows.append(make_row({"method": method, "arm": arm}, rec,
                                     rev=rev or "unknown"))
        docs["async"] = _doc("async", legacy.get("config", {}),
                             ["method", "arm"], rows, rev)

    # kernels: one legacy file -> fused + sharded docs; micro rows lived only
    # in results/bench/kernels.csv
    legacy = _load("BENCH_kernels.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        docs["kernels_fused"] = _doc(
            "kernels_fused", legacy.get("config", {}), ["case"],
            _rows_from_mapping(legacy["cases"], "case", rev), rev)
        sh = legacy.get("sharded", {})
        docs["kernels_sharded"] = _doc(
            "kernels_sharded", sh.get("config", {}), ["plan"],
            [make_row({"plan": plan},
                      {"n_shards": pr["n_shards"],
                       "collective_bytes_sharded":
                           pr["sharded"]["collective_bytes"],
                       "collective_bytes_naive":
                           pr["naive"]["collective_bytes"],
                       "collective_bytes_tree":
                           pr["tree"]["collective_bytes"]},
                      rev=rev or "unknown")
             for plan, pr in sh.get("plans", {}).items()], rev)
        micro = os.path.join(root, "results", "bench", "kernels.csv")
        if os.path.exists(micro):
            rows = []
            for r in _read_legacy_csv(micro):
                rows.append(make_row(
                    {"kernel": r["kernel"]},
                    {"us_interpret": float(r["us_interpret"]),
                     "us_ref_jit": float(r["us_ref_jit"])},
                    rev=r.get("git_rev") or "unknown"))
            docs["kernels"] = _doc(
                "kernels", {"backend": legacy.get("config", {}).get(
                    "backend", "cpu")}, ["kernel"], rows, rev)

    # serve: {"archs": {arch: {mode: rec}}}
    legacy = _load("BENCH_serve.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        rows = []
        for arch, modes in legacy["archs"].items():
            for mode, rec in modes.items():
                rec = {k: v for k, v in rec.items() if k != "mode"}
                rows.append(make_row({"arch": arch, "mode": mode}, rec,
                                     rev=rev or "unknown"))
        docs["serve"] = _doc("serve", legacy.get("config", {}),
                             ["arch", "mode"], rows, rev)

    # train_lm: {"methods": {...}, "full_shape_projection": [...]}
    legacy = _load("BENCH_train_lm.json")
    if legacy and "schema_version" not in legacy:
        rev = legacy.get("git_rev")
        rows = _rows_from_mapping(legacy["methods"], "method", rev)
        for p in legacy.get("full_shape_projection", []):
            coords = {"method": f"projection:{p['shape']}@{p['mesh']}"}
            rec = {k: v for k, v in p.items()
                   if k not in ("shape", "mesh", "mode", "tag")}
            rows.append(make_row(
                coords, rec,
                info={k: p[k] for k in ("shape", "mesh", "mode", "tag")
                      if k in p},
                rev=rev or "unknown"))
        docs["train_lm"] = _doc("train_lm", legacy.get("config", {}),
                                ["method"], rows, rev)

    # fig1 / sec52: CSV-only legacy artifacts -> documents
    fig1 = os.path.join(root, "results", "bench", "fig1.csv")
    if os.path.exists(fig1):
        rows = []
        for r in _read_legacy_csv(fig1):
            rows.append(make_row(
                {"main_frac": float(r["main_frac"]), "method": r["method"],
                 "round": int(r["round"])},
                {"loss": float(r["loss"]), "test_acc": float(r["test_acc"])},
                rev=r.get("git_rev") or "unknown"))
        if rows and "schema_version" not in open(fig1).readline():
            docs["fig1"] = _doc("fig1", {"model": "mlp_cls", "clients": 10,
                                         "rounds": 25, "h_local": 6},
                                ["main_frac", "method", "round"], rows, None)
    sec52 = os.path.join(root, "results", "bench", "sec52.csv")
    if os.path.exists(sec52):
        rows = []
        for r in _read_legacy_csv(sec52):
            if "v_init" not in r:
                rows = []
                break
            rows.append(make_row(
                {"v_init": r["v_init"], "tau": float(r["tau"])},
                {"mean_step_norm": float(r["mean_step_norm"])},
                rev=r.get("git_rev") or "unknown"))
        if rows:
            docs["sec52"] = _doc("sec52", {"rounds": 5, "h_local": 5,
                                           "clients": 4, "method":
                                           "fedadagrad"},
                                 ["v_init", "tau"], rows, None)

    for doc in docs.values():
        assert_valid(doc)
    if write:
        for name, doc in docs.items():
            write_outputs(doc, out_dir=root,
                          results_dir=os.path.join(root, "results", "bench"))
        # controller rows now live on the async document's arm axis
        stale = os.path.join(root, "results", "bench", "controller.csv")
        if os.path.exists(stale) and "async" in docs:
            os.remove(stale)
    return docs


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #


def _parse_set(pairs):
    out = {}
    for p in pairs:
        k, _, v = p.partition("=")
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def _parse_select(pairs):
    out = {}
    for p in pairs:
        axis, _, vs = p.partition("=")
        vals = []
        for v in vs.split(","):
            try:
                vals.append(json.loads(v))
            except json.JSONDecodeError:
                vals.append(v)
        out[axis] = tuple(vals)
    return out


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(prog="benchmarks.matrix",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="expand + run + validate + emit")
    p_run.add_argument("--bench", required=True)
    p_run.add_argument("--select", action="append", default=[],
                       metavar="axis=v1,v2")
    p_run.add_argument("--limit", type=int, default=None)
    p_run.add_argument("--set", dest="sets", action="append", default=[],
                       metavar="key=value")
    p_run.add_argument("--out-dir", default=None)
    p_run.add_argument("--results-dir", default=None)

    p_upd = sub.add_parser("update-output",
                           help="regenerate CSV/summary from stored JSON "
                                "without re-running")
    p_upd.add_argument("paths", nargs="*")
    p_upd.add_argument("--bench", default=None)
    p_upd.add_argument("--results-dir", default=None)

    p_val = sub.add_parser("validate", help="schema-check BENCH documents")
    p_val.add_argument("paths", nargs="+")

    p_exp = sub.add_parser("expand", help="print the deterministic points")
    p_exp.add_argument("--bench", required=True)
    p_exp.add_argument("--select", action="append", default=[])
    p_exp.add_argument("--limit", type=int, default=None)

    p_mig = sub.add_parser("migrate", help="one-shot legacy converter")
    p_mig.add_argument("--write", action="store_true")
    p_mig.add_argument("--root", default=None)

    args = ap.parse_args(argv)

    if args.cmd == "run":
        doc = run_bench(args.bench, select=_parse_select(args.select),
                        limit=args.limit, overrides=_parse_set(args.sets),
                        out_dir=args.out_dir, results_dir=args.results_dir)
        for metric, value in summarize(doc):
            print(f"{doc['bench']},{metric},{value}")
        print(f"# {len(doc['rows'])} rows -> "
              f"{bench_paths(doc['bench'], args.out_dir, args.results_dir)[0]}")
        return 0

    if args.cmd == "update-output":
        paths = list(args.paths)
        if args.bench:
            paths.append(bench_paths(args.bench)[0])
        for path in paths:
            doc, csv_path = update_output(path, results_dir=args.results_dir)
            for metric, value in summarize(doc):
                print(f"{doc['bench']},{metric},{value}")
            print(f"# regenerated {csv_path} from {path} (no rerun)")
        return 0

    if args.cmd == "validate":
        bad = 0
        for path in args.paths:
            errs = validate_doc(json.load(open(path)))
            if errs:
                bad += 1
                print(f"{path}: INVALID")
                for e in errs:
                    print(f"  - {e}")
            else:
                print(f"{path}: ok")
        return 1 if bad else 0

    if args.cmd == "expand":
        bench = _registry()[args.bench]
        for pt in expand(bench.config, select=_parse_select(args.select),
                         limit=args.limit):
            print(json.dumps({"coords": pt.coords, "seed": pt.seed}))
        return 0

    if args.cmd == "migrate":
        docs = migrate(root=args.root, write=args.write)
        for name, doc in sorted(docs.items()):
            print(f"{name}: {len(doc['rows'])} rows "
                  f"({'written' if args.write else 'dry-run'})")
        return 0


if __name__ == "__main__":
    # Whether invoked as `python -m benchmarks.matrix` or as a script, this
    # module is loaded as __main__ — alias it as benchmarks.matrix so
    # run.py's registrations land in THIS registry, not a second instance.
    if __package__ in (None, ""):
        sys.path.insert(0, _REPO_ROOT)
    sys.modules.setdefault("benchmarks.matrix", sys.modules["__main__"])
    import benchmarks
    benchmarks.matrix = sys.modules["benchmarks.matrix"]
    sys.exit(main())
