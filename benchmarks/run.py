"""Benchmark harness — one benchmark per paper table/figure + roofline feeds.

Outputs CSV rows ``benchmark,metric,value`` to stdout and per-benchmark CSVs
under results/bench/.

  fig1        paper Figure 1: {SGD, Adam-global, Adam-local, OASIS-global,
              OASIS-local} on heterogeneous classification (30/50/70% main
              class), loss + accuracy per communication round.
  thm1        Theorem 1 shape validation on identical-data quadratics:
              noise-ball vs γ and vs M; transient rate vs (1-γμ/2Γ).
  thm2        Theorem 2: heterogeneous quadratics; stationary error vs H and
              vs the analytic bound.
  sec52       §5.2 critique table: FedAdaGrad step size as τ→0 with
              v_{-1}=1 (stalls) vs v_{-1}=τ² (does not).
  engine      wall-time per round for every round-engine method (savic,
              fedavg, fedadagrad, fedadam, fedyogi, local-adam) on the
              reduced config; also writes BENCH_engine.json at the repo root.
  compression bytes-on-wire per round × wall-time for every sync compression
              operator (none/topk/randk/int8-stochastic, ±error feedback) on
              a method slice; writes BENCH_compression.json at the repo root.
  async       simulated wall-clock sync vs staleness-buffered async under the
              lognormal-straggler systems model for every method (simulated
              round time + time-to-loss); writes BENCH_async.json.
  comm        communication volume per round: SAVIC sync vs per-step DDP
              (analytic, from param counts) + measured collective bytes from
              dry-run artifacts when present.
  kernels     µs/call for the Pallas kernels (interpret mode on CPU —
              correctness-path timing, NOT TPU perf) vs their jnp references,
              PLUS the fused flat-buffer local step: HBM bytes per launch
              (xla_cost_properties) fused vs the pre-PR per-leaf kernel path,
              per PrecondConfig kind, AND the shard-mapped rows (8-device
              subprocess): per-step collective bytes of the per-shard flat
              pipeline (~0) vs the naive global flat view's reshard blowup on
              model-/FSDP-/mixed-sharded plans; writes BENCH_kernels.json at
              the repo root.
  serve       production decode path: prefill-cache reuse vs prompt replay
              (TTFT, phase timings), steady-state decode tok/s with p50/p99
              per-token latency, and continuous vs static batching on the
              same Poisson arrival trace; writes BENCH_serve.json at the
              repo root.
  train_lm    federated causal-LM training through the production driver
              (repro.launch.train) on the reduced qwen2-0.5b zoo config:
              real loss curves, tokens/sec/device and simulated round time
              for every engine method, plus the full-shape (train_4k on the
              16×16 mesh) tokens/sec/device projection from the dry-run cost
              model; writes BENCH_train_lm.json at the repo root.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
_GIT_REV = None


def _git_rev():
    """Short git rev of the tree the numbers came from (benchmark hygiene:
    every emitted BENCH row is attributable to a commit). Cached; "unknown"
    outside a git checkout."""
    global _GIT_REV
    if _GIT_REV is None:
        import subprocess
        try:
            _GIT_REV = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=10,
            ).stdout.strip() or "unknown"
        except Exception:
            _GIT_REV = "unknown"
    return _GIT_REV


def _emit(rows, name):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.csv")
    rows = [{**r, "git_rev": _git_rev()} for r in rows]
    with open(path, "w") as f:
        if rows:
            f.write(",".join(rows[0].keys()) + "\n")
            for r in rows:
                f.write(",".join(str(v) for v in r.values()) + "\n")
    return path


def _dump_json(name, payload):
    """Write a BENCH_*.json at the repo root, stamped with the git rev."""
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        json.dump({**payload, "git_rev": _git_rev()}, f, indent=1)
    return path


# --------------------------------------------------------------------------- #
# fig1 — the paper's experiment
# --------------------------------------------------------------------------- #


def _mlp(n_in, n_classes, width=128):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (n_in, width)) * (n_in ** -0.5),
                "b1": jnp.zeros((width,)),
                "w2": jax.random.normal(k2, (width, n_classes)) * width ** -0.5,
                "b2": jnp.zeros((n_classes,))}

    def loss(params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
        return (logz - gold).mean()

    def acc(params, x, y):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        return float((jnp.argmax(logits, -1) == y).mean())

    return init, loss, acc


def bench_fig1(rounds=25, H=6, fracs=(0.3, 0.5, 0.7), seed=0):
    from repro.core import PrecondConfig, SavicConfig, engine, savic
    from repro.data import (ClassificationData, FederatedLoader,
                            main_class_partition)

    methods = {
        "SGD": ("identity", "global"),
        "Adam global": ("adam", "global"),
        "Adam local": ("adam", "local"),
        "OASIS global": ("oasis", "global"),
        "OASIS local": ("oasis", "local"),
    }
    data = ClassificationData.make(n=8000, n_classes=10, seed=seed)
    ntest = 1000
    xte = jnp.asarray(data.x[-ntest:])
    yte = jnp.asarray(data.y[-ntest:])
    rows = []
    for frac in fracs:
        parts = main_class_partition(data.y[:-ntest], 10, frac, seed=seed)
        for mname, (kind, scaling) in methods.items():
            init, loss, acc = _mlp(data.x.shape[1], 10)
            # α floor active (corrected Adam debias: D̂ tracks |g| from the
            # first sync), shared γ across methods — the Fig.1 comparison
            pc = PrecondConfig(kind=kind, alpha=1e-2)
            sv = SavicConfig(gamma=0.002, beta1=0.9, scaling=scaling)
            spec = savic.engine_spec(pc, sv)
            step = jax.jit(engine.build_round_step(loss, spec))
            state = engine.init_state(jax.random.PRNGKey(seed), init, spec, 10)
            loader = FederatedLoader(data.x[:-ntest],
                                     data.y[:-ntest].astype(np.int32),
                                     parts, batch_size=64, seed=seed)
            key = jax.random.PRNGKey(seed + 1)
            for r in range(rounds):
                key, k = jax.random.split(key)
                batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
                state, met = step(state, batch, k)
                avg = engine.average_params(state)
                rows.append({"main_frac": frac, "method": mname, "round": r,
                             "loss": float(met["loss"]),
                             "test_acc": acc(avg, xte, yte)})
    path = _emit(rows, "fig1")
    # summary: convergence SPEED (the paper's Fig.1 axis is communication
    # rounds) — rounds to reach loss <= 1.2 and loss at round 10, per method
    out = []
    for mname in methods:
        for frac in (0.3, 0.5):
            seq = sorted((r["round"], r["loss"]) for r in rows
                         if r["method"] == mname and r["main_frac"] == frac)
            hit = next((rd for rd, l in seq if l <= 1.2), -1)
            out.append(("fig1", f"rounds_to_loss1.2_{int(frac*100)}_"
                        f"{mname.replace(' ', '_')}", hit))
        l10 = [r["loss"] for r in rows if r["method"] == mname
               and r["main_frac"] == 0.5 and r["round"] == 10][0]
        out.append(("fig1", f"loss_at_r10_50_{mname.replace(' ', '_')}",
                    round(l10, 3)))
    return out, path


# --------------------------------------------------------------------------- #
# thm1 / thm2 — quadratic validations
# --------------------------------------------------------------------------- #


def _quad_runner(problem, gamma, H, rounds, kind="identity", alpha=1e-8,
                 seed=0):
    from repro.core import PrecondConfig, SavicConfig, savic
    from repro.data import QuadraticLoader
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        Qm, bm = Q[micro["cid"]], b[micro["cid"]]
        return 0.5 * (x - bm) @ Qm @ (x - bm) + micro["z"] @ x

    pc = PrecondConfig(kind=kind, alpha=alpha)
    sv = SavicConfig(gamma=gamma, beta1=0.0)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    M, d = problem.b.shape
    state = savic.init_state(jax.random.PRNGKey(seed),
                             lambda k: {"x": jnp.zeros(d)}, pc, sv, M)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    xstar = jnp.asarray(problem.x_star(), jnp.float32)
    dists = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, _ = step(state, jax.tree.map(jnp.asarray,
                                            loader.round_batch(H)), k)
        x = savic.average_params(state)["x"]
        dists.append(float(jnp.sum((x - xstar) ** 2)))
    return np.asarray(dists)


def bench_thm1():
    from repro.core import theory
    from repro.data import QuadraticProblem
    prob = QuadraticProblem.make(d=24, M=8, mu=0.5, L=4.0, sigma=0.6, seed=1)
    rows, out = [], []
    balls = {}
    for gamma in (0.02, 0.04, 0.08):
        tail = np.mean([_quad_runner(prob, gamma, 4, 120, seed=s)[-10:].mean()
                        for s in range(3)])
        balls[gamma] = tail
        rows.append({"experiment": "ball_vs_gamma", "gamma": gamma, "H": 4,
                     "M": 8, "value": tail})
    out.append(("thm1", "ball_ratio_gamma_4x",
                round(balls[0.08] / balls[0.02], 2)))
    for M in (2, 8):
        p = QuadraticProblem.make(d=24, M=M, mu=0.5, L=4.0, sigma=0.6, seed=1)
        tail = np.mean([_quad_runner(p, 0.06, 4, 120, seed=s)[-10:].mean()
                        for s in range(3)])
        rows.append({"experiment": "ball_vs_M", "gamma": 0.06, "H": 4, "M": M,
                     "value": tail})
        balls[f"M{M}"] = tail
    out.append(("thm1", "ball_ratio_M_4x", round(balls["M2"] / balls["M8"], 2)))
    d = _quad_runner(prob, 0.05, 4, 40, seed=0)
    spec = theory.ProblemSpec(mu=0.5, L=4.0, sigma2=0.36, alpha=1, Gamma=1,
                              M=8, H=4)
    pred = theory.thm1_rate(spec, 0.05) ** 4
    meas = (d[9] / d[0]) ** (1 / 9)
    out.append(("thm1", "transient_rate_measured", round(meas, 4)))
    out.append(("thm1", "transient_rate_bound_per_round", round(pred, 4)))
    return out, _emit(rows, "thm1")


def bench_thm2():
    from repro.core import theory
    from repro.data import QuadraticProblem
    prob = QuadraticProblem.make(d=24, M=8, mu=0.5, L=4.0, sigma=0.2,
                                 heterogeneity=6.0, seed=2)
    rows, out = [], []
    balls = {}
    for H in (1, 4, 16):
        tail = np.mean([_quad_runner(prob, 0.04, H, 320 // H,
                                     seed=s)[-5:].mean() for s in range(3)])
        balls[H] = tail
        rows.append({"experiment": "ball_vs_H", "gamma": 0.04, "H": H,
                     "sigma_dif2": prob.sigma_dif2(), "value": tail})
    out.append(("thm2", "ball_H16_over_H1", round(balls[16] / balls[1], 2)))
    spec = theory.ProblemSpec(mu=0.5, L=4.0, sigma2=0.04, alpha=1.0,
                              Gamma=1.0, M=8, H=4)
    rhs = theory.thm2_bound(spec, 0.04, 320 // 4, r0=float(
        np.sum(prob.x_star() ** 2)), sigma2_dif=prob.sigma_dif2())
    lhs = 0.5 * 4.0 * balls[4]       # crude f-gap proxy: 0.5·L·dist²
    out.append(("thm2", "bound_satisfied", int(lhs <= rhs)))
    out.append(("thm2", "bound_slack_x", round(rhs / max(lhs, 1e-12), 1)))
    return out, _emit(rows, "thm2")


def bench_sec52():
    from repro.core import engine
    from repro.data import QuadraticLoader, QuadraticProblem
    prob = QuadraticProblem.make(d=24, M=4, mu=0.5, L=4.0, sigma=0.3, seed=0)
    Q = jnp.asarray(prob.Q, jnp.float32)
    b = jnp.asarray(prob.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    rows, out = [], []
    for v_init_mode, v_init in (("one", 1.0), ("tau2", None)):
        for tau in (1e-1, 1e-3, 1e-5):
            spec = engine.method_spec("fedadagrad", eta=0.05, eta_l=0.5 * tau,
                                      tau=tau, server_beta1=0.0, v_init=v_init)
            step = jax.jit(engine.build_round_step(loss, spec))
            state = engine.init_state(jax.random.PRNGKey(0),
                                      lambda k: {"x": jnp.zeros(24)}, spec, 4)
            loader = QuadraticLoader(prob, seed=0)
            key = jax.random.PRNGKey(1)
            sn = []
            for _ in range(5):
                key, k = jax.random.split(key)
                state, met = step(state, jax.tree.map(
                    jnp.asarray, loader.round_batch(5)), k)
                sn.append(float(met["step_norm"]))
            rows.append({"v_init": v_init_mode, "tau": tau,
                         "mean_step_norm": float(np.mean(sn))})
    stall = [r for r in rows if r["v_init"] == "one"]
    fixed = [r for r in rows if r["v_init"] == "tau2"]
    out.append(("sec52", "stall_ratio_vinit1",
                round(stall[0]["mean_step_norm"]
                      / max(stall[-1]["mean_step_norm"], 1e-12), 1)))
    out.append(("sec52", "stall_ratio_vinit_tau2",
                round(fixed[0]["mean_step_norm"]
                      / max(fixed[-1]["mean_step_norm"], 1e-12), 2)))
    return out, _emit(rows, "sec52")


# --------------------------------------------------------------------------- #
# engine — wall-time per round per method (reduced config) -> BENCH_engine.json
# --------------------------------------------------------------------------- #


ENGINE_BENCH_METHODS = ("savic", "fedavg", "fedadagrad", "fedadam", "fedyogi",
                        "local-adam")


def _time_round_loop(spec, init, loss, data, parts, rounds, H, M, seed):
    """Shared engine/compression timing loop: wall time per round + analytic
    bytes-on-wire per round (benchmark hygiene: every engine timing record
    carries its communication volume)."""
    from repro.core import engine
    from repro.data import FederatedLoader

    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
    loader = FederatedLoader(data.x, data.y.astype(np.int32), parts[:M],
                             batch_size=32, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    times = []
    for r in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        t0 = time.perf_counter()
        state, met = step(state, batch, k)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) * 1e3)
    wire = engine.bytes_on_wire(
        spec, jax.eval_shape(init, jax.random.PRNGKey(seed)))
    # only sampled clients transmit under partial participation (half-up to
    # match engine.participation_weights — round() banker's-rounds 0.5·M)
    n_tx = max(1, int(math.floor(spec.sync.participation * M + 0.5)))
    return {
        "round_ms_first": round(times[0], 3),        # includes compile
        "round_ms_mean": round(float(np.mean(times[1:])), 3),
        "round_ms_p50": round(float(np.median(times[1:])), 3),
        "rounds": rounds,
        "final_loss": round(float(met["loss"]), 4),
        "wire_bytes_per_client_round": wire["total_bytes"],
        "wire_bytes_per_round": wire["total_bytes"] * n_tx,
        "compression_x": wire["compression_x"],
    }


def bench_engine(rounds=12, H=4, M=8, seed=0):
    """Per-round wall time for every engine method on the reduced fig1-style
    config (MLP on heterogeneous classification). Emits the usual CSV plus a
    machine-readable BENCH_engine.json at the repo root to seed the perf
    trajectory across PRs."""
    from repro.core import engine
    from repro.data import (ClassificationData, FederatedLoader,
                            main_class_partition)

    data = ClassificationData.make(n=2000, n_classes=10, seed=seed)
    parts = main_class_partition(data.y, 10, 0.5, seed=seed)
    rows, out = [], []
    methods_json = {}
    # adaptive-server step is ~η per coordinate: the Adam/Yogi server needs a
    # smaller η when clients are scaled too (local-adam)
    overrides = {"local-adam": dict(eta_l=0.005, eta=0.02)}
    for method in ENGINE_BENCH_METHODS:
        init, loss, _ = _mlp(data.x.shape[1], 10)
        kw = dict(gamma=0.002, alpha=1e-2, eta_l=0.02, eta=0.1)
        kw.update(overrides.get(method, {}))
        spec = engine.method_spec(method, **kw)
        rec = _time_round_loop(spec, init, loss, data, parts, rounds, H, M,
                               seed)
        methods_json[method] = rec
        rows.append({"method": method, **rec})
        out.append(("engine", f"round_ms_{method.replace('-', '_')}",
                    rec["round_ms_mean"]))
    path_json = _dump_json("BENCH_engine.json", {"bench": "engine_round_walltime",
                   "config": {"model": "mlp_cls_reduced", "clients": M,
                              "h_local": H, "rounds": rounds,
                              "backend": jax.default_backend()},
                   "methods": methods_json})
    return out, _emit(rows, "engine")


# --------------------------------------------------------------------------- #
# compression — bytes-on-wire × wall-time per (method, operator)
#               -> BENCH_compression.json
# --------------------------------------------------------------------------- #


COMPRESSION_BENCH_CASES = (
    ("none", 1.0, False),
    ("topk", 0.1, False),
    ("topk", 0.1, True),
    ("randk", 0.1, False),
    ("int8-stochastic", 1.0, False),
)
COMPRESSION_BENCH_METHODS = ("savic", "fedavg", "fedadam")


def bench_compression(rounds=10, H=4, M=8, seed=0):
    """Every compression operator × a representative method slice on the
    reduced fig1-style config: bytes-on-wire per round alongside wall time, so
    BENCH_compression.json seeds a communication-volume trajectory (not just a
    latency one). EF topk / int8 rows double as end-to-end convergence
    sanity (final_loss)."""
    from repro.core import engine
    from repro.data import ClassificationData, main_class_partition

    data = ClassificationData.make(n=2000, n_classes=10, seed=seed)
    parts = main_class_partition(data.y, 10, 0.5, seed=seed)
    rows, out = [], []
    entries = {}
    for method in COMPRESSION_BENCH_METHODS:
        for op, k, ef in COMPRESSION_BENCH_CASES:
            init, loss, _ = _mlp(data.x.shape[1], 10)
            spec = engine.method_spec(
                method, gamma=0.002, alpha=1e-2, eta_l=0.02, eta=0.1,
                compression=engine.CompressionSpec(op=op, k=k,
                                                   error_feedback=ef))
            rec = _time_round_loop(spec, init, loss, data, parts, rounds, H,
                                   M, seed)
            tag = f"{method}__{op}" + (f"_k{k}" if op in ("topk", "randk")
                                       else "") + ("_ef" if ef else "")
            entries[tag] = rec
            rows.append({"method": method, "op": op, "k": k,
                         "error_feedback": ef, **rec})
    for method in COMPRESSION_BENCH_METHODS:
        base = entries[f"{method}__none"]
        ef_ = entries[f"{method}__topk_k0.1_ef"]
        out.append(("compression", f"wire_x_topk_{method.replace('-', '_')}",
                    round(base["wire_bytes_per_round"]
                          / ef_["wire_bytes_per_round"], 1)))
        out.append(("compression", f"round_ms_topk_ef_{method.replace('-', '_')}",
                    ef_["round_ms_mean"]))
    path_json = _dump_json("BENCH_compression.json", {"bench": "compression_bytes_x_walltime",
                   "config": {"model": "mlp_cls_reduced", "clients": M,
                              "h_local": H, "rounds": rounds,
                              "backend": jax.default_backend()},
                   "entries": entries})
    return out, _emit(rows, "compression")


# --------------------------------------------------------------------------- #
# async — simulated wall-clock sync vs async under systems heterogeneity
#         -> BENCH_async.json
# --------------------------------------------------------------------------- #


ASYNC_BENCH_BUFFER = 4       # staleness budget B for the async arm
ASYNC_BENCH_SIGMA = 0.8      # lognormal straggler sigma
# shared lr settings (bench_controller races on the same footing)
ASYNC_BENCH_KW = dict(gamma=0.002, alpha=1e-2, eta_l=0.02, eta=0.1)
ASYNC_BENCH_OVERRIDES = {"local-adam": dict(eta_l=0.005, eta=0.02)}
# staleness-scaled server lr for buffered arms (see bench_async docstring)
ASYNC_BENCH_ASYNC_OVERRIDES = {"fedadagrad": dict(eta=0.025),
                               "fedadam": dict(eta=0.015),
                               "fedyogi": dict(eta=0.015),
                               "local-adam": dict(eta=0.005)}


def bench_async(rounds=30, H=6, M=8, seed=0):
    """Sync barrier vs staleness-buffered async for every engine method under
    the lognormal-straggler systems model (DESIGN.md §5).

    The sync arm runs uniform H for ``rounds`` rounds with the server waiting
    for the slowest client (simulated round time max_m t_m·H). The async arm
    gives stragglers a budgeted H_m (fewer local steps) and a B-round
    staleness buffer, so the simulated server period is max_m(t_m·H_m)/B —
    and it gets 4·rounds rounds, matching the B=4 staleness budget (its
    simulated rounds are ~B× shorter, so both arms spend comparable simulated
    time). Adaptive servers get a staleness-scaled-down η in the async arm
    (the FedBuff discipline: a lagged pseudo-gradient through an adaptive
    normalizer needs a smaller server step or it oscillates divergently —
    measured here, η=0.1 FedAdam ends 90× above init under B=4 lag). Both
    arms race the simulated clock to a shared target loss (55% of the sync
    arm's round-0 loss); writes BENCH_async.json at the repo root to seed the
    async-speedup trajectory.
    """
    from repro.core import engine
    from repro.data import ClassificationData, main_class_partition
    from repro.data.federated import (local_steps_from_times,
                                      sample_step_times, simulated_round_time)

    data = ClassificationData.make(n=2000, n_classes=10, seed=seed)
    parts = main_class_partition(data.y, 10, 0.5, seed=seed)
    step_times = sample_step_times("lognormal", M, seed=seed,
                                   sigma=ASYNC_BENCH_SIGMA)
    h_m = tuple(int(h) for h in local_steps_from_times(step_times, H))
    sim_t = {
        "sync": simulated_round_time(step_times, [H] * M, barrier="sync"),
        "async": simulated_round_time(step_times, h_m, barrier="async",
                                      buffer_rounds=ASYNC_BENCH_BUFFER),
    }
    arms = {
        "sync": dict(),
        "async": dict(local_steps=h_m,
                      asynchrony=engine.AsyncSpec(
                          buffer_rounds=ASYNC_BENCH_BUFFER,
                          weighting="polynomial")),
    }
    arm_rounds = {"sync": rounds, "async": ASYNC_BENCH_BUFFER * rounds}
    overrides = ASYNC_BENCH_OVERRIDES
    async_overrides = ASYNC_BENCH_ASYNC_OVERRIDES
    rows, out = [], []
    entries = {}
    from repro.data import FederatedLoader
    for method in ENGINE_BENCH_METHODS:
        entries[method] = {}
        target = None
        for arm, arm_kw in arms.items():
            init, loss, _ = _mlp(data.x.shape[1], 10)
            kw = dict(ASYNC_BENCH_KW)
            kw.update(overrides.get(method, {}))
            if arm == "async":
                kw.update(async_overrides.get(method, {}))
            spec = engine.method_spec(method, **kw, **arm_kw)
            step = jax.jit(engine.build_round_step(loss, spec))
            state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
            loader = FederatedLoader(data.x, data.y.astype(np.int32),
                                     parts[:M], batch_size=32, seed=seed)
            key = jax.random.PRNGKey(seed + 1)
            times, losses = [], []
            for _ in range(arm_rounds[arm]):
                key, k = jax.random.split(key)
                batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
                t0 = time.perf_counter()
                state, met = step(state, batch, k)
                jax.block_until_ready(state)
                times.append((time.perf_counter() - t0) * 1e3)
                losses.append(float(met["loss"]))
            if target is None:
                target = losses[0] * 0.55   # shared, reachable by both arms
            r_hit = next((r + 1 for r, l in enumerate(losses) if l <= target),
                         -1)
            rec = {
                "sim_round_time": round(sim_t[arm], 4),
                "round_ms_mean": round(float(np.mean(times[1:])), 3),
                "rounds": arm_rounds[arm],
                "final_loss": round(losses[-1], 4),
                "target_loss": round(target, 4),
                "rounds_to_target": r_hit,
                "sim_time_to_target": round(r_hit * sim_t[arm], 4)
                if r_hit > 0 else -1.0,
            }
            entries[method][arm] = rec
            rows.append({"method": method, "arm": arm, **rec})
        s, a = entries[method]["sync"], entries[method]["async"]
        if s["sim_time_to_target"] > 0 and a["sim_time_to_target"] > 0:
            out.append(("async",
                        f"sim_speedup_{method.replace('-', '_')}",
                        round(s["sim_time_to_target"]
                              / a["sim_time_to_target"], 2)))
        out.append(("async", f"final_loss_async_{method.replace('-', '_')}",
                    a["final_loss"]))
    path_json = _dump_json("BENCH_async.json", {"bench": "async_simulated_walltime",
                   "config": {"model": "mlp_cls_reduced", "clients": M,
                              "h_local": H, "rounds": rounds,
                              "het_model": "lognormal",
                              "sigma": ASYNC_BENCH_SIGMA,
                              "step_times": [round(float(t), 4)
                                             for t in step_times],
                              "local_steps_async": list(h_m),
                              "buffer_rounds": ASYNC_BENCH_BUFFER,
                              "staleness_weight": "polynomial",
                              "backend": jax.default_backend()},
                   "methods": entries})
    return out, _emit(rows, "async")


# --------------------------------------------------------------------------- #
# controller — adaptive knob schedule races the static arms of bench_async
# --------------------------------------------------------------------------- #


# Per-method controller tuning (the static arms get per-method lr overrides;
# the controller arm gets per-method gns targets — same discipline). The GNS
# scale is method-dependent: on this task at H_t=2 the gns EMA sits around
# 3-7 for savic, ~12 for fedavg, ~8-9 for fedadagrad, ~5-8 for fedadam/yogi,
# ~6 for local-adam. noise_target sits just above the early-phase plateau so
# H_t grows only once accumulated heterogeneity noise crosses it; local-adam
# diverges under tiny partial rounds, so it starts near the full budget and
# grows immediately.
CONTROLLER_H_MIN = 2            # >= 2 active clients; at h=1 the gns ratio
                                # degenerates to M/n_act - 1 (no variance info)
CONTROLLER_TUNE = {
    "savic": dict(noise_target=8.0),
    "fedavg": dict(noise_target=12.0),
    "fedadagrad": dict(noise_target=8.5),
    "fedadam": dict(noise_target=9.0),
    "fedyogi": dict(noise_target=9.0),
    "local-adam": dict(noise_target=5.0, h_min=5),
}


def bench_controller(rounds=30, H=6, M=8, seed=0):
    """Adaptive communication-budget controller vs the best static config,
    per method, on the SAME lognormal straggler trace / data / learning
    rates as bench_async (DESIGN.md §10).

    The controller arm starts at a cheap round shape (H_t = 2 under the
    min(t)-bounded budget rule: 4 of 8 clients active, stragglers sitting
    rounds out inside the staleness window) and grows H_t geometrically
    while the gradient-noise-scale EMA exceeds its ``noise_target``. Its
    per-round simulated time comes from the REALIZED knobs — the
    ``ctrl_h_m``/``ctrl_b_eff`` metrics the engine logs — through the same
    ``simulated_round_time`` systems model the static arms use, so the race
    is apples-to-apples: cumulative simulated clock until the method's
    recorded ``target_loss`` from BENCH_async.json (regenerated first if
    missing). Inserts a "controller" entry per method into BENCH_async.json
    next to the static sync/async arms.
    """
    from repro.core import engine
    from repro.data import (ClassificationData, FederatedLoader,
                            main_class_partition)
    from repro.data.federated import sample_step_times, simulated_round_time

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    async_json = os.path.join(repo_root, "BENCH_async.json")
    if not os.path.exists(async_json):
        bench_async(rounds=rounds, H=H, M=M, seed=seed)
    with open(async_json) as f:
        base = json.load(f)

    data = ClassificationData.make(n=2000, n_classes=10, seed=seed)
    parts = main_class_partition(data.y, 10, 0.5, seed=seed)
    step_times = sample_step_times("lognormal", M, seed=seed,
                                   sigma=ASYNC_BENCH_SIGMA)
    n_rounds = ASYNC_BENCH_BUFFER * rounds   # same round count as async arm
    rows, out = [], []
    entries = base["methods"]
    for method in ENGINE_BENCH_METHODS:
        tune = dict(h_min=CONTROLLER_H_MIN)
        tune.update(CONTROLLER_TUNE.get(method, {}))
        ctrl = engine.ControllerSpec(
            enabled=True, h_max=H, buffer_max=ASYNC_BENCH_BUFFER,
            step_times=tuple(float(t) for t in step_times), **tune)
        init, loss, _ = _mlp(data.x.shape[1], 10)
        kw = dict(ASYNC_BENCH_KW)
        kw.update(ASYNC_BENCH_OVERRIDES.get(method, {}))
        kw.update(ASYNC_BENCH_ASYNC_OVERRIDES.get(method, {}))
        spec = engine.method_spec(
            method, **kw,
            asynchrony=engine.AsyncSpec(buffer_rounds=ASYNC_BENCH_BUFFER,
                                        weighting="polynomial"),
            controller=ctrl)
        step = jax.jit(engine.build_round_step(loss, spec))
        state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
        loader = FederatedLoader(data.x, data.y.astype(np.int32), parts[:M],
                                 batch_size=32, seed=seed)
        key = jax.random.PRNGKey(seed + 1)
        target = entries[method]["sync"]["target_loss"]
        times, losses, h_t_log = [], [], []
        sim_elapsed, sim_hit, r_hit = 0.0, -1.0, -1
        for _ in range(n_rounds):
            key, k = jax.random.split(key)
            batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
            t0 = time.perf_counter()
            state, met = step(state, batch, k)
            jax.block_until_ready(state)
            times.append((time.perf_counter() - t0) * 1e3)
            # simulated clock advances by the round shape the controller
            # actually realized this round
            h_real = [int(h) for h in np.asarray(met["ctrl_h_m"])]
            sim_elapsed += simulated_round_time(
                step_times, h_real, barrier="async",
                buffer_rounds=int(met["ctrl_b_eff"]))
            losses.append(float(met["loss"]))
            h_t_log.append(int(met["ctrl_h_t"]))
            if r_hit < 0 and losses[-1] <= target:
                r_hit, sim_hit = len(losses), round(sim_elapsed, 4)
        # compact knob trajectory: (round, H_t) at each change point
        h_t_changes = [[r, h] for r, h in enumerate(h_t_log)
                       if r == 0 or h != h_t_log[r - 1]]
        rec = {
            "sim_time_total": round(sim_elapsed, 4),
            "round_ms_mean": round(float(np.mean(times[1:])), 3),
            "rounds": n_rounds,
            "final_loss": round(losses[-1], 4),
            "target_loss": target,
            "rounds_to_target": r_hit,
            "sim_time_to_target": sim_hit,
            "h_t_trajectory": h_t_changes,
            "b_eff": int(np.asarray(state["ctrl"]["b_eff"])),
            "tune": tune,
        }
        entries[method]["controller"] = rec
        rows.append({"method": method, "arm": "controller", **rec})
        statics = [entries[method][a]["sim_time_to_target"]
                   for a in ("sync", "async")
                   if entries[method][a]["sim_time_to_target"] > 0]
        mname = method.replace("-", "_")
        out.append(("controller", f"sim_time_adaptive_{mname}", sim_hit))
        if statics and sim_hit > 0:
            out.append(("controller", f"sim_speedup_vs_best_static_{mname}",
                        round(min(statics) / sim_hit, 2)))
    base["config"]["controller"] = {
        "h_min": CONTROLLER_H_MIN, "h_max": H,
        "buffer_max": ASYNC_BENCH_BUFFER, "rounds": n_rounds,
        "per_method_tune": CONTROLLER_TUNE,
    }
    _dump_json("BENCH_async.json", base)
    return out, _emit(rows, "controller")


# --------------------------------------------------------------------------- #
# serve — production decode path -> BENCH_serve.json
# --------------------------------------------------------------------------- #


SERVE_BENCH_ARCHS = ("qwen2-0.5b", "mamba2-1.3b")
SERVE_BENCH_TRACE = dict(slots=4, n_requests=10, arrival_rate=0.6)


def bench_serve(batch=4, prompt_len=32, gen_len=16, seed=0):
    """The serving decode path (launch/serve.py, DESIGN.md §8) on reduced
    configs: prefill-cache reuse vs prompt replay (TTFT + phase-separated
    timings), steady-state decode tok/s with p50/p99 per-token latency, and
    continuous vs static batching on the SAME Poisson arrival trace (makespan
    and throughput in decode-step clock units — the scheduling comparison —
    with compute wall seconds reported alongside, honestly: on CPU-reduced
    configs continuous pays more prefill dispatches, so its wall tok/s can
    trail static even when its trace throughput wins). All arms run with
    warmup=True, so compile time is excluded. Writes BENCH_serve.json at the
    repo root."""
    from repro.launch.serve import (serve, serve_continuous, serve_replay,
                                    serve_static)
    kw = dict(reduced=True, batch=batch, prompt_len=prompt_len,
              gen_len=gen_len, seed=seed, warmup=True, verbose=False)
    tkw = dict(reduced=True, prompt_len=8, gen_len=gen_len, seed=seed,
               warmup=True, verbose=False, **SERVE_BENCH_TRACE)
    rows, out, entries = [], [], {}
    for arch in SERVE_BENCH_ARCHS:
        reuse = serve(arch, **kw)
        replay = serve_replay(arch, **kw)
        assert np.array_equal(reuse.tokens, replay.tokens)   # same greedy ids
        cont = serve_continuous(arch, **tkw)
        stat = serve_static(arch, **tkw)
        rec = {}
        for mode, r in (("reuse", reuse), ("replay", replay)):
            rec[mode] = dict(r.timings)
            rec[mode]["p50_token_s"] = float(np.percentile(r.per_token_s, 50))
            rec[mode]["p99_token_s"] = float(np.percentile(r.per_token_s, 99))
            rows.append({"arch": arch, "mode": mode, **rec[mode]})
        for r in (cont, stat):
            m = r.metrics
            rec[m["mode"]] = {k: v for k, v in m.items()
                              if k != "jit_cache_sizes"}
            rec[m["mode"]]["jit_cache_step"] = m["jit_cache_sizes"]["step"]
            rows.append({"arch": arch, "mode": m["mode"],
                         "ttft_s": "", "tok_per_s": m["wall_tok_per_s"],
                         "p50_token_s": m["p50_step_s"],
                         "p99_token_s": m["p99_step_s"],
                         "makespan_steps": m["makespan_steps"],
                         "tok_per_step": m["tok_per_step"],
                         "mean_queue_delay_steps":
                             m["mean_queue_delay_steps"]})
        entries[arch] = rec
        a = arch.replace("-", "_").replace(".", "_")
        out.append(("serve", f"ttft_speedup_reuse_{a}",
                    round(replay.timings["ttft_s"]
                          / max(reuse.timings["ttft_s"], 1e-9), 2)))
        out.append(("serve", f"decode_tok_per_s_{a}",
                    round(reuse.timings["tok_per_s"], 1)))
        out.append(("serve", f"trace_throughput_x_continuous_{a}",
                    round(cont.metrics["tok_per_step"]
                          / max(stat.metrics["tok_per_step"], 1e-9), 2)))
    path_json = _dump_json("BENCH_serve.json", {"bench": "serve_decode_path",
                   "config": {"reduced": True, "batch": batch,
                              "prompt_len": prompt_len, "gen_len": gen_len,
                              "trace": {**SERVE_BENCH_TRACE,
                                        "prompt_len": 8, "gen_len": gen_len,
                                        "clock": "decode-step units; "
                                                 "prefill=0 steps"},
                              "warmup": True, "greedy": True,
                              "backend": jax.default_backend()},
                   "archs": entries})
    return out, _emit(rows, "serve")


# --------------------------------------------------------------------------- #
# comm — communication volume per round
# --------------------------------------------------------------------------- #


def bench_comm():
    from repro.configs import ARCH_IDS, get_config
    rows, out = [], []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        savic_bytes = 2 * 4 * n          # params + momentum all-reduce, fp32
        ddp_bytes = 4 * n * 8            # grad all-reduce every step, H=8
        rows.append({"arch": arch, "params": n,
                     "savic_sync_GB_per_round": savic_bytes / 1e9,
                     "ddp_GB_per_round_H8": ddp_bytes / 1e9,
                     "saving_x": ddp_bytes / savic_bytes})
    out.append(("comm", "mean_saving_x",
                round(float(np.mean([r["saving_x"] for r in rows])), 1)))
    ddir = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if os.path.isdir(ddir):
        import glob
        n_rec = len(glob.glob(os.path.join(ddir, "*__16x16.json")))
        out.append(("comm", "dryrun_records_single_pod", n_rec))
    return out, _emit(rows, "comm")


# --------------------------------------------------------------------------- #
# kernels — µs/call (interpret mode: correctness-path timing, NOT TPU perf)
# --------------------------------------------------------------------------- #


def _time(f, *args, n=5):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6


def bench_fused_sharded():
    """Sharded rows for BENCH_kernels.json (DESIGN.md §7): per-step collective
    bytes of the shard-mapped fused local step on model-/FSDP-/mixed-sharded
    plans, vs the naive global flat view's resharding blowup and the tree
    path's zero baseline.  Runs benchmarks/sharded_collectives.py in a
    subprocess (the worker forces 8 host devices; this process keeps 1)."""
    import subprocess
    import sys
    worker = os.path.join(os.path.dirname(__file__), "sharded_collectives.py")
    r = subprocess.run([sys.executable, worker], capture_output=True,
                       text=True, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"sharded_collectives worker failed:\n{r.stderr}")
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    rows, out = [], []
    for plan, pr in rec["plans"].items():
        rows.append({
            "plan": plan, "n_shards": pr["n_shards"],
            "collective_bytes_sharded": pr["sharded"]["collective_bytes"],
            "collective_bytes_naive": pr["naive"]["collective_bytes"],
            "collective_bytes_tree": pr["tree"]["collective_bytes"],
        })
        out.append(("kernels", f"sharded_step_collective_bytes_{plan}",
                    pr["sharded"]["collective_bytes"]))
        out.append(("kernels", f"naive_flat_collective_bytes_{plan}",
                    pr["naive"]["collective_bytes"]))
    return out, rows, rec


FUSED_BENCH_M = 8
FUSED_BENCH_SHAPES = {"w1": (256, 128), "b1": (128,), "w2": (128, 10),
                      "b2": (10,)}
FUSED_BENCH_CASES = (
    # (tag, PrecondConfig kind, D advances in-loop?, external Hutchinson stat?)
    ("adam_local", "adam", True, False),
    ("rmsprop_local", "rmsprop", True, False),
    ("adagrad_local", "adagrad", True, False),
    ("oasis_local", "oasis", True, True),
    ("adam_global", "adam", False, False),
)


def bench_fused_step():
    """HBM bytes on the client local step, fused flat-buffer kernel vs the
    pre-PR per-leaf kernel path — per PrecondConfig kind -> BENCH_kernels.json.

    Both arms are measured with ``xla_cost_properties`` ("bytes accessed") on
    compiled programs, summed PER LAUNCH, because HBM round-trips happen at
    launch boundaries:

      * pre-PR path — what ``use_fused_kernel`` emitted before the flat-buffer
        refactor: an XLA momentum pass, ONE ``scaled_update`` launch PER LEAF
        (whose contract includes a zeros operand and a dead momentum write),
        and — when D advances every step — a separate D̂ EMA pass with its own
        HBM round-trip.  6+ reads / 4 writes per element across 3 launches.
      * fused path — the ``fused_step_flat`` kernel contract: ONE launch over
        the per-client flat buffer, 4–5 reads / 2–3 writes per element.  On
        CPU the Mosaic kernel cannot compile, so the measured program is the
        kernel's jnp oracle (``ref.fused_step_ref``) in one jit — XLA emits a
        single fusion whose traffic IS the kernel's operand/result contract;
        tests/test_fused_step.py pins the kernel to that oracle.

    Wall-times: the oracle fusions (both arms; TPU-shaped traffic) plus the
    interpret-mode Pallas kernel (correctness-path timing, NOT TPU perf).
    """
    from repro.core import preconditioner as PC
    from repro.kernels import ops, ref
    from repro.utils.flatten import FlatLayout
    from repro.utils.hlo_cost import xla_cost_properties

    M = FUSED_BENCH_M
    k = jax.random.key(7)
    tree = lambda i0: {name: jax.random.normal(jax.random.fold_in(k, i0 + i),
                                               (M,) + shp)
                       for i, (name, shp) in
                       enumerate(FUSED_BENCH_SHAPES.items())}
    p_t, m_t, g_t = tree(0), tree(10), tree(20)
    d_t = jax.tree.map(lambda x: jnp.abs(x) + 0.1, tree(30))
    h_t = tree(40)
    layout = FlatLayout.for_tree(p_t, batch_dims=1)
    P, Mo, G = (layout.flatten(x, batch_dims=1) for x in (p_t, m_t, g_t))
    D, Hs = layout.flatten(d_t, batch_dims=1), layout.flatten(h_t, batch_dims=1)
    t_m = jnp.zeros((M,), jnp.int32)

    def _bytes(fn, *args):
        c = jax.jit(fn).lower(*args).compile()
        cost = xla_cost_properties(c)
        if "bytes accessed" not in cost:
            # fail loudly: a silent 0 would fabricate the reduction ratio
            raise RuntimeError("cost_analysis() has no 'bytes accessed' on "
                               f"this backend; keys: {sorted(cost)}")
        return float(cost["bytes accessed"]), c

    rows, out, entries = [], [], {}
    for tag, kind, local, hutch in FUSED_BENCH_CASES:
        pc = PC.PrecondConfig(kind=kind, alpha=1e-2)
        squared = pc.rule == "squared"

        # ---- pre-PR per-leaf kernel path ------------------------------------
        # Verbatim launch structure of the old fused path: an XLA momentum
        # pass, then PER LEAF (flattened to (M·n_leaf,)) a pad launch to the
        # fixed BLOCK = 8·128·16 (the old kernel padded every ragged leaf all
        # the way up — custom-call operands materialize, so the pad copies
        # are real HBM traffic), the kernel launch (zeros in the momentum
        # slot, beta1 pre-applied, dead m output — see ops.scaled_update_tree)
        # and the [:n] slice launch back.
        OLD_BLOCK = 8 * 128 * 16

        def mom_pass(m, g):
            return jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)

        by_mom, c_mom = _bytes(mom_pass, m_t, g_t)
        by_leaf = 0.0
        c_leaf = []
        for name in FUSED_BENCH_SHAPES:
            n_leaf = int(np.prod(FUSED_BENCH_SHAPES[name])) * M
            npad = (OLD_BLOCK - n_leaf % OLD_BLOCK) % OLD_BLOCK
            flat = lambda x: x.reshape(-1)
            args = (flat(p_t[name]), jnp.zeros((n_leaf,), jnp.float32),
                    flat(m_t[name]), flat(d_t[name]))
            launches = []
            if npad:
                def pad_fn(p, z, m, d, _npad=npad):
                    pad = lambda x, v: jnp.concatenate(
                        [x, jnp.full((_npad,), v, x.dtype)])
                    return pad(p, 0), pad(z, 0), pad(m, 0), pad(d, 1.0)
                b, c = _bytes(pad_fn, *args)
                by_leaf += b
                launches.append((c, args))
                args = tuple(np.asarray(a) for a in c(*args))
                args = tuple(jnp.asarray(a) for a in args)

            def leaf_fn(p, z, m, d):
                return ref.scaled_update_ref(p, z, m, d, gamma=0.01,
                                             beta1=0.0, alpha=1e-2,
                                             squared=squared)
            b, c = _bytes(leaf_fn, *args)
            by_leaf += b
            launches.append((c, args))
            if npad:
                outs = tuple(jnp.asarray(np.asarray(o)) for o in c(*args))

                def slice_fn(po, mo, _n=n_leaf):
                    return po[:_n], mo[:_n]
                b, c = _bytes(slice_fn, *outs)
                by_leaf += b
                launches.append((c, outs))
            c_leaf.append(launches)
        by_dpass = 0.0
        c_dpass = None
        if local:
            def d_pass(d, g, h, t):
                b = PC.beta_t(pc, t)
                stat = h if hutch else jax.tree.map(lambda x: x ** 2, g)
                if kind == "adagrad":
                    return jax.tree.map(lambda dd, hh: dd + hh, d, stat)
                return jax.tree.map(lambda dd, hh: b * dd + (1.0 - b) * hh,
                                    d, stat)
            by_dpass, c_dpass = _bytes(d_pass, d_t, g_t, h_t, jnp.int32(0))
        bytes_prepr = by_mom + by_leaf + by_dpass

        # ---- fused flat-buffer kernel contract (one launch) ----------------
        kw = dict(gamma=0.01, beta1=0.9, alpha=1e-2, beta2=pc.beta2,
                  kind=kind, clip="max", schedule=pc.schedule, update_d=local)
        hstat = Hs if (local and hutch) else None
        d_arg = D if local else D[0]
        bytes_fused, c_fused = _bytes(
            lambda *a: ref.fused_step_ref(*a, **kw), P, Mo, G, d_arg, hstat,
            t_m, None)

        ratio = bytes_prepr / max(bytes_fused, 1.0)
        us_prepr = _time(lambda: [c_mom(m_t, g_t)]
                         + [c(*a) for launches in c_leaf
                            for c, a in launches]
                         + ([c_dpass(d_t, g_t, h_t, jnp.int32(0))]
                            if c_dpass else []))
        us_oracle = _time(lambda: c_fused(P, Mo, G, d_arg, hstat, t_m, None))
        us_interp = _time(lambda: ops.fused_local_step(
            P, Mo, G, d_arg, hstat, t_m, None, **kw))
        rec = {
            "bytes_prepr_path": bytes_prepr,
            "bytes_fused": bytes_fused,
            "hbm_reduction_x": round(ratio, 2),
            "launches_prepr": 1 + sum(len(l) for l in c_leaf) + (1 if local
                                                                 else 0),
            "launches_fused": 1,
            "us_prepr_oracle": round(us_prepr, 1),
            "us_fused_oracle": round(us_oracle, 1),
            "us_fused_interpret": round(us_interp, 1),
        }
        entries[tag] = rec
        rows.append({"case": tag, **rec})
        out.append(("kernels", f"hbm_reduction_x_{tag}", rec["hbm_reduction_x"]))

    # sharded rows (DESIGN.md §7): per-step collective bytes of the
    # shard-mapped path must be ~0 vs the naive flat view's reshard blowup
    sh_out, sh_rows, sh_rec = bench_fused_sharded()
    out.extend(sh_out)
    _emit(sh_rows, "kernels_sharded")

    path_json = _dump_json("BENCH_kernels.json", {
            "bench": "fused_local_step_hbm_bytes",
            "config": {
                "clients": FUSED_BENCH_M,
                "leaves": {nm: list(s) for nm, s in
                           FUSED_BENCH_SHAPES.items()},
                "n_total_per_client": FlatLayout.for_tree(
                    {n_: jax.ShapeDtypeStruct(s, jnp.float32) for n_, s in
                     FUSED_BENCH_SHAPES.items()}).n_total,
                "backend": jax.default_backend(),
                "measurement": "xla_cost_properties('bytes accessed'), "
                               "summed per launch (HBM round-trips happen at "
                               "launch boundaries). pre-PR arm = the verbatim "
                               "old launch structure: momentum pass + per-"
                               "leaf pad-to-BLOCK / kernel-contract / slice "
                               "launches + separate D-EMA pass. fused arm = "
                               "the fused_step_flat kernel's jnp-oracle "
                               "contract in one jit (kernel pinned to it in "
                               "tests/test_fused_step.py); interpret-mode "
                               "timing is correctness-path, not TPU perf",
            },
            "cases": entries,
            "sharded": {
                "config": {
                    "n_devices": sh_rec["n_devices"],
                    "clients": sh_rec["clients"],
                    "leaves": sh_rec["leaves"],
                    "measurement": "ONE local step of the flat pipeline "
                                   "(flatten -> fused kernel -> unflatten) "
                                   "lowered per plan on a (2,4)=('data',"
                                   "'model') 8-host-device mesh; collective "
                                   "bytes parsed from optimized HLO (utils/"
                                   "hlo.collective_bytes — cost_analysis() "
                                   "has no collective key on this backend), "
                                   "'bytes accessed' from "
                                   "xla_cost_properties. sharded arm runs "
                                   "inside shard_map (must be 0 collective "
                                   "bytes: nothing touches the flat "
                                   "buffers); naive arm is the single "
                                   "global flat view the pre-PR launch gate "
                                   "guarded against (GSPMD reshards the "
                                   "whole client state per step); tree arm "
                                   "is the old fallback baseline. The "
                                   "sharded arm's bytes_accessed includes "
                                   "the flatten/unflatten boundary copies "
                                   "that the real engine pays once per "
                                   "round, not per step (the flat carry "
                                   "rides through the scan).",
                },
                "plans": sh_rec["plans"],
            }})
    return out, rows


def bench_kernels():
    from repro.kernels import ops, ref
    rows, out = [], []
    k = jax.random.key(0)
    n = 1 << 20
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (n,))
               for i in range(3))
    d = jax.random.uniform(jax.random.fold_in(k, 3), (n,), minval=0.1,
                           maxval=2.0)
    kw = dict(gamma=0.1, beta1=0.9, alpha=1e-3)
    us_k = _time(lambda: ops.scaled_update(p, m, g, d, **kw))
    us_r = _time(jax.jit(lambda p, m, g, d: ref.scaled_update_ref(
        p, m, g, d, **kw)), p, m, g, d)
    rows.append({"kernel": "scaled_update_1M", "us_interpret": us_k,
                 "us_ref_jit": us_r})

    B, S, H, D = 1, 512, 4, 64
    q, kk, v = (jax.random.normal(jax.random.fold_in(k, 10 + i), (B, S, H, D))
                for i in range(3))
    us_k = _time(lambda: ops.flash_attention(q, kk, v, bq=128, bk=128))
    us_r = _time(jax.jit(lambda q, kk, v: ref.attention_ref(
        q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3))), q, kk, v)
    rows.append({"kernel": "flash_attn_512", "us_interpret": us_k,
                 "us_ref_jit": us_r})

    B, S, H, P, N = 1, 256, 4, 32, 16
    xh = jax.random.normal(jax.random.fold_in(k, 20), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 21),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 22), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(k, 23), (B, S, H, N))
    Cm = jax.random.normal(jax.random.fold_in(k, 24), (B, S, H, N))
    us_k = _time(lambda: ops.ssd(xh, dt, A, Bm, Cm, chunk=64))
    us_r = _time(jax.jit(lambda *a: ref.ssd_ref(*a)), xh, dt, A, Bm, Cm)
    rows.append({"kernel": "ssd_256", "us_interpret": us_k,
                 "us_ref_jit": us_r})
    for r in rows:
        out.append(("kernels", r["kernel"] + "_us", round(r["us_interpret"])))
    # fused flat-buffer local step: HBM bytes fused vs pre-PR per-leaf path
    # (per PrecondConfig kind; writes BENCH_kernels.json at the repo root)
    f_out, f_rows = bench_fused_step()
    out.extend(f_out)
    _emit(f_rows, "kernels_fused")
    return out, _emit(rows, "kernels")


# --------------------------------------------------------------------------- #
# train_lm — federated LM rounds through the production driver
#            -> BENCH_train_lm.json
# --------------------------------------------------------------------------- #


# per-method step sizes for the qwen2-0.5b-reduced Markov-stream task (tuned
# for a visible loss trend in ~10 rounds on CPU; pure-SGD clients need a much
# larger γ than adam-scaled ones on a token LM)
TRAIN_LM_OVERRIDES = {
    "savic": ["--gamma", "0.05"],
    "fedavg": ["--gamma", "6.0"],
    "fedadagrad": ["--gamma", "1.0", "--server-eta", "0.5"],
    "fedadam": ["--gamma", "1.0", "--server-eta", "0.5"],
    "fedyogi": ["--gamma", "1.0", "--server-eta", "0.5"],
    "local-adam": ["--gamma", "0.05", "--server-eta", "0.05"],
}

TRAIN_LM_ARCH = "qwen2-0.5b"


def _train_lm_projection(arch):
    """Full-shape tokens/sec/device from the dry-run cost model: roofline
    bound (compute/memory/collective, benchmarks/roofline.py terms) over the
    trip-count-corrected per-device numerators of each train artifact."""
    import glob

    from repro.configs import get_shape
    from roofline import terms

    ddir = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    proj = []
    for f in sorted(glob.glob(os.path.join(ddir, f"{arch}__*.json"))):
        rec = json.load(open(f))
        if rec.get("kind") != "train" or not rec.get("ok"):
            continue
        t = terms(rec)
        bound_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        s = get_shape(rec["shape"])
        tokens = s.global_batch * s.seq_len * rec.get("h_local", 8)
        proj.append({
            "shape": rec["shape"], "mesh": rec["mesh"], "mode": rec["mode"],
            "tag": rec.get("tag", ""), "n_devices": rec["n_devices"],
            "tokens_per_round": tokens,
            "round_s_roofline": round(bound_s, 6),
            "dominant_term": t["dominant"],
            "tokens_per_s_per_device": round(
                tokens / rec["n_devices"] / bound_s, 1),
            # compute-term bound for context: the measured-HLO memory term
            # dominates this artifact by ~500×, so the roofline number above
            # is the conservative end of the projection
            "tokens_per_s_per_device_compute_bound": round(
                tokens / rec["n_devices"] / t["compute_s"], 1),
            "model_flops_utilization": round(t["roofline_frac"], 4),
        })
    return proj


def bench_train_lm(rounds=10, H=8, M=4, b=4, seq=64, seed=0):
    """Real federated causal-LM rounds for every engine method, through the
    SAME driver that carries mesh launches (repro.launch.train): loss curves
    on the reduced qwen2-0.5b config (CPU), measured tokens/sec/device, the
    simulated round time, and the full-shape projection rows. Emits the usual
    CSV plus BENCH_train_lm.json at the repo root."""
    from repro.launch import train as train_mod

    tokens_round = M * H * b * seq
    n_dev = jax.device_count()
    rows, out, methods_json = [], [], {}
    for method in ENGINE_BENCH_METHODS:
        argv = ["--arch", TRAIN_LM_ARCH, "--reduced", "--method", method,
                "--rounds", str(rounds), "--h-local", str(H),
                "--clients", str(M), "--batch", str(b), "--seq", str(seq),
                "--seed", str(seed)] + TRAIN_LM_OVERRIDES[method]
        log = train_mod.main(argv)
        losses = [l["loss"] for l in log]
        walls = [l["wall_s"] for l in log]
        steady = walls[1:] or walls           # round 0 pays the jit compile
        tps = tokens_round / float(np.mean(steady))
        half = len(losses) // 2
        rec = {
            "loss_first": round(losses[0], 4),
            "loss_last": round(losses[-1], 4),
            "loss_curve": [round(l, 4) for l in losses],
            "loss_decreasing_trend": bool(
                losses[-1] < losses[0]
                and np.mean(losses[half:]) < np.mean(losses[:half])),
            "round_wall_s_mean": round(float(np.mean(steady)), 4),
            "tokens_per_s": round(tps, 1),
            "tokens_per_s_per_device": round(tps / n_dev, 1),
            "sim_time_total": log[-1]["sim_time"],
        }
        methods_json[method] = rec
        rows.append({"method": method,
                     **{k: ("|".join(str(x) for x in v)
                            if isinstance(v, list) else v)
                        for k, v in rec.items()}})
        out.append(("train_lm", f"loss_drop_{method.replace('-', '_')}",
                    round(losses[0] - losses[-1], 4)))
        out.append(("train_lm", f"tok_s_dev_{method.replace('-', '_')}",
                    rec["tokens_per_s_per_device"]))
    proj = _train_lm_projection(TRAIN_LM_ARCH)
    for p in proj:
        rows.append({"method": f"projection:{p['shape']}@{p['mesh']}",
                     "loss_first": "", "loss_last": "", "loss_curve": "",
                     "loss_decreasing_trend": "",
                     "round_wall_s_mean": p["round_s_roofline"],
                     "tokens_per_s": "",
                     "tokens_per_s_per_device": p["tokens_per_s_per_device"],
                     "sim_time_total": ""})
        out.append(("train_lm", f"tok_s_dev_proj_{p['shape']}",
                    p["tokens_per_s_per_device"]))
    path_json = _dump_json("BENCH_train_lm.json", {"bench": "train_lm",
                   "config": {"arch": f"{TRAIN_LM_ARCH}-reduced",
                              "clients": M, "h_local": H,
                              "batch_per_client": b, "seq": seq,
                              "rounds": rounds, "seed": seed,
                              "tokens_per_round": tokens_round,
                              "backend": jax.default_backend(),
                              "n_devices": n_dev},
                   "methods": methods_json,
                   "full_shape_projection": proj})
    return out, _emit(rows, "train_lm")


BENCHES = {
    "fig1": bench_fig1,
    "thm1": bench_thm1,
    "thm2": bench_thm2,
    "sec52": bench_sec52,
    "engine": bench_engine,
    "compression": bench_compression,
    "async": bench_async,
    "controller": bench_controller,
    "comm": bench_comm,
    "kernels": bench_kernels,
    "serve": bench_serve,
    "train_lm": bench_train_lm,
}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n for n in BENCHES if not args.only or n in args.only.split(",")]
    print("benchmark,metric,value")
    for name in names:
        t0 = time.time()
        out, path = BENCHES[name]()
        for b, metric, val in out:
            print(f"{b},{metric},{val}", flush=True)
        print(f"{name},seconds,{time.time()-t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
