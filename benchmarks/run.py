"""Benchmark registrations — every bench is a declarative matrix config plus
a ``run(point, ctx) -> rows`` callable registered in benchmarks/matrix.py
(DESIGN.md §11).  One runner expands each matrix deterministically, tags
every row with its full axis coordinates + git_rev + schema version, and
emits BENCH_<name>.json + results/bench/<name>.csv in the uniform row shape.

Registered benches (axes in parentheses):

  fig1            paper Figure 1 (main_frac × method; per-round rows)
  thm1 / thm2     Theorem 1/2 shape validation on quadratics (experiment)
  sec52           §5.2 FedAdaGrad v_{-1} critique (v_init × tau)
  engine          wall-time per round per engine method (method)
  compression     bytes-on-wire × wall-time (method × compression)
  async           sync vs buffered-async vs adaptive controller under the
                  lognormal straggler model (method × arm) — the old
                  ``controller`` subcommand is the arm=controller slice
  comm            analytic sync-vs-DDP communication volume (arch)
  kernels         Pallas kernel µs/call, interpret mode (kernel)
  kernels_fused   fused flat-buffer local step HBM bytes (case)
  kernels_sharded shard-mapped fused-step collective bytes (plan)
  serve           production decode path (arch × mode)
  train_lm        federated causal-LM rounds through the production driver
                  (method; + full-shape projection rows)

Run benches through the matrix CLI::

  python -m benchmarks.matrix run --bench engine [--select method=savic]
  python -m benchmarks.matrix update-output --bench engine   # no rerun
  python benchmarks/diff.py A.json B.json --check            # cross-PR diff

or through this module's legacy alias CLI (``python benchmarks/run.py
[--only engine,async]``), which prints the ``benchmark,metric,value``
trajectory lines derived from the stored rows.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # script style: python benchmarks/run.py
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import matrix
from benchmarks.matrix import BenchDef, MatrixConfig, make_row, register


# --------------------------------------------------------------------------- #
# shared helpers
# --------------------------------------------------------------------------- #


def _mlp(n_in, n_classes, width=128):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (n_in, width)) * (n_in ** -0.5),
                "b1": jnp.zeros((width,)),
                "w2": jax.random.normal(k2, (width, n_classes)) * width ** -0.5,
                "b2": jnp.zeros((n_classes,))}

    def loss(params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
        return (logz - gold).mean()

    def acc(params, x, y):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        return float((jnp.argmax(logits, -1) == y).mean())

    return init, loss, acc


def _quad_runner(problem, gamma, H, rounds, kind="identity", alpha=1e-8,
                 seed=0):
    from repro.core import PrecondConfig, SavicConfig, savic
    from repro.data import QuadraticLoader
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        Qm, bm = Q[micro["cid"]], b[micro["cid"]]
        return 0.5 * (x - bm) @ Qm @ (x - bm) + micro["z"] @ x

    pc = PrecondConfig(kind=kind, alpha=alpha)
    sv = SavicConfig(gamma=gamma, beta1=0.0)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    M, d = problem.b.shape
    state = savic.init_state(jax.random.PRNGKey(seed),
                             lambda k: {"x": jnp.zeros(d)}, pc, sv, M)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    xstar = jnp.asarray(problem.x_star(), jnp.float32)
    dists = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, _ = step(state, jax.tree.map(jnp.asarray,
                                            loader.round_batch(H)), k)
        x = savic.average_params(state)["x"]
        dists.append(float(jnp.sum((x - xstar) ** 2)))
    return np.asarray(dists)


def _time_round_loop(spec, init, loss, data, parts, rounds, H, M, seed):
    """Shared engine/compression timing loop: wall time per round + analytic
    bytes-on-wire per round (benchmark hygiene: every engine timing record
    carries its communication volume)."""
    from repro.core import engine
    from repro.data import FederatedLoader

    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
    loader = FederatedLoader(data.x, data.y.astype(np.int32), parts[:M],
                             batch_size=32, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    times = []
    for r in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        t0 = time.perf_counter()
        state, met = step(state, batch, k)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) * 1e3)
    wire = engine.bytes_on_wire(
        spec, jax.eval_shape(init, jax.random.PRNGKey(seed)))
    # only sampled clients transmit under partial participation (half-up to
    # match engine.participation_weights — round() banker's-rounds 0.5·M)
    n_tx = max(1, int(math.floor(spec.sync.participation * M + 0.5)))
    return {
        "round_ms_first": round(times[0], 3),        # includes compile
        "round_ms_mean": round(float(np.mean(times[1:])), 3),
        "round_ms_p50": round(float(np.median(times[1:])), 3),
        "rounds": rounds,
        "final_loss": round(float(met["loss"]), 4),
        "wire_bytes_per_client_round": wire["total_bytes"],
        "wire_bytes_per_round": wire["total_bytes"] * n_tx,
        "compression_x": wire["compression_x"],
    }


def _time(f, *args, n=5):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6


def _cls_data(ctx, seed, n=2000):
    """Reduced fig1-style classification task, cached across matrix points."""
    key = ("cls_data", seed, n)
    if key not in ctx:
        from repro.data import ClassificationData, main_class_partition
        data = ClassificationData.make(n=n, n_classes=10, seed=seed)
        parts = main_class_partition(data.y, 10, 0.5, seed=seed)
        ctx[key] = (data, parts)
    return ctx[key]


def _extra(ctx, **kv):
    ctx.setdefault("config_extra", {}).update(kv)


def _uniq(doc, axis):
    out = []
    for r in doc["rows"]:
        v = r["coords"][axis]
        if v not in out:
            out.append(v)
    return out


# --------------------------------------------------------------------------- #
# fig1 — the paper's experiment (main_frac × method; per-round rows)
# --------------------------------------------------------------------------- #


FIG1_METHODS = {
    "SGD": ("identity", "global"),
    "Adam global": ("adam", "global"),
    "Adam local": ("adam", "local"),
    "OASIS global": ("oasis", "global"),
    "OASIS local": ("oasis", "local"),
}


def _run_fig1(point, ctx):
    from repro.core import PrecondConfig, SavicConfig, engine, savic
    from repro.data import (ClassificationData, FederatedLoader,
                            main_class_partition)

    f, seed = point.fixed, point.seed
    if "fig1_env" not in ctx:
        data = ClassificationData.make(n=8000, n_classes=10, seed=seed)
        ntest = 1000
        ctx["fig1_env"] = dict(
            data=data, ntest=ntest, parts={},
            xte=jnp.asarray(data.x[-ntest:]), yte=jnp.asarray(data.y[-ntest:]))
    env = ctx["fig1_env"]
    data, ntest = env["data"], env["ntest"]
    frac, mname = point.coords["main_frac"], point.coords["method"]
    if frac not in env["parts"]:
        env["parts"][frac] = main_class_partition(data.y[:-ntest], 10, frac,
                                                  seed=seed)
    kind, scaling = FIG1_METHODS[mname]
    init, loss, acc = _mlp(data.x.shape[1], 10)
    # α floor active (corrected Adam debias: D̂ tracks |g| from the first
    # sync), shared γ across methods — the Fig.1 comparison
    pc = PrecondConfig(kind=kind, alpha=1e-2)
    sv = SavicConfig(gamma=0.002, beta1=0.9, scaling=scaling)
    spec = savic.engine_spec(pc, sv)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(seed), init, spec,
                              f["clients"])
    loader = FederatedLoader(data.x[:-ntest], data.y[:-ntest].astype(np.int32),
                             env["parts"][frac], batch_size=64, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    rows = []
    for r in range(f["rounds"]):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(f["h_local"]))
        state, met = step(state, batch, k)
        avg = engine.average_params(state)
        rows.append(make_row({**point.coords, "round": r},
                             {"loss": float(met["loss"]),
                              "test_acc": acc(avg, env["xte"], env["yte"])}))
    return rows


def _sum_fig1(doc):
    # convergence SPEED (the paper's Fig.1 axis is communication rounds):
    # rounds to reach loss <= 1.2 and loss at round 10, per method
    out = []
    rows = doc["rows"]
    for mname in _uniq(doc, "method"):
        for frac in (0.3, 0.5):
            seq = sorted((r["coords"]["round"], r["metrics"]["loss"])
                         for r in rows if r["coords"]["method"] == mname
                         and float(r["coords"]["main_frac"]) == frac)
            if not seq:
                continue
            hit = next((rd for rd, l in seq if l <= 1.2), -1)
            out.append((f"rounds_to_loss1.2_{int(frac * 100)}_"
                        f"{mname.replace(' ', '_')}", hit))
        l10 = [r["metrics"]["loss"] for r in rows
               if r["coords"]["method"] == mname
               and float(r["coords"]["main_frac"]) == 0.5
               and r["coords"]["round"] == 10]
        if l10:
            out.append((f"loss_at_r10_50_{mname.replace(' ', '_')}",
                        round(l10[0], 3)))
    return out


register(BenchDef(
    "fig1",
    MatrixConfig.make("fig1",
                      {"main_frac": (0.3, 0.5, 0.7),
                       "method": tuple(FIG1_METHODS)},
                      fixed=dict(model="mlp_cls", clients=10, rounds=25,
                                 h_local=6),
                      row_axes=("round",)),
    _run_fig1, _sum_fig1))


# --------------------------------------------------------------------------- #
# thm1 / thm2 — quadratic validations (experiment axis; per-case rows)
# --------------------------------------------------------------------------- #


def _run_thm1(point, ctx):
    from repro.core import theory
    from repro.data import QuadraticProblem
    if "thm1_prob" not in ctx:
        ctx["thm1_prob"] = QuadraticProblem.make(d=24, M=8, mu=0.5, L=4.0,
                                                 sigma=0.6, seed=1)
    prob = ctx["thm1_prob"]
    exp = point.coords["experiment"]
    rows = []
    if exp == "ball_vs_gamma":
        for gamma in (0.02, 0.04, 0.08):
            tail = float(np.mean([_quad_runner(prob, gamma, 4, 120,
                                               seed=s)[-10:].mean()
                                  for s in range(3)]))
            rows.append(make_row({**point.coords, "case": f"gamma{gamma}"},
                                 {"gamma": gamma, "H": 4, "M": 8,
                                  "value": tail}))
    elif exp == "ball_vs_M":
        for M in (2, 8):
            p = QuadraticProblem.make(d=24, M=M, mu=0.5, L=4.0, sigma=0.6,
                                      seed=1)
            tail = float(np.mean([_quad_runner(p, 0.06, 4, 120,
                                               seed=s)[-10:].mean()
                                  for s in range(3)]))
            rows.append(make_row({**point.coords, "case": f"M{M}"},
                                 {"gamma": 0.06, "H": 4, "M": M,
                                  "value": tail}))
    else:  # transient
        d = _quad_runner(prob, 0.05, 4, 40, seed=0)
        spec = theory.ProblemSpec(mu=0.5, L=4.0, sigma2=0.36, alpha=1,
                                  Gamma=1, M=8, H=4)
        pred = theory.thm1_rate(spec, 0.05) ** 4
        meas = (d[9] / d[0]) ** (1 / 9)
        rows.append(make_row(
            {**point.coords, "case": "rate"},
            {"transient_rate_measured": round(float(meas), 4),
             "transient_rate_bound_per_round": round(float(pred), 4)}))
    return rows


def _sum_thm1(doc):
    m = {r["coords"]["case"]: r["metrics"] for r in doc["rows"]}
    out = []
    if "gamma0.08" in m and "gamma0.02" in m:
        out.append(("ball_ratio_gamma_4x",
                    round(m["gamma0.08"]["value"] / m["gamma0.02"]["value"],
                          2)))
    if "M2" in m and "M8" in m:
        out.append(("ball_ratio_M_4x",
                    round(m["M2"]["value"] / m["M8"]["value"], 2)))
    if "rate" in m:
        out.append(("transient_rate_measured",
                    m["rate"]["transient_rate_measured"]))
        out.append(("transient_rate_bound_per_round",
                    m["rate"]["transient_rate_bound_per_round"]))
    return out


register(BenchDef(
    "thm1",
    MatrixConfig.make("thm1",
                      {"experiment": ("ball_vs_gamma", "ball_vs_M",
                                      "transient")},
                      fixed=dict(d=24, clients=8, mu=0.5, L=4.0, sigma=0.6,
                                 h_local=4),
                      row_axes=("case",)),
    _run_thm1, _sum_thm1))


def _thm2_ball(ctx, prob, H):
    balls = ctx.setdefault("thm2_balls", {})
    if H not in balls:
        balls[H] = float(np.mean([_quad_runner(prob, 0.04, H, 320 // H,
                                               seed=s)[-5:].mean()
                                  for s in range(3)]))
    return balls[H]


def _run_thm2(point, ctx):
    from repro.core import theory
    from repro.data import QuadraticProblem
    if "thm2_prob" not in ctx:
        ctx["thm2_prob"] = QuadraticProblem.make(d=24, M=8, mu=0.5, L=4.0,
                                                 sigma=0.2, heterogeneity=6.0,
                                                 seed=2)
    prob = ctx["thm2_prob"]
    if point.coords["experiment"] == "ball_vs_H":
        rows = []
        for H in (1, 4, 16):
            rows.append(make_row(
                {**point.coords, "case": f"H{H}"},
                {"gamma": 0.04, "H": H,
                 "sigma_dif2": float(prob.sigma_dif2()),
                 "value": _thm2_ball(ctx, prob, H)}))
        return rows
    # bound: crude f-gap proxy 0.5·L·dist² vs the analytic Thm-2 rhs
    spec = theory.ProblemSpec(mu=0.5, L=4.0, sigma2=0.04, alpha=1.0,
                              Gamma=1.0, M=8, H=4)
    rhs = float(theory.thm2_bound(spec, 0.04, 320 // 4,
                                  r0=float(np.sum(prob.x_star() ** 2)),
                                  sigma2_dif=prob.sigma_dif2()))
    lhs = 0.5 * 4.0 * _thm2_ball(ctx, prob, 4)
    return [make_row({**point.coords, "case": "check"},
                     {"bound_satisfied": int(lhs <= rhs),
                      "lhs": float(lhs), "rhs": rhs,
                      "bound_slack_x": round(rhs / max(lhs, 1e-12), 1)})]


def _sum_thm2(doc):
    m = {r["coords"]["case"]: r["metrics"] for r in doc["rows"]}
    out = []
    if "H16" in m and "H1" in m:
        out.append(("ball_H16_over_H1",
                    round(m["H16"]["value"] / m["H1"]["value"], 2)))
    if "check" in m:
        out.append(("bound_satisfied", m["check"]["bound_satisfied"]))
        out.append(("bound_slack_x", m["check"]["bound_slack_x"]))
    return out


register(BenchDef(
    "thm2",
    MatrixConfig.make("thm2", {"experiment": ("ball_vs_H", "bound")},
                      fixed=dict(d=24, clients=8, mu=0.5, L=4.0, sigma=0.2,
                                 heterogeneity=6.0, gamma=0.04),
                      row_axes=("case",)),
    _run_thm2, _sum_thm2))


# --------------------------------------------------------------------------- #
# sec52 — §5.2 FedAdaGrad v_{-1} critique (v_init × tau)
# --------------------------------------------------------------------------- #


def _run_sec52(point, ctx):
    from repro.core import engine
    from repro.data import QuadraticLoader, QuadraticProblem
    if "sec52_prob" not in ctx:
        ctx["sec52_prob"] = QuadraticProblem.make(d=24, M=4, mu=0.5, L=4.0,
                                                  sigma=0.3, seed=0)
    prob = ctx["sec52_prob"]
    Q = jnp.asarray(prob.Q, jnp.float32)
    b = jnp.asarray(prob.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    f = point.fixed
    tau = point.coords["tau"]
    v_init = 1.0 if point.coords["v_init"] == "one" else None
    spec = engine.method_spec("fedadagrad", eta=0.05, eta_l=0.5 * tau,
                              tau=tau, server_beta1=0.0, v_init=v_init)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec,
                              f["clients"])
    loader = QuadraticLoader(prob, seed=0)
    key = jax.random.PRNGKey(1)
    sn = []
    for _ in range(f["rounds"]):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(
            jnp.asarray, loader.round_batch(f["h_local"])), k)
        sn.append(float(met["step_norm"]))
    return [make_row(point.coords, {"mean_step_norm": float(np.mean(sn))})]


def _sum_sec52(doc):
    m = {(r["coords"]["v_init"], float(r["coords"]["tau"])): r["metrics"]
         for r in doc["rows"]}
    out = []
    for mode, label, nd in (("one", "stall_ratio_vinit1", 1),
                            ("tau2", "stall_ratio_vinit_tau2", 2)):
        hi, lo = m.get((mode, 0.1)), m.get((mode, 1e-5))
        if hi and lo:
            out.append((label, round(hi["mean_step_norm"]
                                     / max(lo["mean_step_norm"], 1e-12), nd)))
    return out


register(BenchDef(
    "sec52",
    MatrixConfig.make("sec52",
                      {"v_init": ("one", "tau2"), "tau": (0.1, 0.001, 1e-5)},
                      fixed=dict(method="fedadagrad", rounds=5, h_local=5,
                                 clients=4)),
    _run_sec52, _sum_sec52))


# --------------------------------------------------------------------------- #
# engine — wall-time per round per method (reduced config)
# --------------------------------------------------------------------------- #


ENGINE_BENCH_METHODS = ("savic", "fedavg", "fedadagrad", "fedadam", "fedyogi",
                        "local-adam")
# shared lr settings (the async/controller arms race on the same footing);
# the adaptive-server step is ~η per coordinate, so the Adam/Yogi server
# needs a smaller η when clients are scaled too (local-adam)
ASYNC_BENCH_KW = dict(gamma=0.002, alpha=1e-2, eta_l=0.02, eta=0.1)
ASYNC_BENCH_OVERRIDES = {"local-adam": dict(eta_l=0.005, eta=0.02)}


def _run_engine(point, ctx):
    from repro.core import engine
    f, seed = point.fixed, point.seed
    data, parts = _cls_data(ctx, seed)
    method = point.coords["method"]
    init, loss, _ = _mlp(data.x.shape[1], 10)
    kw = dict(ASYNC_BENCH_KW)
    kw.update(ASYNC_BENCH_OVERRIDES.get(method, {}))
    spec = engine.method_spec(method, **kw)
    rec = _time_round_loop(spec, init, loss, data, parts, f["rounds"],
                           f["h_local"], f["clients"], seed)
    _extra(ctx, backend=jax.default_backend())
    return [make_row(point.coords, rec)]


def _sum_engine(doc):
    return [(f"round_ms_{r['coords']['method'].replace('-', '_')}",
             r["metrics"]["round_ms_mean"]) for r in doc["rows"]]


register(BenchDef(
    "engine",
    MatrixConfig.make("engine", {"method": ENGINE_BENCH_METHODS},
                      fixed=dict(model="mlp_cls_reduced", clients=8,
                                 h_local=4, rounds=12)),
    _run_engine, _sum_engine))


# --------------------------------------------------------------------------- #
# compression — bytes-on-wire × wall-time per (method, operator)
# --------------------------------------------------------------------------- #


COMPRESSION_BENCH_METHODS = ("savic", "fedavg", "fedadam")


def _run_compression(point, ctx):
    from repro.core import engine
    f, seed = point.fixed, point.seed
    data, parts = _cls_data(ctx, seed)
    method = point.coords["method"]
    op, k, ef = matrix.COMPRESSION_VARIANTS[point.coords["compression"]]
    init, loss, _ = _mlp(data.x.shape[1], 10)
    spec = engine.method_spec(
        method, **ASYNC_BENCH_KW,
        compression=engine.CompressionSpec(op=op, k=k, error_feedback=ef))
    rec = _time_round_loop(spec, init, loss, data, parts, f["rounds"],
                           f["h_local"], f["clients"], seed)
    _extra(ctx, backend=jax.default_backend())
    return [make_row(point.coords, rec,
                     info={"op": op, "k": k, "error_feedback": ef})]


def _sum_compression(doc):
    m = {(r["coords"]["method"], r["coords"]["compression"]): r["metrics"]
         for r in doc["rows"]}
    out = []
    for method in _uniq(doc, "method"):
        base, ef = m.get((method, "none")), m.get((method, "topk0.1-ef"))
        if not base or not ef:
            continue
        mname = method.replace("-", "_")
        out.append((f"wire_x_topk_{mname}",
                    round(base["wire_bytes_per_round"]
                          / ef["wire_bytes_per_round"], 1)))
        out.append((f"round_ms_topk_ef_{mname}", ef["round_ms_mean"]))
    return out


register(BenchDef(
    "compression",
    MatrixConfig.make("compression",
                      {"method": COMPRESSION_BENCH_METHODS,
                       "compression": tuple(matrix.COMPRESSION_VARIANTS)},
                      fixed=dict(model="mlp_cls_reduced", clients=8,
                                 h_local=4, rounds=10)),
    _run_compression, _sum_compression,
    note="EF topk / int8 rows double as end-to-end convergence sanity "
         "(final_loss); wire bytes are analytic (engine.bytes_on_wire)"))


# --------------------------------------------------------------------------- #
# async — sync vs buffered-async vs adaptive controller (method × arm)
# --------------------------------------------------------------------------- #


ASYNC_BENCH_BUFFER = 4       # staleness budget B for the async arm
ASYNC_BENCH_SIGMA = 0.8      # lognormal straggler sigma
# staleness-scaled server lr for buffered arms (the FedBuff discipline: a
# lagged pseudo-gradient through an adaptive normalizer needs a smaller
# server step or it oscillates divergently — measured, η=0.1 FedAdam ends
# 90× above init under B=4 lag)
ASYNC_BENCH_ASYNC_OVERRIDES = {"fedadagrad": dict(eta=0.025),
                               "fedadam": dict(eta=0.015),
                               "fedyogi": dict(eta=0.015),
                               "local-adam": dict(eta=0.005)}

# Per-method controller tuning (the static arms get per-method lr overrides;
# the controller arm gets per-method gns targets — same discipline). The GNS
# scale is method-dependent: on this task at H_t=2 the gns EMA sits around
# 3-7 for savic, ~12 for fedavg, ~8-9 for fedadagrad, ~5-8 for fedadam/yogi,
# ~6 for local-adam. noise_target sits just above the early-phase plateau so
# H_t grows only once accumulated heterogeneity noise crosses it; local-adam
# diverges under tiny partial rounds, so it starts near the full budget and
# grows immediately.
CONTROLLER_H_MIN = 2            # >= 2 active clients; at h=1 the gns ratio
                                # degenerates to M/n_act - 1 (no variance info)
CONTROLLER_TUNE = {
    "savic": dict(noise_target=8.0),
    "fedavg": dict(noise_target=12.0),
    "fedadagrad": dict(noise_target=8.5),
    "fedadam": dict(noise_target=9.0),
    "fedyogi": dict(noise_target=9.0),
    "local-adam": dict(noise_target=5.0, h_min=5),
}


def _async_env(ctx, fixed, seed):
    """Straggler trace + data shared by all three arms (and recorded in the
    document config so the race is reproducible from the artifact alone)."""
    if "async_env" in ctx:
        return ctx["async_env"]
    from repro.data.federated import (local_steps_from_times,
                                      sample_step_times, simulated_round_time)
    M, H = fixed["clients"], fixed["h_local"]
    data, parts = _cls_data(ctx, seed)
    step_times = sample_step_times("lognormal", M, seed=seed,
                                   sigma=ASYNC_BENCH_SIGMA)
    h_m = tuple(int(h) for h in local_steps_from_times(step_times, H))
    sim_t = {
        "sync": simulated_round_time(step_times, [H] * M, barrier="sync"),
        "async": simulated_round_time(step_times, h_m, barrier="async",
                                      buffer_rounds=ASYNC_BENCH_BUFFER),
    }
    ctx["async_env"] = dict(data=data, parts=parts, step_times=step_times,
                            h_m=h_m, sim_t=sim_t)
    _extra(ctx,
           het_model="lognormal", sigma=ASYNC_BENCH_SIGMA,
           step_times=[round(float(t), 4) for t in step_times],
           local_steps_async=list(h_m),
           buffer_rounds=ASYNC_BENCH_BUFFER,
           staleness_weight="polynomial",
           controller={"h_min": CONTROLLER_H_MIN, "h_max": H,
                       "buffer_max": ASYNC_BENCH_BUFFER,
                       "rounds": ASYNC_BENCH_BUFFER * fixed["rounds"],
                       "per_method_tune": CONTROLLER_TUNE},
           backend=jax.default_backend())
    return ctx["async_env"]


def _async_target(ctx, method):
    """Shared time-to-loss target: set by the sync arm of this run; partial
    (--select) runs fall back to the committed sync row."""
    t = ctx.get("targets", {}).get(method)
    if t is not None:
        return t
    path = matrix.bench_paths("async")[0]
    if os.path.exists(path):
        doc = json.load(open(path))
        for r in doc.get("rows", []):
            if r["coords"].get("method") == method \
                    and r["coords"].get("arm") == "sync":
                return r["metrics"]["target_loss"]
    raise RuntimeError(f"no sync target_loss for {method!r}: run the sync "
                       "arm first (or keep arm=sync in --select)")


def _run_async(point, ctx):
    """One (method, arm) race against the simulated straggler clock.

    sync: uniform H, server waits for the slowest client (period max t_m·H).
    async: budgeted H_m + B-round staleness buffer (period max(t_m·H_m)/B),
    4·rounds rounds so both arms spend comparable simulated time.
    controller: the adaptive arm (DESIGN.md §10) — H_t grows while the
    gradient-noise-scale EMA exceeds its per-method target; its simulated
    clock advances by the REALIZED ctrl_h_m/ctrl_b_eff knobs through the
    same systems model, so the race is apples-to-apples.
    """
    from repro.core import engine
    from repro.data import FederatedLoader
    from repro.data.federated import simulated_round_time

    f, seed = point.fixed, point.seed
    M, H = f["clients"], f["h_local"]
    env = _async_env(ctx, f, seed)
    method, arm = point.coords["method"], point.coords["arm"]
    kw = dict(ASYNC_BENCH_KW)
    kw.update(ASYNC_BENCH_OVERRIDES.get(method, {}))
    if arm in ("async", "controller"):
        kw.update(ASYNC_BENCH_ASYNC_OVERRIDES.get(method, {}))
    init, loss, _ = _mlp(env["data"].x.shape[1], 10)
    n_rounds = f["rounds"] if arm == "sync" else ASYNC_BENCH_BUFFER * f["rounds"]
    tune = None
    if arm == "sync":
        arm_kw = {}
    elif arm == "async":
        arm_kw = dict(local_steps=env["h_m"],
                      asynchrony=engine.AsyncSpec(
                          buffer_rounds=ASYNC_BENCH_BUFFER,
                          weighting="polynomial"))
    else:
        tune = dict(h_min=CONTROLLER_H_MIN)
        tune.update(CONTROLLER_TUNE.get(method, {}))
        arm_kw = dict(
            asynchrony=engine.AsyncSpec(buffer_rounds=ASYNC_BENCH_BUFFER,
                                        weighting="polynomial"),
            controller=engine.ControllerSpec(
                enabled=True, h_max=H, buffer_max=ASYNC_BENCH_BUFFER,
                step_times=tuple(float(t) for t in env["step_times"]),
                **tune))
    spec = engine.method_spec(method, **kw, **arm_kw)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
    loader = FederatedLoader(env["data"].x, env["data"].y.astype(np.int32),
                             env["parts"][:M], batch_size=32, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    times, losses, h_t_log = [], [], []
    sim_elapsed, sim_hit, r_hit = 0.0, -1.0, -1
    target = None if arm == "sync" else _async_target(ctx, method)
    for _ in range(n_rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        t0 = time.perf_counter()
        state, met = step(state, batch, k)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) * 1e3)
        losses.append(float(met["loss"]))
        if arm == "controller":
            # simulated clock advances by the round shape the controller
            # actually realized this round
            h_real = [int(h) for h in np.asarray(met["ctrl_h_m"])]
            sim_elapsed += simulated_round_time(
                env["step_times"], h_real, barrier="async",
                buffer_rounds=int(met["ctrl_b_eff"]))
            h_t_log.append(int(met["ctrl_h_t"]))
            if target is None:
                target = _async_target(ctx, method)
            if r_hit < 0 and losses[-1] <= target:
                r_hit, sim_hit = len(losses), round(sim_elapsed, 4)
    if arm == "sync" and target is None:
        target = losses[0] * 0.55   # shared, reachable by both arms
        ctx.setdefault("targets", {})[method] = target
    if arm == "controller":
        # compact knob trajectory: (round, H_t) at each change point
        h_t_changes = [[r, h] for r, h in enumerate(h_t_log)
                       if r == 0 or h != h_t_log[r - 1]]
        rec = {
            "sim_time_total": round(sim_elapsed, 4),
            "round_ms_mean": round(float(np.mean(times[1:])), 3),
            "rounds": n_rounds,
            "final_loss": round(losses[-1], 4),
            "target_loss": round(target, 4),
            "rounds_to_target": r_hit,
            "sim_time_to_target": sim_hit,
            "b_eff": int(np.asarray(state["ctrl"]["b_eff"])),
            "h_t_trajectory": h_t_changes,
            "tune": tune,
        }
    else:
        r_hit = next((r + 1 for r, l in enumerate(losses) if l <= target), -1)
        rec = {
            "sim_round_time": round(env["sim_t"][arm], 4),
            "round_ms_mean": round(float(np.mean(times[1:])), 3),
            "rounds": n_rounds,
            "final_loss": round(losses[-1], 4),
            "target_loss": round(target, 4),
            "rounds_to_target": r_hit,
            "sim_time_to_target": round(r_hit * env["sim_t"][arm], 4)
            if r_hit > 0 else -1.0,
        }
    return [make_row(point.coords, rec)]


def _sum_async(doc):
    m = {(r["coords"]["method"], r["coords"]["arm"]): r["metrics"]
         for r in doc["rows"]}
    out = []
    for method in _uniq(doc, "method"):
        mname = method.replace("-", "_")
        s, a, c = (m.get((method, arm))
                   for arm in ("sync", "async", "controller"))
        if s and a and s["sim_time_to_target"] > 0 \
                and a["sim_time_to_target"] > 0:
            out.append((f"sim_speedup_{mname}",
                        round(s["sim_time_to_target"]
                              / a["sim_time_to_target"], 2)))
        if a:
            out.append((f"final_loss_async_{mname}", a["final_loss"]))
        if c:
            out.append((f"sim_time_adaptive_{mname}",
                        c["sim_time_to_target"]))
            statics = [m[(method, arm)]["sim_time_to_target"]
                       for arm in ("sync", "async")
                       if m.get((method, arm))
                       and m[(method, arm)]["sim_time_to_target"] > 0]
            if statics and c["sim_time_to_target"] > 0:
                out.append((f"sim_speedup_vs_best_static_{mname}",
                            round(min(statics)
                                  / c["sim_time_to_target"], 2)))
    return out


register(BenchDef(
    "async",
    MatrixConfig.make("async",
                      {"method": ENGINE_BENCH_METHODS,
                       "arm": ("sync", "async", "controller")},
                      fixed=dict(model="mlp_cls_reduced", clients=8,
                                 h_local=6, rounds=30)),
    _run_async, _sum_async,
    note="arm axis order matters: the sync arm sets the shared target_loss "
         "(55% of its round-0 loss) the async and controller arms race to; "
         "async/controller arms run buffer_rounds*rounds rounds (their "
         "simulated rounds are ~B x shorter). Partial --select runs without "
         "arm=sync read the committed sync row's target_loss instead."))


# --------------------------------------------------------------------------- #
# objectives — semi-supervised races on a label-scarce main-class split
# --------------------------------------------------------------------------- #


# fedavg FIRST: it is the anchor that sets the shared time-to-target loss
# the adaptive methods race to (same discipline as the async bench's sync arm)
OBJECTIVES_BENCH_METHODS = ("fedavg", "savic", "fedadagrad", "fedadam",
                            "fedyogi", "local-adam")
OBJECTIVES_BENCH_LABELED_FRAC = 0.1
# the async-bench local-adam step sizes overshoot on the semi-supervised
# loss surface (hits target in 2 rounds, then oscillates); halve them
OBJECTIVES_BENCH_OVERRIDES = {"local-adam": dict(eta_l=0.002, eta=0.01)}


def _objectives_env(ctx, fixed, seed):
    """Label-scarce environment shared by every method row: the fig1-style
    main-class split plus a stratified 10%-labeled mask (DESIGN.md §12)."""
    if "obj_env" in ctx:
        return ctx["obj_env"]
    from repro.core import objectives
    from repro.data import labeled_mask
    data, parts = _cls_data(ctx, seed)
    lab = labeled_mask(data.y, OBJECTIVES_BENCH_LABELED_FRAC, seed=seed)
    obj_spec = objectives.ObjectiveSpec(kind="consistency",
                                        unlabeled_weight=0.5,
                                        noise_sigma=0.1)
    ctx["obj_env"] = dict(data=data, parts=parts, labeled=lab,
                          obj_spec=obj_spec)
    _extra(ctx, labeled_frac=OBJECTIVES_BENCH_LABELED_FRAC,
           labeled_count=int(lab.sum()),
           objective=dict(kind=obj_spec.kind,
                          unlabeled_weight=obj_spec.unlabeled_weight,
                          noise_sigma=obj_spec.noise_sigma),
           backend=jax.default_backend())
    return ctx["obj_env"]


def _objectives_target(ctx):
    """FedAvg-anchored time-to-loss target: set by this run's fedavg row;
    partial (--select) runs fall back to the committed fedavg row."""
    t = ctx.get("obj_target")
    if t is not None:
        return t
    path = matrix.bench_paths("objectives")[0]
    if os.path.exists(path):
        doc = json.load(open(path))
        for r in doc.get("rows", []):
            if r["coords"].get("method") == "fedavg":
                return r["metrics"]["target_loss"]
    raise RuntimeError("no fedavg target_loss for the objectives bench: run "
                       "the fedavg row first (or keep method=fedavg in "
                       "--select)")


def _run_objectives(point, ctx):
    """One method racing on 10%-labeled heterogeneous clients: every client
    differentiates the consistency-regularized semi-supervised objective;
    the adaptive methods' scaling must beat FedAvg's rounds-to-target."""
    from repro.core import engine, objectives
    from repro.data import FederatedLoader

    f, seed = point.fixed, point.seed
    M, H = f["clients"], f["h_local"]
    env = _objectives_env(ctx, f, seed)
    method = point.coords["method"]
    kw = dict(ASYNC_BENCH_KW)
    kw.update(ASYNC_BENCH_OVERRIDES.get(method, {}))
    kw.update(OBJECTIVES_BENCH_OVERRIDES.get(method, {}))
    init, _, _ = _mlp(env["data"].x.shape[1], 10)

    def logits_fn(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    obj = objectives.classification_objective(env["obj_spec"], logits_fn)
    spec = engine.method_spec(method, **kw)
    step = jax.jit(engine.build_round_step(obj.base_loss, spec,
                                           objective=obj))
    state = engine.init_state(jax.random.PRNGKey(seed), init, spec, M)
    loader = FederatedLoader(env["data"].x, env["data"].y.astype(np.int32),
                             env["parts"][:M], batch_size=32, seed=seed,
                             labeled=env["labeled"])
    key = jax.random.PRNGKey(seed + 1)
    times, losses = [], []
    for _ in range(f["rounds"]):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        t0 = time.perf_counter()
        state, met = step(state, batch, k)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) * 1e3)
        losses.append(float(met["loss"]))
    if method == "fedavg":
        target = losses[0] * 0.55          # shared, reachable by every method
        ctx["obj_target"] = target
    else:
        target = _objectives_target(ctx)
    r_hit = next((r + 1 for r, l in enumerate(losses) if l <= target), -1)
    rec = {
        "round_ms_mean": round(float(np.mean(times[1:])), 3),
        "rounds": f["rounds"],
        "final_loss": round(losses[-1], 4),
        "target_loss": round(target, 4),
        "rounds_to_target": r_hit,
    }
    return [make_row(point.coords, rec)]


def _sum_objectives(doc):
    m = {r["coords"]["method"]: r["metrics"] for r in doc["rows"]}
    base = m.get("fedavg")
    out = []
    for method in _uniq(doc, "method"):
        mname = method.replace("-", "_")
        rm = m[method]
        out.append((f"final_loss_{mname}", rm["final_loss"]))
        if method != "fedavg" and base \
                and base["rounds_to_target"] > 0 and rm["rounds_to_target"] > 0:
            out.append((f"speedup_vs_fedavg_{mname}",
                        round(base["rounds_to_target"]
                              / rm["rounds_to_target"], 2)))
    return out


register(BenchDef(
    "objectives",
    MatrixConfig.make("objectives", {"method": OBJECTIVES_BENCH_METHODS},
                      fixed=dict(model="mlp_cls_reduced", clients=8,
                                 h_local=4, rounds=30)),
    _run_objectives, _sum_objectives,
    note="method axis order matters: the fedavg row sets the shared "
         "target_loss (55% of its round-0 loss) the adaptive methods race "
         "to on the 10%-labeled main-class split. Partial --select runs "
         "without method=fedavg read the committed fedavg row's target_loss "
         "instead."))


# --------------------------------------------------------------------------- #
# comm — analytic communication volume per round (arch)
# --------------------------------------------------------------------------- #


def _run_comm(point, ctx):
    from repro.configs import get_config
    arch = point.coords["arch"]
    cfg = get_config(arch)
    n = cfg.param_count()
    savic_bytes = 2 * 4 * n          # params + momentum all-reduce, fp32
    ddp_bytes = 4 * n * 8            # grad all-reduce every step, H=8
    if "dryrun_counted" not in ctx:
        ctx["dryrun_counted"] = True
        ddir = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                            "results", "dryrun")
        if os.path.isdir(ddir):
            import glob
            _extra(ctx, dryrun_records_single_pod=len(
                glob.glob(os.path.join(ddir, "*__16x16.json"))))
    return [make_row(point.coords,
                     {"params": n,
                      "savic_sync_GB_per_round": savic_bytes / 1e9,
                      "ddp_GB_per_round_H8": ddp_bytes / 1e9,
                      "saving_x": ddp_bytes / savic_bytes})]


def _sum_comm(doc):
    out = [("mean_saving_x",
            round(float(np.mean([r["metrics"]["saving_x"]
                                 for r in doc["rows"]])), 1))]
    n_rec = doc["config"].get("dryrun_records_single_pod")
    if n_rec is not None:
        out.append(("dryrun_records_single_pod", n_rec))
    return out


try:
    from repro.configs import ARCH_IDS as _ARCH_IDS
except Exception:                    # repro not importable (no PYTHONPATH=src)
    _ARCH_IDS = ()
if _ARCH_IDS:
    register(BenchDef(
        "comm",
        MatrixConfig.make("comm", {"arch": tuple(_ARCH_IDS)},
                          fixed=dict(h_local=8, dtype="fp32")),
        _run_comm, _sum_comm))


# --------------------------------------------------------------------------- #
# kernels — µs/call (interpret mode: correctness-path timing, NOT TPU perf)
# --------------------------------------------------------------------------- #


KERNELS_MICRO = ("scaled_update_1M", "flash_attn_512", "ssd_256")


def _run_kernels(point, ctx):
    from repro.kernels import ops, ref
    name = point.coords["kernel"]
    k = jax.random.key(0)
    if name == "scaled_update_1M":
        n = 1 << 20
        p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (n,))
                   for i in range(3))
        d = jax.random.uniform(jax.random.fold_in(k, 3), (n,), minval=0.1,
                               maxval=2.0)
        kw = dict(gamma=0.1, beta1=0.9, alpha=1e-3)
        us_k = _time(lambda: ops.scaled_update(p, m, g, d, **kw))
        us_r = _time(jax.jit(lambda p, m, g, d: ref.scaled_update_ref(
            p, m, g, d, **kw)), p, m, g, d)
    elif name == "flash_attn_512":
        B, S, H, D = 1, 512, 4, 64
        q, kk, v = (jax.random.normal(jax.random.fold_in(k, 10 + i),
                                      (B, S, H, D)) for i in range(3))
        us_k = _time(lambda: ops.flash_attention(q, kk, v, bq=128, bk=128))
        us_r = _time(jax.jit(lambda q, kk, v: ref.attention_ref(
            q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))), q, kk, v)
    else:  # ssd_256
        B, S, H, P, N = 1, 256, 4, 32, 16
        xh = jax.random.normal(jax.random.fold_in(k, 20), (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 21),
                                               (B, S, H)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 22), (H,)))
        Bm = jax.random.normal(jax.random.fold_in(k, 23), (B, S, H, N))
        Cm = jax.random.normal(jax.random.fold_in(k, 24), (B, S, H, N))
        us_k = _time(lambda: ops.ssd(xh, dt, A, Bm, Cm, chunk=64))
        us_r = _time(jax.jit(lambda *a: ref.ssd_ref(*a)), xh, dt, A, Bm, Cm)
    _extra(ctx, backend=jax.default_backend())
    return [make_row(point.coords, {"us_interpret": us_k, "us_ref_jit": us_r})]


def _sum_kernels(doc):
    return [(f"{r['coords']['kernel']}_us", round(r["metrics"]["us_interpret"]))
            for r in doc["rows"]]


register(BenchDef(
    "kernels",
    MatrixConfig.make("kernels", {"kernel": KERNELS_MICRO}),
    _run_kernels, _sum_kernels,
    note="interpret-mode Pallas timings vs jnp references on CPU: "
         "correctness-path timing, NOT TPU perf"))


# --------------------------------------------------------------------------- #
# kernels_fused — HBM bytes, fused flat-buffer step vs pre-PR per-leaf path
# --------------------------------------------------------------------------- #


FUSED_BENCH_M = 8
FUSED_BENCH_SHAPES = {"w1": (256, 128), "b1": (128,), "w2": (128, 10),
                      "b2": (10,)}
FUSED_BENCH_CASES = (
    # (tag, PrecondConfig kind, D advances in-loop?, external Hutchinson stat?)
    ("adam_local", "adam", True, False),
    ("rmsprop_local", "rmsprop", True, False),
    ("adagrad_local", "adagrad", True, False),
    ("oasis_local", "oasis", True, True),
    ("adam_global", "adam", False, False),
)


def _bytes_accessed(fn, *args):
    from repro.utils.hlo_cost import xla_cost_properties
    c = jax.jit(fn).lower(*args).compile()
    cost = xla_cost_properties(c)
    if "bytes accessed" not in cost:
        # fail loudly: a silent 0 would fabricate the reduction ratio
        raise RuntimeError("cost_analysis() has no 'bytes accessed' on "
                           f"this backend; keys: {sorted(cost)}")
    return float(cost["bytes accessed"]), c


def _fused_env(ctx):
    if "fused_env" in ctx:
        return ctx["fused_env"]
    from repro.utils.flatten import FlatLayout
    M = FUSED_BENCH_M
    k = jax.random.key(7)
    tree = lambda i0: {name: jax.random.normal(jax.random.fold_in(k, i0 + i),
                                               (M,) + shp)
                       for i, (name, shp) in
                       enumerate(FUSED_BENCH_SHAPES.items())}
    p_t, m_t, g_t = tree(0), tree(10), tree(20)
    d_t = jax.tree.map(lambda x: jnp.abs(x) + 0.1, tree(30))
    h_t = tree(40)
    layout = FlatLayout.for_tree(p_t, batch_dims=1)
    P, Mo, G = (layout.flatten(x, batch_dims=1) for x in (p_t, m_t, g_t))
    D = layout.flatten(d_t, batch_dims=1)
    Hs = layout.flatten(h_t, batch_dims=1)
    ctx["fused_env"] = dict(p_t=p_t, m_t=m_t, g_t=g_t, d_t=d_t, h_t=h_t,
                            P=P, Mo=Mo, G=G, D=D, Hs=Hs,
                            t_m=jnp.zeros((M,), jnp.int32))
    _extra(ctx, clients=M,
           leaves={nm: list(s) for nm, s in FUSED_BENCH_SHAPES.items()},
           n_total_per_client=layout.n_total,
           backend=jax.default_backend())
    return ctx["fused_env"]


def _run_fused(point, ctx):
    """One (tag, kind, local-D, hutchinson) case of the fused-step HBM
    comparison.  Both arms are measured with ``xla_cost_properties`` ("bytes
    accessed") on compiled programs, summed PER LAUNCH, because HBM
    round-trips happen at launch boundaries (full methodology in the bench
    note / DESIGN.md §7)."""
    from repro.core import preconditioner as PC
    from repro.kernels import ops, ref

    env = _fused_env(ctx)
    p_t, m_t, g_t, d_t, h_t = (env[n] for n in
                               ("p_t", "m_t", "g_t", "d_t", "h_t"))
    P, Mo, G, D, Hs, t_m = (env[n] for n in ("P", "Mo", "G", "D", "Hs", "t_m"))
    tag = point.coords["case"]
    _, kind, local, hutch = next(c for c in FUSED_BENCH_CASES
                                 if c[0] == tag)
    M = FUSED_BENCH_M
    pc = PC.PrecondConfig(kind=kind, alpha=1e-2)
    squared = pc.rule == "squared"

    # ---- pre-PR per-leaf kernel path ------------------------------------
    # Verbatim launch structure of the old fused path: an XLA momentum
    # pass, then PER LEAF (flattened to (M·n_leaf,)) a pad launch to the
    # fixed BLOCK = 8·128·16 (the old kernel padded every ragged leaf all
    # the way up — custom-call operands materialize, so the pad copies
    # are real HBM traffic), the kernel launch (zeros in the momentum
    # slot, beta1 pre-applied, dead m output — see ops.scaled_update_tree)
    # and the [:n] slice launch back.
    OLD_BLOCK = 8 * 128 * 16

    def mom_pass(m, g):
        return jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)

    by_mom, c_mom = _bytes_accessed(mom_pass, m_t, g_t)
    by_leaf = 0.0
    c_leaf = []
    for name in FUSED_BENCH_SHAPES:
        n_leaf = int(np.prod(FUSED_BENCH_SHAPES[name])) * M
        npad = (OLD_BLOCK - n_leaf % OLD_BLOCK) % OLD_BLOCK
        flat = lambda x: x.reshape(-1)
        args = (flat(p_t[name]), jnp.zeros((n_leaf,), jnp.float32),
                flat(m_t[name]), flat(d_t[name]))
        launches = []
        if npad:
            def pad_fn(p, z, m, d, _npad=npad):
                pad = lambda x, v: jnp.concatenate(
                    [x, jnp.full((_npad,), v, x.dtype)])
                return pad(p, 0), pad(z, 0), pad(m, 0), pad(d, 1.0)
            b, c = _bytes_accessed(pad_fn, *args)
            by_leaf += b
            launches.append((c, args))
            args = tuple(np.asarray(a) for a in c(*args))
            args = tuple(jnp.asarray(a) for a in args)

        def leaf_fn(p, z, m, d):
            return ref.scaled_update_ref(p, z, m, d, gamma=0.01,
                                         beta1=0.0, alpha=1e-2,
                                         squared=squared)
        b, c = _bytes_accessed(leaf_fn, *args)
        by_leaf += b
        launches.append((c, args))
        if npad:
            outs = tuple(jnp.asarray(np.asarray(o)) for o in c(*args))

            def slice_fn(po, mo, _n=n_leaf):
                return po[:_n], mo[:_n]
            b, c = _bytes_accessed(slice_fn, *outs)
            by_leaf += b
            launches.append((c, outs))
        c_leaf.append(launches)
    by_dpass = 0.0
    c_dpass = None
    if local:
        def d_pass(d, g, h, t):
            b = PC.beta_t(pc, t)
            stat = h if hutch else jax.tree.map(lambda x: x ** 2, g)
            if kind == "adagrad":
                return jax.tree.map(lambda dd, hh: dd + hh, d, stat)
            return jax.tree.map(lambda dd, hh: b * dd + (1.0 - b) * hh,
                                d, stat)
        by_dpass, c_dpass = _bytes_accessed(d_pass, d_t, g_t, h_t,
                                            jnp.int32(0))
    bytes_prepr = by_mom + by_leaf + by_dpass

    # ---- fused flat-buffer kernel contract (one launch) ----------------
    kw = dict(gamma=0.01, beta1=0.9, alpha=1e-2, beta2=pc.beta2,
              kind=kind, clip="max", schedule=pc.schedule, update_d=local)
    hstat = Hs if (local and hutch) else None
    d_arg = D if local else D[0]
    bytes_fused, c_fused = _bytes_accessed(
        lambda *a: ref.fused_step_ref(*a, **kw), P, Mo, G, d_arg, hstat,
        t_m, None)

    ratio = bytes_prepr / max(bytes_fused, 1.0)
    us_prepr = _time(lambda: [c_mom(m_t, g_t)]
                     + [c(*a) for launches in c_leaf
                        for c, a in launches]
                     + ([c_dpass(d_t, g_t, h_t, jnp.int32(0))]
                        if c_dpass else []))
    us_oracle = _time(lambda: c_fused(P, Mo, G, d_arg, hstat, t_m, None))
    us_interp = _time(lambda: ops.fused_local_step(
        P, Mo, G, d_arg, hstat, t_m, None, **kw))
    rec = {
        "bytes_prepr_path": bytes_prepr,
        "bytes_fused": bytes_fused,
        "hbm_reduction_x": round(ratio, 2),
        "launches_prepr": 1 + sum(len(l) for l in c_leaf) + (1 if local
                                                             else 0),
        "launches_fused": 1,
        "us_prepr_oracle": round(us_prepr, 1),
        "us_fused_oracle": round(us_oracle, 1),
        "us_fused_interpret": round(us_interp, 1),
    }
    return [make_row(point.coords, rec)]


def _sum_fused(doc):
    return [(f"hbm_reduction_x_{r['coords']['case']}",
             r["metrics"]["hbm_reduction_x"]) for r in doc["rows"]]


register(BenchDef(
    "kernels_fused",
    MatrixConfig.make("kernels_fused",
                      {"case": tuple(c[0] for c in FUSED_BENCH_CASES)}),
    _run_fused, _sum_fused,
    note="xla_cost_properties('bytes accessed'), summed per launch (HBM "
         "round-trips happen at launch boundaries). pre-PR arm = the "
         "verbatim old launch structure: momentum pass + per-leaf "
         "pad-to-BLOCK / kernel-contract / slice launches + separate D-EMA "
         "pass. fused arm = the fused_step_flat kernel's jnp-oracle "
         "contract in one jit (kernel pinned to it in "
         "tests/test_fused_step.py); interpret-mode timing is "
         "correctness-path, not TPU perf"))


# --------------------------------------------------------------------------- #
# kernels_sharded — shard-mapped fused-step collective bytes (plan)
# --------------------------------------------------------------------------- #


SHARDED_PLANS = ("model", "fsdp", "mixed")


def _run_kernels_sharded(point, ctx):
    """Per-step collective bytes of the shard-mapped fused local step
    (DESIGN.md §7) vs the naive global flat view and the tree baseline.
    Runs benchmarks/sharded_collectives.py once in a subprocess (the worker
    forces 8 host devices; this process keeps 1); per-plan rows come from
    that one record."""
    if "sharded_rec" not in ctx:
        import subprocess
        worker = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "sharded_collectives.py")
        r = subprocess.run([sys.executable, worker], capture_output=True,
                           text=True, timeout=560)
        if r.returncode != 0:
            raise RuntimeError(
                f"sharded_collectives worker failed:\n{r.stderr}")
        ctx["sharded_rec"] = json.loads(r.stdout.strip().splitlines()[-1])
        rec = ctx["sharded_rec"]
        _extra(ctx, n_devices=rec["n_devices"], clients=rec["clients"],
               leaves=rec["leaves"])
    pr = ctx["sharded_rec"]["plans"][point.coords["plan"]]
    return [make_row(point.coords,
                     {"n_shards": pr["n_shards"],
                      "collective_bytes_sharded":
                          pr["sharded"]["collective_bytes"],
                      "collective_bytes_naive":
                          pr["naive"]["collective_bytes"],
                      "collective_bytes_tree":
                          pr["tree"]["collective_bytes"]})]


def _sum_sharded(doc):
    out = []
    for r in doc["rows"]:
        plan = r["coords"]["plan"]
        out.append((f"sharded_step_collective_bytes_{plan}",
                    r["metrics"]["collective_bytes_sharded"]))
        out.append((f"naive_flat_collective_bytes_{plan}",
                    r["metrics"]["collective_bytes_naive"]))
    return out


register(BenchDef(
    "kernels_sharded",
    MatrixConfig.make("kernels_sharded", {"plan": SHARDED_PLANS}),
    _run_kernels_sharded, _sum_sharded,
    note="ONE local step of the flat pipeline (flatten -> fused kernel -> "
         "unflatten) lowered per plan on a (2,4)=('data','model') "
         "8-host-device mesh; collective bytes parsed from optimized HLO "
         "(utils/hlo.collective_bytes), 'bytes accessed' from "
         "xla_cost_properties. sharded arm runs inside shard_map (must be "
         "0 collective bytes); naive arm is the single global flat view "
         "the pre-PR launch gate guarded against; tree arm is the old "
         "fallback baseline."))


# --------------------------------------------------------------------------- #
# serve — production decode path (arch × mode)
# --------------------------------------------------------------------------- #


SERVE_BENCH_ARCHS = ("qwen2-0.5b", "mamba2-1.3b")
SERVE_BENCH_MODES = ("reuse", "replay", "continuous", "static")
SERVE_BENCH_TRACE = dict(slots=4, n_requests=10, arrival_rate=0.6)


def _serve_arch(ctx, arch, fixed, seed):
    """All four serve modes for one arch, computed once per run (reuse and
    replay must decode the same greedy ids; continuous and static share one
    Poisson arrival trace)."""
    cache = ctx.setdefault("serve_recs", {})
    if arch in cache:
        return cache[arch]
    from repro.launch.serve import (serve, serve_continuous, serve_replay,
                                    serve_static)
    kw = dict(reduced=True, batch=fixed["batch"],
              prompt_len=fixed["prompt_len"], gen_len=fixed["gen_len"],
              seed=seed, warmup=True, verbose=False)
    tkw = dict(reduced=True, prompt_len=8, gen_len=fixed["gen_len"],
               seed=seed, warmup=True, verbose=False, **SERVE_BENCH_TRACE)
    reuse = serve(arch, **kw)
    replay = serve_replay(arch, **kw)
    assert np.array_equal(reuse.tokens, replay.tokens)   # same greedy ids
    cont = serve_continuous(arch, **tkw)
    stat = serve_static(arch, **tkw)
    rec = {}
    for mode, r in (("reuse", reuse), ("replay", replay)):
        rec[mode] = dict(r.timings)
        rec[mode]["p50_token_s"] = float(np.percentile(r.per_token_s, 50))
        rec[mode]["p99_token_s"] = float(np.percentile(r.per_token_s, 99))
    for r in (cont, stat):
        m = r.metrics
        rec[m["mode"]] = {k: v for k, v in m.items()
                          if k not in ("mode", "jit_cache_sizes")}
        rec[m["mode"]]["jit_cache_step"] = m["jit_cache_sizes"]["step"]
    cache[arch] = rec
    _extra(ctx,
           trace={**SERVE_BENCH_TRACE, "prompt_len": 8,
                  "gen_len": fixed["gen_len"],
                  "clock": "decode-step units; prefill=0 steps"},
           warmup=True, greedy=True, backend=jax.default_backend())
    return rec


def _run_serve(point, ctx):
    recs = _serve_arch(ctx, point.coords["arch"], point.fixed, point.seed)
    return [make_row(point.coords, recs[point.coords["mode"]])]


def _sum_serve(doc):
    m = {(r["coords"]["arch"], r["coords"]["mode"]): r["metrics"]
         for r in doc["rows"]}
    out = []
    for arch in _uniq(doc, "arch"):
        a = arch.replace("-", "_").replace(".", "_")
        reuse, replay = m.get((arch, "reuse")), m.get((arch, "replay"))
        cont, stat = m.get((arch, "continuous")), m.get((arch, "static"))
        if reuse and replay:
            out.append((f"ttft_speedup_reuse_{a}",
                        round(replay["ttft_s"]
                              / max(reuse["ttft_s"], 1e-9), 2)))
            out.append((f"decode_tok_per_s_{a}",
                        round(reuse["tok_per_s"], 1)))
        if cont and stat:
            out.append((f"trace_throughput_x_continuous_{a}",
                        round(cont["tok_per_step"]
                              / max(stat["tok_per_step"], 1e-9), 2)))
    return out


register(BenchDef(
    "serve",
    MatrixConfig.make("serve",
                      {"arch": SERVE_BENCH_ARCHS, "mode": SERVE_BENCH_MODES},
                      fixed=dict(reduced=True, batch=4, prompt_len=32,
                                 gen_len=16)),
    _run_serve, _sum_serve,
    note="all arms warmup=True (compile excluded); continuous vs static "
         "compare on the same Poisson trace in decode-step clock units — "
         "on CPU-reduced configs continuous pays more prefill dispatches, "
         "so its wall tok/s can trail static even when its trace "
         "throughput wins"))


# --------------------------------------------------------------------------- #
# train_lm — federated causal-LM rounds through the production driver
# --------------------------------------------------------------------------- #


# per-method step sizes for the qwen2-0.5b-reduced Markov-stream task (tuned
# for a visible loss trend in ~10 rounds on CPU; pure-SGD clients need a much
# larger γ than adam-scaled ones on a token LM)
TRAIN_LM_OVERRIDES = {
    "savic": ["--gamma", "0.05"],
    "fedavg": ["--gamma", "6.0"],
    "fedadagrad": ["--gamma", "1.0", "--server-eta", "0.5"],
    "fedadam": ["--gamma", "1.0", "--server-eta", "0.5"],
    "fedyogi": ["--gamma", "1.0", "--server-eta", "0.5"],
    "local-adam": ["--gamma", "0.05", "--server-eta", "0.05"],
}

TRAIN_LM_ARCH = "qwen2-0.5b"


def _train_lm_projection(arch):
    """Full-shape tokens/sec/device from the dry-run cost model: roofline
    bound (compute/memory/collective, benchmarks/roofline.py terms) over the
    trip-count-corrected per-device numerators of each train artifact."""
    import glob

    from benchmarks.roofline import terms
    from repro.configs import get_shape

    ddir = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        "results", "dryrun")
    proj = []
    for f in sorted(glob.glob(os.path.join(ddir, f"{arch}__*.json"))):
        rec = json.load(open(f))
        if rec.get("kind") != "train" or not rec.get("ok"):
            continue
        t = terms(rec)
        bound_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        s = get_shape(rec["shape"])
        tokens = s.global_batch * s.seq_len * rec.get("h_local", 8)
        proj.append({
            "shape": rec["shape"], "mesh": rec["mesh"], "mode": rec["mode"],
            "tag": rec.get("tag", ""), "n_devices": rec["n_devices"],
            "tokens_per_round": tokens,
            "round_s_roofline": round(bound_s, 6),
            "dominant_term": t["dominant"],
            # deterministic cost-model outputs — named so diff classifies
            # them as comparable, unlike the wall-derived tokens_per_s_*
            "tok_s_dev_roofline": round(
                tokens / rec["n_devices"] / bound_s, 1),
            # compute-term bound for context: the measured-HLO memory term
            # dominates this artifact by ~500×, so the roofline number above
            # is the conservative end of the projection
            "tok_s_dev_compute_bound": round(
                tokens / rec["n_devices"] / t["compute_s"], 1),
            "model_flops_utilization": round(t["roofline_frac"], 4),
        })
    return proj


def _run_train_lm(point, ctx):
    from repro.launch import train as train_mod
    f, seed = point.fixed, point.seed
    method = point.coords["method"]
    rounds, H, M = f["rounds"], f["h_local"], f["clients"]
    b, seq = f["batch"], f["seq"]
    tokens_round = M * H * b * seq
    argv = ["--arch", TRAIN_LM_ARCH, "--reduced", "--method", method,
            "--rounds", str(rounds), "--h-local", str(H),
            "--clients", str(M), "--batch", str(b), "--seq", str(seq),
            "--seed", str(seed)] + TRAIN_LM_OVERRIDES[method]
    log = train_mod.main(argv)
    losses = [l["loss"] for l in log]
    walls = [l["wall_s"] for l in log]
    steady = walls[1:] or walls           # round 0 pays the jit compile
    tps = tokens_round / float(np.mean(steady))
    half = len(losses) // 2
    n_dev = jax.device_count()
    rec = {
        "loss_first": round(losses[0], 4),
        "loss_last": round(losses[-1], 4),
        "round_wall_s_mean": round(float(np.mean(steady)), 4),
        "tokens_per_s": round(tps, 1),
        "tokens_per_s_per_device": round(tps / n_dev, 1),
        "sim_time_total": log[-1]["sim_time"],
    }
    info = {
        "loss_curve": [round(l, 4) for l in losses],
        "loss_decreasing_trend": bool(
            losses[-1] < losses[0]
            and np.mean(losses[half:]) < np.mean(losses[:half])),
    }
    _extra(ctx, arch=f"{TRAIN_LM_ARCH}-reduced",
           tokens_per_round=tokens_round, n_devices=n_dev,
           backend=jax.default_backend())
    return [make_row(point.coords, rec, info=info)]


def _post_train_lm(rows, ctx):
    out = []
    for p in _train_lm_projection(TRAIN_LM_ARCH):
        out.append(make_row(
            {"method": f"projection:{p['shape']}@{p['mesh']}"},
            {k: p[k] for k in ("n_devices", "tokens_per_round",
                               "round_s_roofline", "tok_s_dev_roofline",
                               "tok_s_dev_compute_bound",
                               "model_flops_utilization")},
            info={k: p[k] for k in ("shape", "mesh", "mode", "tag",
                                    "dominant_term")}))
    return out


def _sum_train_lm(doc):
    out = []
    for r in doc["rows"]:
        method = r["coords"]["method"]
        m = r["metrics"]
        if method.startswith("projection:"):
            shape = (r.get("info") or {}).get(
                "shape", method.split(":", 1)[1].split("@")[0])
            tsd = m.get("tok_s_dev_roofline",
                        m.get("tokens_per_s_per_device"))
            if tsd is not None:
                out.append((f"tok_s_dev_proj_{shape}", tsd))
            continue
        mname = method.replace("-", "_")
        if "loss_first" in m and "loss_last" in m:
            out.append((f"loss_drop_{mname}",
                        round(m["loss_first"] - m["loss_last"], 4)))
        if "tokens_per_s_per_device" in m:
            out.append((f"tok_s_dev_{mname}", m["tokens_per_s_per_device"]))
    return out


register(BenchDef(
    "train_lm",
    MatrixConfig.make("train_lm", {"method": ENGINE_BENCH_METHODS},
                      fixed=dict(clients=4, h_local=8, batch=4, seq=64,
                                 rounds=10)),
    _run_train_lm, _sum_train_lm, post=_post_train_lm,
    note="projection rows (method='projection:<shape>@<mesh>') come from "
         "the dry-run cost model, not a run — their tok_s_dev_* metrics "
         "are deterministic roofline outputs"))


# --------------------------------------------------------------------------- #
# legacy alias CLI — the old subcommands as thin aliases over matrix configs
# --------------------------------------------------------------------------- #


ALIASES = {
    "fig1": ("fig1",),
    "thm1": ("thm1",),
    "thm2": ("thm2",),
    "sec52": ("sec52",),
    "engine": ("engine",),
    "compression": ("compression",),
    "async": ("async",),
    "controller": ("async",),     # controller rows live on the arm axis now
    "comm": ("comm",),
    "kernels": ("kernels", "kernels_fused", "kernels_sharded"),
    "serve": ("serve",),
    "train_lm": ("train_lm",),
}


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="Run benches by their legacy subcommand names (thin "
                    "aliases over benchmarks.matrix configs); prints the "
                    "benchmark,metric,value trajectory lines")
    ap.add_argument("--only", default="",
                    help="comma-separated legacy names (default: all)")
    args = ap.parse_args(argv)
    names = [n for n in ALIASES if not args.only or n in args.only.split(",")]
    todo = []
    for alias in names:
        for bench in ALIASES[alias]:
            if bench in todo or bench not in matrix._registry():
                continue
            todo.append(bench)
    print("benchmark,metric,value")
    for bench in todo:
        t0 = time.time()
        doc = matrix.run_bench(bench)
        for metric, value in matrix.summarize(doc):
            print(f"{bench},{metric},{value}", flush=True)
        print(f"{bench},seconds,{time.time() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
