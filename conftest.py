"""Repo-root pytest conftest: make the `benchmarks` package and `repro`
(src layout) importable without relying on the caller's PYTHONPATH — the
bench-harness suites import benchmarks.matrix directly."""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
