"""The LM training contract (DESIGN.md §9): round-addressable data, resume
bitwise-determinism, per-round modal batches, and the mesh launch path."""
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.data import LMRoundLoader, TokenStream

BASE = ["--arch", "qwen2-0.5b", "--reduced", "--h-local", "2",
        "--clients", "2", "--batch", "2", "--seq", "32"]

# wall-clock measurements are the only log fields exempt from bitwise
# reproducibility (DESIGN.md §9)
MEASURED = ("wall_s", "tokens_per_s")


def _det(rec):
    return {k: v for k, v in rec.items() if k not in MEASURED}


# --------------------------------------------------------------------------- #
# round-addressable vectorized data
# --------------------------------------------------------------------------- #


def test_token_stream_batch_at_stateless():
    ts = TokenStream(64, seed=3)
    t5, l5 = ts.batch_at(5, 4, 16)
    ts.batch(4, 16)                      # stateful draws don't perturb it
    t5b, l5b = ts.batch_at(5, 4, 16)
    np.testing.assert_array_equal(t5, t5b)
    np.testing.assert_array_equal(l5, l5b)
    # a fresh stream with the same seed reproduces the same index
    t5c, _ = TokenStream(64, seed=3).batch_at(5, 4, 16)
    np.testing.assert_array_equal(t5, t5c)
    # different index / different seed -> different data
    assert not np.array_equal(t5, ts.batch_at(6, 4, 16)[0])
    assert not np.array_equal(t5, TokenStream(64, seed=4).batch_at(5, 4, 16)[0])
    # label alignment + vocab bounds survive the vectorized walk
    assert (t5[:, 1:] == l5[:, :-1]).all()
    assert t5.min() >= 0 and t5.max() < 64 and t5.dtype == np.int32


def test_lm_round_loader_round_addressable():
    s1, s2 = TokenStream(64, seed=3), TokenStream(64, seed=3)
    l1, l2 = LMRoundLoader(s1, 3, 2), LMRoundLoader(s2, 3, 2)
    b5 = l1.round_batch(5, 2, 16)
    assert b5["tokens"].shape == (3, 2, 2, 16)
    assert (b5["tokens"][..., 1:] == b5["labels"][..., :-1]).all()
    # pure function of (seed, r): call order / instance is irrelevant
    l2.round_batch(0, 2, 16)
    np.testing.assert_array_equal(b5["tokens"],
                                  l2.round_batch(5, 2, 16)["tokens"])
    assert not np.array_equal(b5["tokens"],
                              l1.round_batch(6, 2, 16)["tokens"])
    # clients draw distinct data within a round
    assert not np.array_equal(b5["tokens"][0], b5["tokens"][1])


# --------------------------------------------------------------------------- #
# modal (audio/vlm) batches advance per round
# --------------------------------------------------------------------------- #


def test_modal_batches_differ_across_rounds():
    from repro.launch.train import _wrap_modal
    cfg = get_config("musicgen-large", reduced=True)
    loader = LMRoundLoader(TokenStream(cfg.vocab_size, seed=0), 2, 2)
    b0 = _wrap_modal(cfg, loader.round_batch(0, 2, 16), 0, 0)
    b1 = _wrap_modal(cfg, loader.round_batch(1, 2, 16), 0, 1)
    assert b0["embeds"].shape == (2, 2, 2, 16, cfg.d_model)
    assert not np.array_equal(b0["embeds"], b1["embeds"])
    assert not np.array_equal(b0["labels"], b1["labels"])
    # same round reproduces bitwise (resume invariant)
    b0b = _wrap_modal(cfg, loader.round_batch(0, 2, 16), 0, 0)
    np.testing.assert_array_equal(b0["embeds"], b0b["embeds"])


def test_modal_vlm_batch_struct_and_seeding():
    from repro.launch.train import _wrap_modal
    cfg = get_config("internvl2-1b", reduced=True)
    P = cfg.frontend_tokens
    loader = LMRoundLoader(TokenStream(cfg.vocab_size, seed=0), 2, 2)
    b0 = _wrap_modal(cfg, loader.round_batch(0, 2, 32), 0, 0)
    b1 = _wrap_modal(cfg, loader.round_batch(1, 2, 32), 0, 1)
    # batch_struct contract: P patches + (S-P) text tokens
    assert b0["patches"].shape == (2, 2, 2, P, cfg.d_model)
    assert b0["tokens"].shape == (2, 2, 2, 32 - P)
    assert not np.array_equal(b0["patches"], b1["patches"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# --------------------------------------------------------------------------- #
# resume bitwise-determinism through the driver
# --------------------------------------------------------------------------- #


def test_resume_bitwise_loss_state_log(tmp_path):
    """train(6) == train(3) + restore + train(3), bitwise: every
    deterministic log field, and the final checkpoint's raw bytes."""
    from repro.launch import train as train_mod
    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    log_a = train_mod.main(BASE + ["--rounds", "6", "--ckpt", da,
                                   "--ckpt-every", "3"])
    train_mod.main(BASE + ["--rounds", "3", "--ckpt", db,
                           "--ckpt-every", "3"])
    log_b = train_mod.main(BASE + ["--rounds", "6", "--ckpt", db,
                                   "--ckpt-every", "3"])
    assert [l["round"] for l in log_b] == [3, 4, 5]   # only remaining rounds
    for ra, rb in zip(log_a[3:], log_b):
        assert _det(ra) == _det(rb)                   # loss/drift/... bitwise
    # final states bitwise equal: compare the checkpoint files themselves
    for fname in ("data.bin", "state.msgpack"):
        pa = os.path.join(da, "step_00000006", fname)
        pb = os.path.join(db, "step_00000006", fname)
        with open(pa, "rb") as fa, open(pb, "rb") as fb:
            assert fa.read() == fb.read(), fname


@pytest.mark.slow
def test_resume_bitwise_10_rounds(tmp_path):
    """The contract at the issue's full length: train(10) == train(5)+train(5)."""
    from repro.launch import train as train_mod
    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    log_a = train_mod.main(BASE + ["--rounds", "10", "--ckpt", da,
                                   "--ckpt-every", "5"])
    train_mod.main(BASE + ["--rounds", "5", "--ckpt", db,
                           "--ckpt-every", "5"])
    log_b = train_mod.main(BASE + ["--rounds", "10", "--ckpt", db,
                                   "--ckpt-every", "5"])
    assert [l["round"] for l in log_b] == list(range(5, 10))
    for ra, rb in zip(log_a[5:], log_b):
        assert _det(ra) == _det(rb)
    for fname in ("data.bin", "state.msgpack"):
        with open(os.path.join(da, "step_00000010", fname), "rb") as fa, \
                open(os.path.join(db, "step_00000010", fname), "rb") as fb:
            assert fa.read() == fb.read(), fname


# --------------------------------------------------------------------------- #
# mesh launch path (steps.build_train_step end-to-end)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_mesh_path_end_to_end_with_resume(tmp_path):
    """--mesh routes through steps.build_train_step (shardings + donation);
    the plan fixes M, checkpoints interoperate with the same driver loop."""
    from repro.launch import train as train_mod
    argv = ["--arch", "qwen2-0.5b", "--reduced", "--mesh", "debug",
            "--mesh-shape", "1x1", "--method", "local-adam",
            "--use-fused-kernel", "--h-local", "2", "--batch", "2",
            "--seq", "32", "--ckpt", str(tmp_path), "--ckpt-every", "1"]
    log = train_mod.main(argv + ["--rounds", "2"])
    assert len(log) == 2
    assert all(np.isfinite(l["loss"]) for l in log)
    assert all("step_norm" in l for l in log)         # adaptive server threads
    # resume runs only the remaining round
    log2 = train_mod.main(argv + ["--rounds", "3"])
    assert [l["round"] for l in log2] == [2]


@pytest.mark.slow
def test_modal_driver_end_to_end():
    """Audio family through the driver: per-round modal batches reach the
    engine (loss varies across rounds — a frozen batch kept it fixed)."""
    from repro.launch import train as train_mod
    log = train_mod.main(["--arch", "musicgen-large", "--reduced",
                          "--rounds", "2", "--h-local", "2", "--clients", "2",
                          "--batch", "2", "--seq", "16"])
    assert len(log) == 2
    assert all(np.isfinite(l["loss"]) for l in log)
