"""Client objectives & personalization — the differential test harness.

Locks down DESIGN.md §12:
  * differential pinning: the supervised / no-personalization configuration
    is bit-identical to the pre-PR engine snapshot
    (tests/_reference_engine.py) for all six METHODS — both objective=None
    and an explicit identity ClientObjective;
  * objective math: masked CE 0/0-safety, consistency at σ=0 and
    pseudo-label at an unreachable threshold both collapse to the
    supervised term, the unlabeled term engages when gated open;
  * personalization never crosses the wire: a poison value planted in a
    personal leaf stays per-client forever, server state carries no
    personal leaves, ``bytes_on_wire`` accounting drops exactly the
    personal subset, and checkpoints round-trip the stripped state;
  * the fused Pallas client loop stays engaged (bit-equal to the tree
    path) under a non-identity objective;
  * loader plumbing: the ``labeled`` leaf appears only when requested and
    is round-addressable;
  * launch threading: build_train_step records the objective meta, aligns
    the stripped sharding specs, and rejects personal × global-D builds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_engine as ref_engine
from repro.core import engine, objectives
from repro.data import (ClassificationData, FederatedLoader, LMRoundLoader,
                        QuadraticLoader, QuadraticProblem, TokenStream,
                        labeled_mask, main_class_partition)


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, build_round_step, init_state, spec, rounds=4, H=3, seed=0,
         n_clients=4, objective=None, init_fn=None):
    loss = _quad_loss(problem)
    kw = {} if objective is None and build_round_step \
        is ref_engine.build_round_step else {"objective": objective}
    step = jax.jit(build_round_step(loss, spec, **kw)
                   if kw else build_round_step(loss, spec))
    init_fn = init_fn or (lambda k: {"x": jnp.zeros(24)})
    state = init_state(jax.random.PRNGKey(0), init_fn, spec, n_clients)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
    return state, met


MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)


# --------------------------------------------------------------------------- #
# differential: supervised / no-personalization == pre-PR engine, bitwise
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("method", engine.METHODS)
def test_supervised_bit_identical_to_prepr_engine(problem, method):
    """objective=None + personal=() emits the exact pre-objectives program:
    trajectories agree BITWISE with the verbatim engine snapshot."""
    spec_new = engine.method_spec(method, **MS_KW)
    assert spec_new.sync.personal == ()
    spec_ref = ref_engine.method_spec(method, **MS_KW)
    st_new, met_new = _run(problem, engine.build_round_step,
                           engine.init_state, spec_new)
    st_ref, met_ref = _run(problem, ref_engine.build_round_step,
                           ref_engine.init_state, spec_ref)
    np.testing.assert_array_equal(np.asarray(st_new["params"]["x"]),
                                  np.asarray(st_ref["params"]["x"]))
    np.testing.assert_array_equal(np.asarray(st_new["mom"]["x"]),
                                  np.asarray(st_ref["mom"]["x"]))
    if "server" in st_ref:
        np.testing.assert_array_equal(np.asarray(st_new["server"]["v"]["x"]),
                                      np.asarray(st_ref["server"]["v"]["x"]))
    assert float(met_new["loss"]) == float(met_ref["loss"])


def _quad_objective(problem, kind="consistency", noise=0.0):
    """A ClientObjective over quadratic micros (loss gets an optional keyed
    perturbation so the trajectory provably consumes the objective key)."""
    base = _quad_loss(problem)

    def loss(params, micro, key):
        eps = noise * jax.random.normal(key, ()) if noise else 0.0
        return base(params, micro) * (1.0 + eps)

    return objectives.ClientObjective(
        spec=objectives.ObjectiveSpec(kind=kind), loss=loss, base_loss=base)


def test_identity_objective_bit_identical(problem):
    """An explicit supervised ClientObjective short-circuits to the unkeyed
    grad path — bitwise equal to objective=None."""
    spec = engine.method_spec("savic", **MS_KW)
    ident = objectives.ClientObjective(
        spec=objectives.ObjectiveSpec(kind="supervised"),
        loss=lambda p, mc, k: _quad_loss(problem)(p, mc),
        base_loss=_quad_loss(problem))
    st_a, _ = _run(problem, engine.build_round_step, engine.init_state, spec)
    st_b, _ = _run(problem, engine.build_round_step, engine.init_state, spec,
                   objective=ident)
    np.testing.assert_array_equal(np.asarray(st_a["params"]["x"]),
                                  np.asarray(st_b["params"]["x"]))


def test_nonidentity_objective_changes_trajectory(problem):
    spec = engine.method_spec("savic", **MS_KW)
    st_a, _ = _run(problem, engine.build_round_step, engine.init_state, spec)
    st_b, _ = _run(problem, engine.build_round_step, engine.init_state, spec,
                   objective=_quad_objective(problem, noise=0.3))
    assert not np.array_equal(np.asarray(st_a["params"]["x"]),
                              np.asarray(st_b["params"]["x"]))


def test_fused_path_bit_identical_under_objective(problem):
    """The flat-buffer fused loop is grad-source agnostic: with a keyed
    objective it matches the tree path bit-for-bit (same per-step keys)."""
    obj = _quad_objective(problem, noise=0.3)
    mk = lambda fused: engine.method_spec("savic", **MS_KW,
                                          use_fused_kernel=fused)
    st_t, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False), objective=obj)
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True), objective=obj)
    np.testing.assert_array_equal(np.asarray(st_t["params"]["x"]),
                                  np.asarray(st_f["params"]["x"]))


# --------------------------------------------------------------------------- #
# objective math
# --------------------------------------------------------------------------- #


def _toy_logits_fn():
    def logits_fn(params, x):
        return x @ params["w"]
    return logits_fn


def _toy_micro(key, b=8, d=4, c=3, labeled=None):
    kx, ky = jax.random.split(key)
    micro = {"x": jax.random.normal(kx, (b, d)),
             "y": jax.random.randint(ky, (b,), 0, c)}
    if labeled is not None:
        micro["labeled"] = jnp.asarray(labeled, jnp.float32)
    return micro


def test_masked_ce_empty_mask_is_zero():
    logits = jnp.array([[2.0, -1.0], [0.5, 0.5]])
    y = jnp.array([0, 1])
    assert float(objectives._masked_ce(logits, y, jnp.zeros(2))) == 0.0
    full = objectives._masked_ce(logits, y, jnp.ones(2))
    assert np.isfinite(float(full)) and float(full) > 0.0


def test_consistency_sigma_zero_collapses_to_supervised():
    """σ=0 makes the perturbed view the clean view — the unlabeled term
    vanishes and only the labeled-subset CE remains."""
    spec = objectives.ObjectiveSpec(kind="consistency", noise_sigma=0.0,
                                    unlabeled_weight=5.0)
    obj = objectives.classification_objective(spec, _toy_logits_fn())
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3))}
    lab = [1, 1, 0, 0, 1, 0, 0, 0]
    micro = _toy_micro(jax.random.PRNGKey(1), labeled=lab)
    got = obj.loss(params, micro, jax.random.PRNGKey(2))
    want = objectives._masked_ce(_toy_logits_fn()(params, micro["x"]),
                                 micro["y"], micro["labeled"])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_consistency_noise_engages_unlabeled_term():
    spec = objectives.ObjectiveSpec(kind="consistency", noise_sigma=0.5,
                                    unlabeled_weight=5.0)
    obj = objectives.classification_objective(spec, _toy_logits_fn())
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3))}
    micro = _toy_micro(jax.random.PRNGKey(1), labeled=[1, 1, 0, 0, 1, 0, 0, 0])
    got = float(obj.loss(params, micro, jax.random.PRNGKey(2)))
    sup = float(objectives._masked_ce(_toy_logits_fn()(params, micro["x"]),
                                      micro["y"], micro["labeled"]))
    assert got > sup


def test_pseudo_label_gate():
    """An unreachable confidence threshold gates the unlabeled term shut
    (loss == supervised); a near-zero one opens it on unlabeled examples."""
    fn = _toy_logits_fn()
    params = {"w": 3.0 * jax.random.normal(jax.random.PRNGKey(0), (4, 3))}
    micro = _toy_micro(jax.random.PRNGKey(1), labeled=[1, 0, 0, 0, 1, 0, 0, 0])
    sup = float(objectives._masked_ce(fn(params, micro["x"]), micro["y"],
                                      micro["labeled"]))
    closed = objectives.classification_objective(
        objectives.ObjectiveSpec(kind="pseudo-label", pseudo_threshold=1 - 1e-9,
                                 unlabeled_weight=2.0), fn)
    np.testing.assert_allclose(
        float(closed.loss(params, micro, jax.random.PRNGKey(2))), sup,
        rtol=1e-6)
    open_ = objectives.classification_objective(
        objectives.ObjectiveSpec(kind="pseudo-label", pseudo_threshold=1e-9,
                                 unlabeled_weight=2.0), fn)
    assert float(open_.loss(params, micro, jax.random.PRNGKey(2))) > sup


def test_missing_labeled_leaf_means_fully_labeled():
    """No 'labeled' leaf -> all-ones mask: a pseudo-label objective on a
    fully labeled batch has an empty gate, so loss == plain CE."""
    fn = _toy_logits_fn()
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3))}
    micro = _toy_micro(jax.random.PRNGKey(1))
    obj = objectives.classification_objective(
        objectives.ObjectiveSpec(kind="pseudo-label", unlabeled_weight=3.0),
        fn)
    got = float(obj.loss(params, micro, jax.random.PRNGKey(2)))
    want = float(objectives._masked_ce(fn(params, micro["x"]), micro["y"],
                                       jnp.ones(8)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_objective_spec_validation():
    with pytest.raises(ValueError):
        objectives.ObjectiveSpec(kind="nope")
    with pytest.raises(ValueError):
        objectives.ObjectiveSpec(unlabeled_weight=-1.0)
    with pytest.raises(ValueError):
        objectives.ObjectiveSpec(pseudo_threshold=1.5)
    with pytest.raises(ValueError):
        objectives.build_objective(
            objectives.ObjectiveSpec(kind="consistency"))
    assert objectives.build_objective(None) is None
    assert objectives.build_objective(objectives.ObjectiveSpec()) is None


# --------------------------------------------------------------------------- #
# strip / merge machinery
# --------------------------------------------------------------------------- #


def test_strip_personal_identity_for_empty_mask():
    tree = {"a": jnp.ones(3), "b": {"head": jnp.zeros(2)}}
    assert engine.strip_personal((), tree) is tree


def test_strip_personal_substring_match_and_merge():
    tree = {"blocks": {"w": jnp.ones(2)}, "head": {"w": jnp.full(2, 7.0)},
            "final_norm": jnp.full(3, 5.0)}
    stripped = engine.strip_personal(("head", "final_norm"), tree)
    assert stripped["head"]["w"] is None and stripped["final_norm"] is None
    np.testing.assert_array_equal(np.asarray(stripped["blocks"]["w"]),
                                  np.ones(2))
    merged = engine._merge_personal(
        stripped, tree, lambda s, f: s * 0.0)
    np.testing.assert_array_equal(np.asarray(merged["blocks"]["w"]),
                                  np.zeros(2))          # synced: merged via fn
    np.testing.assert_array_equal(np.asarray(merged["head"]["w"]),
                                  np.full(2, 7.0))      # personal: untouched
    np.testing.assert_array_equal(np.asarray(merged["final_norm"]),
                                  np.full(3, 5.0))


def test_sync_spec_personal_validation():
    with pytest.raises(ValueError):
        engine.SyncSpec(personal=("ok", ""))
    with pytest.raises(ValueError):
        engine.SyncSpec(personal="head")  # must be a tuple, not a bare string


# --------------------------------------------------------------------------- #
# personalization: personal leaves provably never cross the wire
# --------------------------------------------------------------------------- #


def _two_leaf_init(poison):
    """params {"x": shared, "head": personal}; ``head`` enters the loss with
    zero gradient so any cross-client mixing could only come from sync."""
    def init(key):
        return {"x": jnp.zeros(24), "head": jnp.asarray(poison, jnp.float32)}
    return init


def _two_leaf_loss(problem):
    base = _quad_loss(problem)

    def loss(params, micro):
        # head's contribution is identically zero (g_head = 0): the leaf can
        # only change if the sync path touches it
        return base({"x": params["x"]}, micro) + 0.0 * jnp.sum(params["head"])
    return loss


@pytest.mark.parametrize("method", ["savic", "fedavg", "fedadam",
                                    "local-adam"])
def test_personal_leaf_poison_never_mixes(problem, method):
    """Plant per-client poison in the personal leaf: after rounds of sync it
    must be exactly where each client left it (zero grad => frozen), while
    the synced leaf is identical across clients after every round."""
    kw = dict(MS_KW)
    if method in ("savic", "local-adam"):
        kw["scaling"] = "local"     # global non-identity D is rejected
    spec = engine.method_spec(method, **kw, personal=("head",))
    loss = _two_leaf_loss(problem)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0), _two_leaf_init(0.0),
                              spec, 4)
    poison = jnp.arange(4, dtype=jnp.float32) * 100.0 + 1.0
    state["params"]["head"] = poison
    loader = QuadraticLoader(problem, seed=0)
    key = jax.random.PRNGKey(1)
    for _ in range(3):
        key, k = jax.random.split(key)
        state, _ = step(state, jax.tree.map(jnp.asarray,
                                            loader.round_batch(3)), k)
        np.testing.assert_array_equal(np.asarray(state["params"]["head"]),
                                      np.asarray(poison))
        x = np.asarray(state["params"]["x"])
        np.testing.assert_array_equal(x, np.broadcast_to(x[:1], x.shape))
    if "server" in state:
        for leaf_path, _ in jax.tree_util.tree_flatten_with_path(
                state["server"])[0]:
            assert "head" not in "/".join(str(p) for p in leaf_path)


def test_personal_matches_no_personal_on_synced_leaves(problem):
    """With a zero-gradient personal leaf, the SYNCED leaves' trajectory is
    bitwise the single-leaf run's — stripping is exact, not approximate."""
    spec_p = engine.method_spec("fedadam", **MS_KW, personal=("head",))
    spec_0 = engine.method_spec("fedadam", **MS_KW)
    loss2 = _two_leaf_loss(problem)
    loss1 = _quad_loss(problem)

    def run_with(loss, spec, init_fn):
        step = jax.jit(engine.build_round_step(loss, spec))
        state = engine.init_state(jax.random.PRNGKey(0), init_fn, spec, 4)
        loader = QuadraticLoader(problem, seed=0)
        key = jax.random.PRNGKey(1)
        for _ in range(4):
            key, k = jax.random.split(key)
            state, _ = step(state, jax.tree.map(jnp.asarray,
                                                loader.round_batch(3)), k)
        return state

    st_p = run_with(loss2, spec_p, _two_leaf_init(3.0))
    st_0 = run_with(loss1, spec_0, lambda k: {"x": jnp.zeros(24)})
    np.testing.assert_array_equal(np.asarray(st_p["params"]["x"]),
                                  np.asarray(st_0["params"]["x"]))
    np.testing.assert_array_equal(np.asarray(st_p["server"]["v"]["x"]),
                                  np.asarray(st_0["server"]["v"]["x"]))


def test_personal_global_precond_rejected(problem):
    spec = engine.method_spec("savic", **MS_KW, personal=("head",))
    assert spec.client.scaling == "global" \
        and spec.precond.kind != "identity"
    with pytest.raises(ValueError, match="personal"):
        engine.build_round_step(_two_leaf_loss(problem), spec)


def test_bytes_on_wire_drops_exactly_the_personal_subset():
    """Personalization changes the wire accounting by exactly the personal
    leaves' bytes — the synced subset's accounting is untouched."""
    params = {"x": jax.ShapeDtypeStruct((64,), jnp.float32),
              "head": jax.ShapeDtypeStruct((16,), jnp.float32)}
    spec_p = engine.method_spec("fedadam", personal=("head",))
    spec_0 = engine.method_spec("fedadam")
    w_p = engine.bytes_on_wire(spec_p, params)
    w_0 = engine.bytes_on_wire(spec_0, params)
    w_synced_only = engine.bytes_on_wire(spec_0, {"x": params["x"]})
    assert w_p["total_bytes"] == w_synced_only["total_bytes"]
    assert w_0["total_bytes"] - w_p["total_bytes"] == 16 * 4
    assert w_p["server_state_bytes"] == w_synced_only["server_state_bytes"]


def test_personal_state_checkpoint_roundtrip(problem, tmp_path):
    """None-stripped server/ef trees ride the path-manifest checkpoint
    bitwise (None subtrees simply have no leaves to save)."""
    from repro.checkpoint import restore, save
    spec = engine.method_spec("fedadam", **MS_KW, personal=("head",))
    loss = _two_leaf_loss(problem)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0), _two_leaf_init(2.0),
                              spec, 4)
    loader = QuadraticLoader(problem, seed=0)
    state, _ = step(state, jax.tree.map(jnp.asarray, loader.round_batch(3)),
                    jax.random.PRNGKey(9))
    save(str(tmp_path), 1, state)
    out, step_no = restore(str(tmp_path), state)
    assert step_no == 1
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# loader plumbing: the 'labeled' leaf
# --------------------------------------------------------------------------- #


def test_federated_loader_labeled_leaf():
    d = ClassificationData.make(n=2000, n_classes=10)
    parts = main_class_partition(d.y, 4, 0.5)
    lab = labeled_mask(d.y, 0.2, seed=3)
    loader = FederatedLoader(d.x, d.y, parts, batch_size=8, labeled=lab)
    b = loader.round_batch(H=3)
    assert b["labeled"].shape == (4, 3, 8)
    assert set(np.unique(b["labeled"])) <= {0.0, 1.0}
    # default: no leaf — the pre-objectives two-leaf batch
    b0 = FederatedLoader(d.x, d.y, parts, batch_size=8).round_batch(H=3)
    assert set(b0.keys()) == {"x", "y"}


def test_lm_round_loader_labeled_leaf_round_addressable():
    stream = TokenStream(128, seed=0)
    loader = LMRoundLoader(stream, 2, 4, labeled_frac=0.25, seed=7)
    b1 = loader.round_batch(3, 2, 16)
    b2 = loader.round_batch(3, 2, 16)
    assert b1["labeled"].shape == (2, 2, 4)
    np.testing.assert_array_equal(b1["labeled"], b2["labeled"])
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b_other = loader.round_batch(4, 2, 16)
    assert not np.array_equal(b1["labeled"], b_other["labeled"])
    # fully labeled: structurally the pre-objectives batch
    full = LMRoundLoader(stream, 2, 4).round_batch(3, 2, 16)
    assert "labeled" not in full


def test_labeled_mask_stratified():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 10, size=5000)
    m = labeled_mask(y, 0.1, seed=1)
    assert m.shape == y.shape and m.dtype == np.float32
    assert set(np.unique(m)) <= {0.0, 1.0}
    total = int(m.sum())
    assert abs(total - 500) <= 10
    for c in range(10):
        sel = m[y == c]
        assert sel.sum() >= 1                      # every class represented
        assert abs(sel.mean() - 0.1) < 0.03
    np.testing.assert_array_equal(m, labeled_mask(y, 0.1, seed=1))
    np.testing.assert_array_equal(labeled_mask(y, 1.0), np.ones_like(m))
    np.testing.assert_array_equal(labeled_mask(y, 0.0), np.zeros_like(m))


# --------------------------------------------------------------------------- #
# launch threading (tiny mesh)
# --------------------------------------------------------------------------- #


def test_build_train_step_threads_objective_and_personal():
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 8, 2, "train")
    obj = objectives.ObjectiveSpec(kind="pseudo-label", unlabeled_weight=0.5)
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             reduced=True, h_local=2, objective=obj,
                             labeled_frac=0.25, personal=("final_norm",))
    assert built.meta["objective"] == {"kind": "pseudo-label",
                                       "labeled_frac": 0.25,
                                       "personal": ["final_norm"]}
    spec = built.meta["engine_spec"]
    assert spec.sync.personal == ("final_norm",)
    assert "labeled" in built.args[1]
    state_shape = built.args[0]
    # server state carries no personal leaves; spec trees align with shapes
    for path, _ in jax.tree_util.tree_flatten_with_path(
            state_shape["server"])[0]:
        assert "final_norm" not in "/".join(str(p) for p in path)
    state_spec, _ = built.in_shardings
    for k in state_shape:
        assert jax.tree.structure(state_shape[k]) \
            == jax.tree.structure(
                jax.tree.map(lambda s: s.spec, state_spec[k]))


def test_build_train_step_rejects_personal_global_precond():
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 8, 2, "train")
    with pytest.raises(ValueError, match="personal"):
        build_train_step("qwen2-0.5b", shape, mesh, method="savic",
                         reduced=True, h_local=2, personal=("final_norm",))


# --------------------------------------------------------------------------- #
# end-to-end: semi-supervised MLP federation learns on a label-scarce split
# --------------------------------------------------------------------------- #


def _mlp(n_in, n_classes, width=32):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (n_in, width)) * (n_in ** -0.5),
                "b1": jnp.zeros((width,)),
                "w2": jax.random.normal(k2, (width, n_classes))
                * (width ** -0.5),
                "b2": jnp.zeros((n_classes,))}

    def logits_fn(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    return init, logits_fn


@pytest.mark.filterwarnings("ignore:main_class_partition")
def test_semi_supervised_federation_learns():
    """Engine × objective × labeled-mask loader end to end: supervised CE on
    the labeled subset decreases over rounds on a main-class split with only
    10% labels."""
    data = ClassificationData.make(n=4000, n_classes=10, seed=0)
    parts = main_class_partition(data.y, 4, 0.3, seed=0)
    lab = labeled_mask(data.y, 0.1, seed=0)
    loader = FederatedLoader(data.x, data.y.astype(np.int32), parts,
                             batch_size=16, seed=0, labeled=lab)
    init, logits_fn = _mlp(data.x.shape[1], 10)
    obj = objectives.classification_objective(
        objectives.ObjectiveSpec(kind="consistency", unlabeled_weight=0.5,
                                 noise_sigma=0.1), logits_fn)
    spec = engine.method_spec("fedadam", eta_l=0.02, eta=0.05)
    step = jax.jit(engine.build_round_step(obj.base_loss, spec,
                                           objective=obj))
    state = engine.init_state(jax.random.PRNGKey(0), init, spec, 4)
    key = jax.random.PRNGKey(1)
    losses = []
    for _ in range(12):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H=4))
        state, met = step(state, batch, k)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0], losses
