"""Heterogeneous & asynchronous rounds — the differential test harness.

Locks down ClientLoopSpec.local_steps + AsyncSpec (DESIGN.md §5):
  * differential pinning: an explicitly-uniform H_m vector plus a zero-depth
    staleness buffer is bit-identical to the pre-PR engine snapshot
    (tests/_reference_engine.py) for all six METHODS — the same discipline
    tests/test_compression.py applies to the compression layer;
  * the masked heterogeneous client loop equals a per-client Python-loop
    oracle (plain SGD and heavy-ball clients), including the per-client
    final-step loss metric;
  * uniform-but-truncated H_m equals the plain engine on a truncated batch;
  * staleness weights normalize to 1 for every (B, weighting, round), B=1
    reduces to plain delta averaging, and the buffered engine matches a
    Python FIFO oracle — alone and composed with heterogeneous H_m;
  * systems-heterogeneity models in data/federated.py (step times, budgeted
    H_m, simulated round times);
  * launch-layer threading: buffer sharding, het metadata;
  * spec validation — deterministic versions plus hypothesis variants via
    _hypothesis_compat.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_engine as ref_engine
from _hypothesis_compat import given, settings, st
from repro.core import engine
from repro.data import QuadraticLoader, QuadraticProblem
from repro.data import federated as fed


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, build_round_step, init_state, spec, rounds=4, H=3, seed=0,
         n_clients=4, collect=False):
    loss = _quad_loss(problem)
    step = jax.jit(build_round_step(loss, spec))
    state = init_state(jax.random.PRNGKey(0),
                       lambda k: {"x": jnp.zeros(24)}, spec, n_clients)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    traj = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
        if collect:
            traj.append(np.asarray(state["params"]["x"][0]))
    return (state, met, traj) if collect else (state, met)


MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)


# --------------------------------------------------------------------------- #
# differential: uniform H_m + no buffer == pre-PR engine, bitwise, 6 methods
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("method", engine.METHODS)
def test_uniform_hm_no_buffer_bit_identical_to_prepr_engine(problem, method):
    """An explicitly-threaded uniform H_m vector (every client = the batch's
    H) and buffer_rounds=0 short-circuit to the exact pre-heterogeneity
    program: trajectories agree BITWISE with the verbatim engine snapshot."""
    H, M = 3, 4
    spec_new = engine.method_spec(method, **MS_KW, local_steps=(H,) * M,
                                  async_buffer=0)
    assert spec_new.sync.asynchrony.is_identity()
    spec_ref = ref_engine.method_spec(method, **MS_KW)
    st_new, met_new = _run(problem, engine.build_round_step,
                           engine.init_state, spec_new, H=H, n_clients=M)
    st_ref, met_ref = _run(problem, ref_engine.build_round_step,
                           ref_engine.init_state, spec_ref, H=H, n_clients=M)
    np.testing.assert_array_equal(np.asarray(st_new["params"]["x"]),
                                  np.asarray(st_ref["params"]["x"]))
    np.testing.assert_array_equal(np.asarray(st_new["mom"]["x"]),
                                  np.asarray(st_ref["mom"]["x"]))
    if "server" in st_ref:
        np.testing.assert_array_equal(np.asarray(st_new["server"]["v"]["x"]),
                                      np.asarray(st_ref["server"]["v"]["x"]))
    assert float(met_new["loss"]) == float(met_ref["loss"])
    assert "buffer" not in st_new
    assert "staleness" not in met_new


# --------------------------------------------------------------------------- #
# masked client loop vs per-client Python-loop oracle
# --------------------------------------------------------------------------- #


def _oracle_round(loss, x0, mom0, batch, h_m, lr, momentum):
    """Per-client Python loop: client m runs h_m[m] heavy-ball SGD steps on
    its own microbatches, then params (and momentum) are plainly averaged."""
    grad = jax.grad(lambda x, mc: loss({"x": x}, mc))
    xs, ms, final_losses = [], [], []
    for m in range(len(h_m)):
        x, mo = x0.copy(), mom0[m].copy()
        for h in range(h_m[m]):
            micro = {k: jnp.asarray(v[m, h]) for k, v in batch.items()}
            l = float(loss({"x": jnp.asarray(x)}, micro))
            g = np.asarray(grad(jnp.asarray(x), micro))
            mo = momentum * mo + g
            x = x - lr * mo
        xs.append(x)
        ms.append(mo)
        final_losses.append(l)
    return (np.mean(xs, axis=0), np.mean(ms, axis=0),
            np.asarray(final_losses))


@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_masked_loop_matches_python_oracle(problem, momentum):
    """One heterogeneous round (H_m = 1..H) equals the per-client oracle:
    frozen clients contribute their step-H_m state to the sync average, and
    loss_per_client reports each client's OWN final step."""
    H, M = 4, 4
    h_m = (1, 2, 4, 3)
    loss = _quad_loss(problem)
    if momentum:   # savic heavy-ball clients, identity D, momentum averaged
        spec = engine.method_spec("savic", **{**MS_KW, "beta1": momentum},
                                  pc_kind="identity", local_steps=h_m)
        lr = MS_KW["gamma"]
    else:          # fedavg plain-SGD clients
        spec = engine.method_spec("fedavg", **MS_KW, local_steps=h_m)
        lr = MS_KW["eta_l"]
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec, M)
    loader = QuadraticLoader(problem, seed=0)
    batch = {k: np.asarray(v) for k, v in loader.round_batch(H).items()}
    new_state, met = step(state, jax.tree.map(jnp.asarray, batch),
                          jax.random.PRNGKey(9))
    x_avg, m_avg, final_losses = _oracle_round(
        loss, np.zeros(24), np.asarray(state["mom"]["x"]), batch, h_m,
        lr, momentum)
    np.testing.assert_allclose(np.asarray(new_state["params"]["x"][0]),
                               x_avg, rtol=1e-6, atol=1e-7)
    if momentum:
        np.testing.assert_allclose(np.asarray(new_state["mom"]["x"][0]),
                                   m_avg, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(met["loss_per_client"]),
                               final_losses, rtol=1e-6)


def test_uniform_truncated_hm_equals_truncated_batch(problem):
    """H_m = (h, h, ..., h) with h < H masks the tail steps: the result is
    bitwise the plain engine run on the batch truncated to h microbatches
    (the masked steps' arithmetic is computed and fully discarded)."""
    H, h, M = 5, 2, 4
    loss = _quad_loss(problem)
    loader = QuadraticLoader(problem, seed=0)
    batch = {k: np.asarray(v) for k, v in loader.round_batch(H).items()}
    spec_m = engine.method_spec("fedavg", **MS_KW, local_steps=(h,) * M)
    spec_u = engine.method_spec("fedavg", **MS_KW)
    init = lambda k: {"x": jnp.zeros(24)}
    st_m = engine.init_state(jax.random.PRNGKey(0), init, spec_m, M)
    st_u = engine.init_state(jax.random.PRNGKey(0), init, spec_u, M)
    key = jax.random.PRNGKey(7)
    out_m, _ = jax.jit(engine.build_round_step(loss, spec_m))(
        st_m, jax.tree.map(jnp.asarray, batch), key)
    trunc = {k: jnp.asarray(v[:, :h]) for k, v in batch.items()}
    out_u, _ = jax.jit(engine.build_round_step(loss, spec_u))(
        st_u, trunc, key)
    np.testing.assert_array_equal(np.asarray(out_m["params"]["x"]),
                                  np.asarray(out_u["params"]["x"]))


def test_local_scaling_masks_per_client_preconditioner(problem):
    """local-adam with heterogeneous H_m: a frozen client's per-client D and
    step counter t freeze too (the D of client m reflects h_m[m] updates)."""
    H, M = 4, 4
    h_m = (1, 4, 2, 3)
    spec = engine.method_spec("local-adam", **MS_KW, local_steps=h_m)
    state, met = _run(problem, engine.build_round_step, engine.init_state,
                      spec, rounds=1, H=H, n_clients=M)
    t = np.asarray(state["precond"]["t"])
    np.testing.assert_array_equal(t, np.asarray(h_m))
    assert np.isfinite(float(met["loss"]))


# --------------------------------------------------------------------------- #
# staleness weights + the buffered server vs a Python FIFO oracle
# --------------------------------------------------------------------------- #


def test_staleness_weights_normalize_and_reduce():
    """w sums to 1 for every (B, weighting, round); invalid (not-yet-
    populated) slots get weight 0; B=1 is plain delta averaging (w = [1])."""
    for B in (1, 2, 3, 6):
        for wt in engine.STALENESS_WEIGHTINGS:
            for r in (0, 1, B - 1, B + 3, 100):
                w = np.asarray(engine.staleness_weights(
                    engine.AsyncSpec(buffer_rounds=B, weighting=wt),
                    jnp.int32(r)))
                np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
                assert (w >= 0).all()
                assert (w[min(r, B - 1) + 1:] == 0).all()
    np.testing.assert_array_equal(
        np.asarray(engine.staleness_weights(
            engine.AsyncSpec(buffer_rounds=1), jnp.int32(0))), [1.0])
    # polynomial weighting decays with staleness over the populated prefix
    w = np.asarray(engine.staleness_weights(
        engine.AsyncSpec(buffer_rounds=4, weighting="polynomial"),
        jnp.int32(10)))
    assert (np.diff(w) < 0).all()


def test_async_b1_reduces_to_plain_averaging(problem):
    """A depth-1 buffer holds only the fresh delta (staleness 0): the
    trajectory matches the synchronous engine to fp32 tolerance (the delta
    round-trip x_t + (x̄ − x_t) is not a bitwise identity — that is why the
    identity short-circuit is B = 0, not B = 1)."""
    spec_b = engine.method_spec("fedavg", **MS_KW, async_buffer=1)
    spec_s = engine.method_spec("fedavg", **MS_KW)
    st_b, met_b = _run(problem, engine.build_round_step, engine.init_state,
                       spec_b)
    st_s, _ = _run(problem, engine.build_round_step, engine.init_state,
                   spec_s)
    assert st_b["buffer"]["x"].shape == (1, 24)
    np.testing.assert_allclose(np.asarray(st_b["params"]["x"]),
                               np.asarray(st_s["params"]["x"]),
                               rtol=1e-5, atol=1e-7)
    assert float(met_b["staleness"]) == 0.0


def _oracle_buffered(loss, batch_rounds, keys, h_m, lr, B, weighting,
                     poly_a=0.5, M=4, d=24):
    """Python FIFO oracle for the staleness-buffered averaging server,
    composed with heterogeneous H_m masking."""
    grad = jax.grad(lambda x, mc: loss({"x": x}, mc))
    x = np.zeros(d)
    buf = [np.zeros(d) for _ in range(B)]
    for t, batch in enumerate(batch_rounds):
        xs = []
        for m in range(M):
            xm = x.copy()
            for h in range(h_m[m]):
                micro = {k: jnp.asarray(v[m, h]) for k, v in batch.items()}
                xm = xm - lr * np.asarray(grad(jnp.asarray(xm), micro))
            xs.append(xm)
        delta = np.mean(xs, axis=0) - x
        buf = [delta] + buf[:-1]
        ages = np.arange(B, dtype=np.float64)
        s = np.ones(B) if weighting == "constant" else (1 + ages) ** -poly_a
        w = s * (ages <= t)
        w = w / w.sum()
        x = x + sum(wi * bi for wi, bi in zip(w, buf))
    return x


@pytest.mark.parametrize("weighting", ["constant", "polynomial"])
def test_async_buffer_matches_python_oracle(problem, weighting):
    """The buffered engine (composed with heterogeneous H_m) equals the
    Python FIFO oracle over multiple rounds, including the early rounds where
    the weights renormalize over the populated prefix."""
    H, M, B, rounds = 3, 4, 3, 6
    h_m = (1, 3, 2, 3)
    loss = _quad_loss(problem)
    spec = engine.method_spec(
        "fedavg", **MS_KW, local_steps=h_m,
        asynchrony=engine.AsyncSpec(buffer_rounds=B, weighting=weighting))
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec, M)
    loader = QuadraticLoader(problem, seed=0)
    key = jax.random.PRNGKey(1)
    batches, keys = [], []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        batches.append({k_: np.asarray(v)
                        for k_, v in loader.round_batch(H).items()})
        keys.append(k)
        state, met = step(state, jax.tree.map(jnp.asarray, batches[-1]),
                          keys[-1])
    x_oracle = _oracle_buffered(loss, batches, keys, h_m, MS_KW["eta_l"], B,
                                weighting)
    np.testing.assert_allclose(np.asarray(state["params"]["x"][0]), x_oracle,
                               rtol=1e-5, atol=1e-6)
    # the applied staleness E_w[τ] is positive once the buffer is populated
    assert float(met["staleness"]) > 0.0


def test_async_buffer_with_adaptive_server_runs(problem):
    """The buffer composes with the adaptive server (the staleness-weighted
    delta is the pseudo-gradient) and with compression."""
    spec = engine.method_spec(
        "fedadam", **MS_KW, async_buffer=2,
        compression=engine.CompressionSpec(op="topk", k=0.5,
                                           error_feedback=True))
    state, met = _run(problem, engine.build_round_step, engine.init_state,
                      spec, rounds=5)
    assert "buffer" in state and "ef" in state and "server" in state
    assert state["buffer"]["x"].shape == (2, 24)
    assert np.isfinite(float(met["loss"]))
    assert np.isfinite(float(met["step_norm"]))


# --------------------------------------------------------------------------- #
# systems-heterogeneity models (data/federated.py)
# --------------------------------------------------------------------------- #


def test_sample_step_times_models():
    t = fed.sample_step_times("uniform", 8)
    np.testing.assert_array_equal(t, np.ones(8))
    t = fed.sample_step_times("lognormal", 64, seed=1, sigma=0.8)
    assert t.min() == 1.0 and t.max() > 1.0 and t.shape == (64,)
    np.testing.assert_array_equal(
        t, fed.sample_step_times("lognormal", 64, seed=1, sigma=0.8))
    t2 = fed.sample_step_times("tiers", 64, seed=2, tiers=(1.0, 2.0, 4.0))
    assert set(np.unique(t2)).issubset({1.0, 2.0, 4.0})
    with pytest.raises(ValueError):
        fed.sample_step_times("gaussian", 4)


def test_local_steps_budget():
    """Fixed wall-clock budget: the fastest client runs all H steps, a 2×
    slower client about H/2, everyone at least 1."""
    times = np.array([1.0, 2.0, 4.0, 100.0])
    h = fed.local_steps_from_times(times, 8)
    np.testing.assert_array_equal(h, [8, 4, 2, 1])
    h = fed.sample_local_steps("lognormal", 32, 8, seed=3)
    assert h.min() >= 1 and h.max() == 8
    np.testing.assert_array_equal(h, fed.sample_local_steps(
        "lognormal", 32, 8, seed=3))


def test_simulated_round_time():
    times = np.array([1.0, 3.0])
    assert fed.simulated_round_time(times, [4, 4]) == 12.0
    assert fed.simulated_round_time(times, [4, 2], barrier="sync") == 6.0
    assert fed.simulated_round_time(times, [4, 2], barrier="async",
                                    buffer_rounds=3) == 2.0
    with pytest.raises(ValueError):
        fed.simulated_round_time(times, [1, 1], barrier="maybe")


# --------------------------------------------------------------------------- #
# spec validation + trace-time shape errors
# --------------------------------------------------------------------------- #


def test_spec_validation():
    with pytest.raises(ValueError):
        engine.AsyncSpec(buffer_rounds=-1)
    with pytest.raises(ValueError):
        engine.AsyncSpec(weighting="exponential")
    with pytest.raises(ValueError):
        engine.AsyncSpec(poly_a=0.0)
    with pytest.raises(ValueError):
        engine.ClientLoopSpec(local_steps=(2, 0, 1))
    with pytest.raises(ValueError):
        engine.ClientLoopSpec(local_steps=())
    with pytest.raises(ValueError):
        engine.SyncSpec(asynchrony="fedbuff")  # must be an AsyncSpec
    # valid settings still construct, and normalize to hashable tuples
    s = engine.ClientLoopSpec(local_steps=np.array([2, 3], np.int64))
    assert s.local_steps == (2, 3)
    hash(engine.method_spec("fedavg", local_steps=(1, 2), async_buffer=2))


def test_trace_time_shape_errors(problem):
    loss = _quad_loss(problem)
    loader = QuadraticLoader(problem, seed=0)
    batch = jax.tree.map(jnp.asarray, loader.round_batch(3))
    init = lambda k: {"x": jnp.zeros(24)}
    # wrong M
    spec = engine.method_spec("fedavg", **MS_KW, local_steps=(1, 2))
    state = engine.init_state(jax.random.PRNGKey(0), init, spec, 4)
    with pytest.raises(ValueError, match="entries for"):
        engine.build_round_step(loss, spec)(state, batch, jax.random.PRNGKey(0))
    # H_m beyond the round's H microbatches
    spec = engine.method_spec("fedavg", **MS_KW, local_steps=(3, 3, 3, 9))
    state = engine.init_state(jax.random.PRNGKey(0), init, spec, 4)
    with pytest.raises(ValueError, match="exceeds"):
        engine.build_round_step(loss, spec)(state, batch, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# property-style invariants (hypothesis via the compat shim)
# --------------------------------------------------------------------------- #


@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=50),
       st.sampled_from(engine.STALENESS_WEIGHTINGS),
       st.floats(min_value=0.1, max_value=3.0))
@settings(max_examples=40, deadline=None)
def test_staleness_weights_property(B, r, weighting, poly_a):
    w = np.asarray(engine.staleness_weights(
        engine.AsyncSpec(buffer_rounds=B, weighting=weighting,
                         poly_a=poly_a), jnp.int32(r)))
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert (w >= 0).all() and w.shape == (B,)


@given(st.integers(min_value=1, max_value=400),
       st.integers(min_value=2, max_value=16))
@settings(max_examples=20, deadline=None)
def test_local_steps_bounds_property(seed, h_max):
    h = fed.sample_local_steps("lognormal", 16, h_max, seed=seed)
    assert h.shape == (16,) and h.min() >= 1 and h.max() <= h_max
    assert h.max() == h_max  # the fastest client always runs the full budget


# --------------------------------------------------------------------------- #
# launch layer: H_m threading + buffer sharding through build_train_step
# --------------------------------------------------------------------------- #


def test_build_train_step_threads_het_and_buffer_sharding():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    asy = engine.AsyncSpec(buffer_rounds=3, weighting="polynomial")
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             reduced=True, h_local=2, het_model="lognormal",
                             asynchrony=asy)
    spec = built.meta["engine_spec"]
    assert spec.sync.asynchrony == asy
    assert spec.client.local_steps is not None
    assert built.meta["het_model"] == "lognormal"
    assert built.meta["sim_round_time_sync"] > 0
    # the "async" pace is only recorded when a buffer actually exists (B>0);
    # pure H_m budgeting is labeled sim_round_time_budgeted instead
    assert built.meta["sim_round_time_async"] <= \
        built.meta["sim_round_time_budgeted"]
    state_shape = built.args[0]
    assert "buffer" in state_shape
    b0 = jax.tree.leaves(state_shape["buffer"])[0]
    assert b0.shape[0] == 3                      # leading B dim
    state_spec, _ = built.in_shardings
    assert jax.tree.structure(state_spec["buffer"]) \
        == jax.tree.structure(state_shape["buffer"])
    for s in jax.tree.leaves(state_spec["buffer"]):
        assert s.spec[0] is None                 # B never sharded
