"""benchmarks/diff.py tests: join-on-coordinates correctness, missing-row
surfacing (a point present in A but not B is reported, never silently
dropped), per-metric delta sign conventions, and the timing/comparable
split, on two small synthetic BENCH fixtures."""
import json

import pytest

from benchmarks import diff, matrix


def _doc(rows, rev="revA", bench="toy", axes=("method", "arm")):
    return {"schema_version": matrix.SCHEMA_VERSION, "bench": bench,
            "git_rev": rev, "config": {}, "axes": list(axes), "rows": rows}


def _row(method, arm, rev="revA", **metrics):
    return {"coords": {"method": method, "arm": arm}, "metrics": metrics,
            "git_rev": rev}


def _fixture_a():
    return _doc([
        _row("savic", "sync", final_loss=1.0, sim_time_to_target=10.0,
             round_ms_mean=5.0),
        _row("savic", "async", final_loss=0.8, sim_time_to_target=4.0,
             round_ms_mean=6.0),
        _row("fedavg", "sync", final_loss=2.0, sim_time_to_target=20.0,
             round_ms_mean=7.0),
    ])


def _fixture_b(rev="revB"):
    return _doc([
        # final_loss improves (delta -0.5), sim time regresses (delta +2.0),
        # wall clock differs (timing, never a regression)
        _row("savic", "sync", rev=rev, final_loss=0.5,
             sim_time_to_target=12.0, round_ms_mean=9.0),
        _row("savic", "async", rev=rev, final_loss=0.8,
             sim_time_to_target=4.0, round_ms_mean=6.5),
        # fedavg/sync missing; extra point instead
        _row("fedavg", "async", rev=rev, final_loss=1.5,
             sim_time_to_target=8.0, round_ms_mean=7.0),
    ], rev=rev)


def test_join_on_coordinates_and_sign_convention():
    rep = diff.diff_docs(_fixture_a(), _fixture_b())
    by = {tuple(r["coords"].values()): r for r in rep["rows"]}
    d = by[("savic", "sync")]["deltas"]
    assert d["final_loss"]["delta"] == pytest.approx(-0.5)   # b - a
    assert d["final_loss"]["rel"] == pytest.approx(-0.5)     # delta / |a|
    assert d["sim_time_to_target"]["delta"] == pytest.approx(2.0)
    # identical row -> deltas present but unchanged
    assert not any(v["changed"]
                   for v in by[("savic", "async")]["deltas"].values()
                   if v["kind"] == "comparable")


def test_missing_rows_surfaced_never_dropped():
    rep = diff.diff_docs(_fixture_a(), _fixture_b())
    assert rep["only_in_a"] == [{"method": "fedavg", "arm": "sync"}]
    assert rep["only_in_b"] == [{"method": "fedavg", "arm": "async"}]
    assert rep["n_missing"] == 2
    text = diff.format_report(rep)
    assert "MISSING in B" in text and "MISSING in A" in text


def test_timing_vs_comparable_classification():
    rep = diff.diff_docs(_fixture_a(), _fixture_b())
    by = {tuple(r["coords"].values()): r for r in rep["rows"]}
    d = by[("savic", "sync")]["deltas"]
    assert d["round_ms_mean"]["kind"] == "timing"
    assert d["final_loss"]["kind"] == "comparable"
    assert d["sim_time_to_target"]["kind"] == "comparable"
    # savic/sync: 2 comparable + 1 timing changed; savic/async: 1 timing
    assert rep["n_comparable_deltas"] == 2
    assert rep["n_timing_deltas"] == 2


def test_self_diff_is_clean():
    rep = diff.diff_docs(_fixture_a(), _fixture_a())
    assert rep["n_comparable_deltas"] == 0
    assert rep["n_timing_deltas"] == 0
    assert rep["n_missing"] == 0
    assert not rep["only_in_a"] and not rep["only_in_b"]


def test_missing_metrics_surfaced():
    a, b = _fixture_a(), _fixture_a()
    del b["rows"][0]["metrics"]["sim_time_to_target"]
    b["rows"][0]["metrics"]["new_metric"] = 1.0
    rep = diff.diff_docs(a, b)
    row = rep["rows"][0]
    assert row["metrics_only_in_a"] == ["sim_time_to_target"]
    assert row["metrics_only_in_b"] == ["new_metric"]
    assert rep["n_missing"] == 2


def test_tolerances():
    a, b = _fixture_a(), _fixture_a()
    b["rows"][0]["metrics"]["final_loss"] = 1.0 + 1e-9
    assert diff.diff_docs(a, b)["n_comparable_deltas"] == 1
    assert diff.diff_docs(a, b, atol=1e-6)["n_comparable_deltas"] == 0
    assert diff.diff_docs(a, b, rtol=1e-6)["n_comparable_deltas"] == 0


def test_mismatched_bench_or_axes_raise():
    with pytest.raises(ValueError, match="bench mismatch"):
        diff.diff_docs(_fixture_a(), _doc([], bench="other"))
    with pytest.raises(ValueError, match="axis mismatch"):
        diff.diff_docs(_fixture_a(), _doc([
            {"coords": {"method": "a"}, "metrics": {"v": 1.0},
             "git_rev": "r"}], axes=("method",)))


def test_invalid_doc_rejected():
    bad = _fixture_a()
    bad["rows"][0].pop("git_rev")
    with pytest.raises(ValueError, match="git_rev"):
        diff.diff_docs(bad, _fixture_b())


def test_cli_check_exit_codes(tmp_path):
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(_fixture_a()))
    pb.write_text(json.dumps(_fixture_b()))
    assert diff.main([str(pa), str(pa), "--check"]) == 0   # self-diff clean
    assert diff.main([str(pa), str(pb), "--check"]) == 1   # deltas + missing
    assert diff.main([str(pa), str(pb)]) == 0              # report-only

    # timing-only differences never fail --check
    c = _fixture_a()
    c["rows"][0]["metrics"]["round_ms_mean"] = 99.0
    pc = tmp_path / "c.json"
    pc.write_text(json.dumps(c))
    assert diff.main([str(pa), str(pc), "--check"]) == 0
