"""Data pipeline + checkpointing."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import (ClassificationData, FederatedLoader, QuadraticProblem,
                        TokenStream, dirichlet_partition, heterogeneity_score,
                        iid_partition, main_class_partition)


def test_main_class_partition_fractions():
    d = ClassificationData.make(n=5000, n_classes=10, seed=0)
    for frac in (0.3, 0.5, 0.7):
        parts = main_class_partition(d.y, 10, frac, seed=1)
        sizes = [len(p) for p in parts]
        assert len(set(sizes)) == 1                      # equal sizes
        for m, idx in enumerate(parts):
            got = (d.y[idx] == m % 10).mean()
            assert abs(got - frac) < 0.05, (m, got, frac)
        # no duplicates across clients
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


def test_heterogeneity_monotone_in_main_fraction():
    d = ClassificationData.make(n=5000, n_classes=10, seed=0)
    scores = [heterogeneity_score(d.y, main_class_partition(d.y, 10, f))
              for f in (0.1, 0.3, 0.5, 0.7)]
    assert scores == sorted(scores), scores


def test_dirichlet_and_iid():
    d = ClassificationData.make(n=4000, n_classes=10, seed=0)
    p_iid = iid_partition(len(d.y), 8)
    p_dir = dirichlet_partition(d.y, 8, alpha=0.1)
    assert heterogeneity_score(d.y, p_dir) > heterogeneity_score(d.y, p_iid)


def test_federated_loader_shapes():
    d = ClassificationData.make(n=2000, n_classes=10)
    parts = main_class_partition(d.y, 4, 0.5)
    loader = FederatedLoader(d.x, d.y, parts, batch_size=8)
    b = loader.round_batch(H=3)
    assert b["x"].shape == (4, 3, 8, d.x.shape[1])
    assert b["y"].shape == (4, 3, 8)


def test_token_stream_learnable():
    ts = TokenStream(128, seed=0)
    toks, labs = ts.batch(4, 64)
    assert toks.shape == (4, 64) and labs.shape == (4, 64)
    assert (toks[:, 1:] == labs[:, :-1]).all()          # labels = next token
    assert toks.max() < 128 and toks.min() >= 0


def test_quadratic_sigma_dif():
    p0 = QuadraticProblem.make(d=16, M=4, heterogeneity=0.0, sigma=0.5, seed=0)
    p1 = QuadraticProblem.make(d=16, M=4, heterogeneity=3.0, sigma=0.5, seed=0)
    assert p1.sigma_dif2() > p0.sigma_dif2()


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
             "step": jnp.int32(7),
             "nested": [jnp.ones((2,)), {"b": jnp.zeros((1,), jnp.bfloat16)}]}
    save(str(tmp_path), 3, state)
    save(str(tmp_path), 9, state)
    assert latest_step(str(tmp_path)) == 9
    out, step = restore(str(tmp_path), state)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert out["nested"][1]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc(tmp_path):
    state = {"x": jnp.zeros((4,))}
    for s in range(6):
        save(str(tmp_path), s, state, keep=3)
    import os
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(left) == 3
