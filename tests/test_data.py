"""Data pipeline + checkpointing."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.checkpoint import latest_step, restore, save
from repro.data import (ClassificationData, FederatedLoader, QuadraticProblem,
                        TokenStream, dirichlet_partition, heterogeneity_score,
                        iid_partition, main_class_partition,
                        realized_main_fraction)
from repro.data import federated as fed


def test_main_class_partition_fractions():
    d = ClassificationData.make(n=5000, n_classes=10, seed=0)
    for frac in (0.3, 0.5, 0.7):
        parts = main_class_partition(d.y, 10, frac, seed=1)
        sizes = [len(p) for p in parts]
        assert len(set(sizes)) == 1                      # equal sizes
        for m, idx in enumerate(parts):
            got = (d.y[idx] == m % 10).mean()
            assert abs(got - frac) < 0.05, (m, got, frac)
        # no duplicates across clients
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


def test_heterogeneity_monotone_in_main_fraction():
    d = ClassificationData.make(n=5000, n_classes=10, seed=0)
    scores = [heterogeneity_score(d.y, main_class_partition(d.y, 10, f))
              for f in (0.1, 0.3, 0.5, 0.7)]
    assert scores == sorted(scores), scores


def test_dirichlet_and_iid():
    d = ClassificationData.make(n=4000, n_classes=10, seed=0)
    p_iid = iid_partition(len(d.y), 8)
    p_dir = dirichlet_partition(d.y, 8, alpha=0.1)
    assert heterogeneity_score(d.y, p_dir) > heterogeneity_score(d.y, p_iid)


def test_federated_loader_shapes():
    d = ClassificationData.make(n=2000, n_classes=10)
    parts = main_class_partition(d.y, 4, 0.5)
    loader = FederatedLoader(d.x, d.y, parts, batch_size=8)
    b = loader.round_batch(H=3)
    assert b["x"].shape == (4, 3, 8, d.x.shape[1])
    assert b["y"].shape == (4, 3, 8)


def test_token_stream_learnable():
    ts = TokenStream(128, seed=0)
    toks, labs = ts.batch(4, 64)
    assert toks.shape == (4, 64) and labs.shape == (4, 64)
    assert (toks[:, 1:] == labs[:, :-1]).all()          # labels = next token
    assert toks.max() < 128 and toks.min() >= 0


def test_quadratic_sigma_dif():
    p0 = QuadraticProblem.make(d=16, M=4, heterogeneity=0.0, sigma=0.5, seed=0)
    p1 = QuadraticProblem.make(d=16, M=4, heterogeneity=3.0, sigma=0.5, seed=0)
    assert p1.sigma_dif2() > p0.sigma_dif2()


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
             "step": jnp.int32(7),
             "nested": [jnp.ones((2,)), {"b": jnp.zeros((1,), jnp.bfloat16)}]}
    save(str(tmp_path), 3, state)
    save(str(tmp_path), 9, state)
    assert latest_step(str(tmp_path)) == 9
    out, step = restore(str(tmp_path), state)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert out["nested"][1]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc(tmp_path):
    state = {"x": jnp.zeros((4,))}
    for s in range(6):
        save(str(tmp_path), s, state, keep=3)
    import os
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(left) == 3


# --------------------------------------------------------------------------- #
# partitioner contract suite (equal sizes / disjointness / realized fractions)
# --------------------------------------------------------------------------- #


def _balanced_labels(n=10000, n_classes=10, seed=0):
    rng = np.random.default_rng(seed)
    return rng.permutation(np.repeat(np.arange(n_classes), n // n_classes))


def _assert_partition_contract(parts, n_total):
    sizes = [len(p) for p in parts]
    assert len(set(sizes)) == 1, sizes                  # equal sizes
    allidx = np.concatenate(parts)
    assert len(allidx) == len(set(allidx.tolist()))     # disjoint
    assert allidx.min() >= 0 and allidx.max() < n_total


@pytest.mark.parametrize("mk", [
    lambda y, M, seed: iid_partition(len(y), M, seed=seed),
    lambda y, M, seed: dirichlet_partition(y, M, alpha=0.3, seed=seed),
    lambda y, M, seed: main_class_partition(y, M, 0.3, seed=seed),
], ids=["iid", "dirichlet", "main_class"])
@pytest.mark.parametrize("M", [4, 7, 10])
@pytest.mark.filterwarnings("ignore:main_class_partition")
def test_partitioners_equal_sizes_and_disjoint(mk, M):
    y = _balanced_labels()
    _assert_partition_contract(mk(y, M, 1), len(y))


@pytest.mark.filterwarnings("ignore:main_class_partition")
def test_main_class_realized_fraction_tolerance():
    """With one client per class the realized main fraction matches the
    requested fraction to within sampling tolerance."""
    y = _balanced_labels()
    for frac in (0.3, 0.5):
        parts = main_class_partition(y, 10, frac, seed=2)
        fr = realized_main_fraction(y, parts)
        np.testing.assert_allclose(fr, frac, atol=0.05)


def test_main_class_dry_pool_warns_and_reports():
    """Oversubscribed main classes (n_clients·main_frac >> n_classes) warn
    and the realized fraction visibly drops for the starved clients."""
    # 4 clients × frac 0.5 of 1000 samples each asks 500 from a 400-sample
    # class pool: guaranteed dry from the first client
    y = _balanced_labels(n=4000)
    with pytest.warns(UserWarning, match="ran dry"):
        parts = main_class_partition(y, 4, 0.5, seed=0)
    _assert_partition_contract(parts, len(y))
    fr = realized_main_fraction(y, parts)
    assert fr.max() <= 0.4 + 0.05          # pool cap: 400/1000 per client


def test_dirichlet_heterogeneity_monotone_in_alpha():
    """Smaller α must mean MORE heterogeneity: the largest-remainder quota
    fix makes heterogeneity_score strictly decreasing in α (truncation +
    uniform backfill used to flatten the small-α end)."""
    y = _balanced_labels()
    for seed in (0, 1):
        scores = [heterogeneity_score(
            y, dirichlet_partition(y, 10, a, seed=seed))
            for a in (0.05, 0.2, 1.0, 5.0, 50.0)]
        assert scores == sorted(scores, reverse=True), (seed, scores)


def test_largest_remainder_quota():
    raw = np.array([2.6, 3.6, 1.8])
    q = fed._largest_remainder(raw, 8)
    assert q.sum() == 8
    assert np.all(np.abs(q - raw) < 1.0)
    # exact integers pass through untouched
    np.testing.assert_array_equal(
        fed._largest_remainder(np.array([2.0, 3.0, 5.0]), 10), [2, 3, 5])


def test_step_times_tiers_normalized_by_declared_fastest_tier():
    """Regression: tiers must normalize by tiers.min(), not the drawn min.
    When no client draws the fast tier, the 2× tier must stay 2× — dividing
    by the drawn minimum used to silently relabel it as the 1× baseline."""
    t = fed.sample_step_times("tiers", 64, seed=0,
                              tiers=(1.0, 2.0, 4.0),
                              tier_probs=(0.0, 0.5, 0.5))
    assert set(np.unique(t)) <= {2.0, 4.0}
    assert t.min() == 2.0                  # NOT renormalized to 1.0
    # with the full fleet the fastest tier is the 1.0 baseline
    t_full = fed.sample_step_times("tiers", 64, seed=0)
    assert set(np.unique(t_full)) <= {1.0, 2.0, 4.0}
    assert t_full.min() == 1.0


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=2, max_value=12),
       st.integers(min_value=2, max_value=8),
       st.integers(min_value=0, max_value=99))
def test_partition_contract_hypothesis(n_classes, M, seed):
    import warnings as _w
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=40 * M)
    with _w.catch_warnings():
        _w.simplefilter("ignore", UserWarning)
        for parts in (dirichlet_partition(y, M, alpha=0.2, seed=seed),
                      main_class_partition(y, M, 0.4, seed=seed),
                      iid_partition(len(y), M, seed=seed)):
            _assert_partition_contract(parts, len(y))


@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=0.01, max_value=0.99),
       st.integers(min_value=0, max_value=99))
def test_largest_remainder_hypothesis(frac, seed):
    rng = np.random.default_rng(seed)
    raw = rng.random(8) * 10.0 * frac
    total = int(np.ceil(raw.sum()))
    q = fed._largest_remainder(raw, total)
    assert q.sum() == total
    assert np.all(q >= np.floor(raw))
    assert np.all(q <= np.floor(raw) + 1)
