"""Sharding: partition-spec rules + sharded-vs-single-device numerical
equivalence (subprocess: needs its own XLA device count)."""
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import ModelCallConfig, build
from repro.sharding import AxisPlan, params_pspecs, plan_for

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _FakeMesh:
    """Just enough of a Mesh for the partitioner's divisibility checks."""

    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b",
                                  "mamba2-1.3b", "qwen2-moe-a2.7b"])
def test_param_specs_divisible(arch):
    """Every sharded dim must divide by its mesh-axes extent (the rule the
    partitioner promises)."""
    mesh = _FakeMesh({"data": 16, "model": 16})
    plan = plan_for("paper", False)
    cfg = get_config(arch)
    model = build(cfg, ModelCallConfig())
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_pspecs(cfg, pshape, mesh, plan, client_dim=False)

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (leaf.shape, spec)

    jax.tree.map(check, pshape, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # something must actually be model-sharded
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert any("model" in str(s) for s in flat)


def test_expert_dim_sharded_when_divisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    plan = plan_for("paper", False)
    cfg = get_config("deepseek-v2-236b")      # 160 experts % 16 == 0
    model = build(cfg, ModelCallConfig())
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_pspecs(cfg, pshape, mesh, plan, client_dim=False)
    s = specs["blocks"]["stack"]["ffn"]["experts"]["wg"]
    assert tuple(s)[1] in ("model", ("model",))   # (L,E,d,f): E expert-parallel


def test_client_dim_added():
    mesh = _FakeMesh({"data": 16, "model": 16})
    plan = plan_for("paper", False)
    cfg = get_config("qwen2-0.5b")
    model = build(cfg, ModelCallConfig())
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    import jax.numpy as jnp
    pm = jax.tree.map(lambda s: jax.ShapeDtypeStruct((16,) + s.shape, s.dtype),
                      pshape)
    specs = params_pspecs(cfg, pm, mesh, plan, client_dim=True)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(tuple(s)[0] in ("data", ("data",)) for s in flat)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b",
                                  "qwen2-moe-a2.7b"])
def test_sharded_equals_single_device(arch):
    """8-device (2,4) mesh run == single-device run (subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_sharding_worker.py"),
         arch],
        capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
