"""Mamba2 SSD: chunked algorithm vs naive recurrence + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.ssm import (mamba2_decode, mamba2_forward, mamba2_init_cache,
                              init_mamba2, ssd_chunked, ssd_reference)


@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    nchunks=st.sampled_from([1, 2, 4]),
    chunk=st.sampled_from([16, 32]),
    H=st.sampled_from([2, 4]),
    P=st.sampled_from([16, 32]),
    N=st.sampled_from([8, 16]),
)
def test_ssd_chunked_vs_reference(B, nchunks, chunk, H, P, N):
    S = nchunks * chunk
    k = jax.random.key(S + H + P)
    xh = jax.random.normal(jax.random.fold_in(k, 0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, H, N))
    Cm = jax.random.normal(jax.random.fold_in(k, 4), (B, S, H, N))
    y1, h1 = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y2, h2 = ssd_reference(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)


def test_ssd_initial_state_carried():
    """h0 path: splitting a sequence in two halves == one pass."""
    B, S, H, P, N, chunk = 1, 64, 2, 16, 8, 16
    k = jax.random.key(0)
    xh = jax.random.normal(jax.random.fold_in(k, 0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, H, N))
    Cm = jax.random.normal(jax.random.fold_in(k, 4), (B, S, H, N))
    y_full, h_full = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    half = S // 2
    y1, h1 = ssd_chunked(xh[:, :half], dt[:, :half], A, Bm[:, :half],
                         Cm[:, :half], chunk)
    y2, h2 = ssd_chunked(xh[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], chunk, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=2e-3,
                               atol=2e-3)


def test_mamba_block_decode_matches_forward():
    """Full mamba2 block: token-by-token decode == full-sequence forward."""
    cfg = get_config("mamba2-1.3b", reduced=True)
    p = init_mamba2(jax.random.key(0), cfg)
    B, S = 2, 32
    u = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.3
    y_full = mamba2_forward(p, cfg, u, jnp.float32)
    cache = mamba2_init_cache(cfg, B)
    outs = []
    dec = jax.jit(lambda u1, c: mamba2_decode(p, cfg, u1, c, jnp.float32))
    for t in range(S):
        y, cache = dec(u[:, t:t + 1], cache)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)
