"""VERBATIM pre-compression snapshot of src/repro/core/engine.py (PR 1 state).

Pinning reference for tests/test_compression.py: the post-compression engine
with ``compression.op == "none"`` (or any identity-resolving CompressionSpec)
must emit bit-identical trajectories to this snapshot for every method in
METHODS. Same pattern as _reference_savic.py / _reference_fedopt.py: the
reference runs in-session so the comparison is exact on this backend.

Do not edit (except this header); regenerate by snapshotting engine.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import preconditioner as PC
from repro.core.preconditioner import PrecondConfig


# --------------------------------------------------------------------------- #
# Specs — one frozen dataclass per layer
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ClientLoopSpec:
    """H local steps per client: x ← x − lr·D̂⁻¹m,  m ← momentum·m + g."""
    lr: float = 0.1                # local step size (γ of Alg. 1, η_l of [42])
    momentum: float = 0.0          # heavy-ball β₁ on the client
    scaling: str = "global"        # "global" (D̂ updated at sync) | "local"
    # D-stat at sync for global scaling: "avg_grad" (from the client-averaged
    # sync gradient) | "avg_local" (average of per-client stats)
    stat_source: str = "avg_grad"
    weight_decay: float = 0.0
    grad_clip: float = 0.0         # global-norm clip per local step (0 = off)
    use_fused_kernel: bool = False # Pallas scaled_update kernel (TPU)
    reset_momentum: bool = False   # zero m at round start (FedOpt clients)

    def __post_init__(self):
        if self.scaling not in ("global", "local"):
            raise ValueError(self.scaling)


@dataclasses.dataclass(frozen=True)
class SyncSpec:
    """The weighted, optionally quantized, optionally partial sync average."""
    participation: float = 1.0     # fraction of clients entering the average
    sync_dtype: str = ""           # all-reduce dtype ("" = full precision)
    average_momentum: bool = True  # also average momentum buffers at sync


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """What the server does with the sync average."""
    kind: str = "average"          # "average" (Alg. 1) | "adaptive" ([42])
    opt: str = "adam"              # adagrad | adam | yogi   (adaptive only)
    eta: float = 0.1               # server lr η
    beta1: float = 0.9
    beta2: float = 0.999
    tau: float = 1e-3              # adaptivity floor τ
    v_init: Optional[float] = None # v_{-1}; default τ² (the §5.2 pain point)

    def __post_init__(self):
        if self.kind not in ("average", "adaptive"):
            raise ValueError(self.kind)
        if self.kind == "adaptive" and self.opt not in ("adagrad", "adam",
                                                        "yogi"):
            raise ValueError(self.opt)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    client: ClientLoopSpec = ClientLoopSpec()
    sync: SyncSpec = SyncSpec()
    server: ServerSpec = ServerSpec()
    precond: PrecondConfig = PrecondConfig(kind="identity")


# --------------------------------------------------------------------------- #
# Method presets — each method is a ~10-line spec
# --------------------------------------------------------------------------- #

METHODS = ("savic", "fedavg", "fedadagrad", "fedadam", "fedyogi", "local-adam")


def method_spec(method: str, *, pc_kind: str = "adam", alpha: float = 1e-2,
                gamma: float = 3e-4, beta1: float = 0.9, scaling: str = "global",
                eta: float = 0.1, eta_l: float = 0.05, tau: float = 1e-3,
                server_beta1: float = 0.9, server_beta2: float = 0.999,
                v_init: Optional[float] = None,
                participation: float = 1.0, sync_dtype: str = "",
                use_fused_kernel: bool = False) -> EngineSpec:
    """Canonical EngineSpec for each named method.

    savic       Algorithm 1: locally-scaled heavy-ball clients, plain average.
    fedavg      plain Local SGD clients (no momentum), plain average.
    fedadagrad / fedadam / fedyogi
                Algorithm 2 of [42]: plain SGD clients (momentum reset each
                round), adaptive server on the pseudo-gradient Δ. ``beta1``
                (client heavy-ball) does not apply; server momentum is
                ``server_beta1``.
    local-adam  composed scenario (cf. 2409.13155): locally-scaled clients
                (per-client D updated every step) AND an adaptive Adam server.
    """
    sync = SyncSpec(participation=participation, sync_dtype=sync_dtype)
    if method == "savic":
        # one source of truth for the SAVIC composition: SavicConfig ->
        # engine_spec in core/savic.py (lazy import; savic imports engine)
        from repro.core.savic import SavicConfig, engine_spec
        return engine_spec(
            PrecondConfig(kind=pc_kind, alpha=alpha),
            SavicConfig(gamma=gamma, beta1=beta1, scaling=scaling,
                        use_fused_kernel=use_fused_kernel,
                        participation=participation, sync_dtype=sync_dtype))
    if method == "fedavg":
        # plain Local SGD clients (no momentum), plain average — textbook
        # FedAvg; heavy-ball local SGD is savic with pc_kind="identity"
        return EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=0.0),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="average"),
            precond=PrecondConfig(kind="identity"))
    if method in ("fedadagrad", "fedadam", "fedyogi"):
        return EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=0.0, reset_momentum=True),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="adaptive", opt=method[3:], eta=eta,
                              beta1=server_beta1, beta2=server_beta2, tau=tau,
                              v_init=v_init),
            precond=PrecondConfig(kind="identity"))
    if method == "local-adam":
        return EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=beta1, scaling="local",
                                  use_fused_kernel=use_fused_kernel),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="adaptive", opt="adam", eta=eta,
                              beta1=server_beta1, beta2=server_beta2, tau=tau,
                              v_init=v_init),
            precond=PrecondConfig(kind=pc_kind, alpha=alpha))
    raise ValueError(f"method {method}; expected one of {METHODS}")


# --------------------------------------------------------------------------- #
# State
# --------------------------------------------------------------------------- #


def init_state(key, init_params_fn, spec: EngineSpec, n_clients: int):
    """x_0^m = x_0 (identical start). Server m/v shaped like one replica."""
    params = init_params_fn(key)
    params_m = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape), params)
    mom = jax.tree.map(jnp.zeros_like, params_m)
    if spec.client.scaling == "local":
        pstate = PC.init_state(spec.precond, params_m)  # per-client D (M dim)
        if "d" in pstate:
            pstate["t"] = jnp.zeros((n_clients,), jnp.int32)  # per-client t
    else:
        pstate = PC.init_state(spec.precond, params)    # global D (no M dim)
    state = {
        "params": params_m,
        "mom": mom,
        "precond": pstate,
        "round": jnp.int32(0),
    }
    if spec.server.kind == "adaptive":
        v0 = spec.server.v_init if spec.server.v_init is not None \
            else spec.server.tau ** 2
        state["server"] = {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(lambda p: jnp.full_like(p, v0), params),
        }
    return state


def average_params(state):
    """The server/averaged point x̂ (clients are identical post-sync)."""
    return jax.tree.map(lambda p: p[0], state["params"])


def client_drift(params_m):
    """(1/M)Σ‖x^m − x̂‖² — the V_t of the analysis (0 right after sync)."""
    def per_leaf(p):
        mean = p.mean(axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)
    return sum(jax.tree.leaves(jax.tree.map(per_leaf, params_m)))


# --------------------------------------------------------------------------- #
# ClientLoop
# --------------------------------------------------------------------------- #


def _clip(grads, max_norm):
    if not max_norm:
        return grads
    nrm = jnp.sqrt(sum(jnp.vdot(g, g).real
                       for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / nrm)
    return jax.tree.map(lambda g: g * scale, grads)


def _apply_update(params, mom, grads, pstate, spec: EngineSpec):
    """x ← x − lr·D̂⁻¹m,  m ← momentum·m + g   (heavy-ball, scaled)."""
    cl, pc = spec.client, spec.precond
    g = grads
    if cl.weight_decay:
        g = jax.tree.map(lambda gi, p: gi + cl.weight_decay * p, g, params)
    mom = jax.tree.map(lambda m, gi: cl.momentum * m + gi, mom, g)
    if cl.use_fused_kernel and pc.kind != "identity":
        from repro.kernels import ops as kops
        params = kops.scaled_update_tree(params, mom, pstate["d"],
                                         cl.lr, pc.alpha,
                                         squared=pc.rule == "squared")
    else:
        direction = PC.precondition(pc, pstate, mom)
        params = jax.tree.map(lambda p, d: p - cl.lr * d, params, direction)
    return params, mom


def _client_loop(loss_fn, grad_fn, spec: EngineSpec):
    """H local steps, vmap-over-M inside a lax.scan over H.

    Returns ``run(params_m, mom_m, pstate, micro, keys) ->
    (params_m, mom_m, pstate, last_grads, losses)`` with micro/keys leading
    (H, M) dims and losses shaped (H, M).
    """
    cl, pc = spec.client, spec.precond

    def local_step_one_client(params, mom, pstate, micro, key):
        """One scaled step on one client. pstate: the client's view of D."""
        loss, grads = grad_fn(params, micro)
        grads = _clip(grads, cl.grad_clip)
        if cl.scaling == "local" and pc.kind != "identity":
            stat = (PC.hutchinson_diag(loss_fn, params, micro, key)
                    if pc.uses_hutchinson else PC.grad_stat(grads))
            if pc.rule == "linear" and not pc.uses_hutchinson:
                stat = jax.tree.map(jnp.abs, grads)
            pstate = PC.update(pc, pstate, stat)
        params, mom = _apply_update(params, mom, grads, pstate, spec)
        return params, mom, pstate, loss, grads

    global_d = cl.scaling == "global"

    def run(params_m, mom_m, pstate, micro, keys):
        def scan_body(carry, xs):
            params_m, mom_m, pstate, _ = carry
            micro_m, ks = xs  # (M, ...) microbatch slice, (M,) keys
            if global_d:
                fn = lambda p, m, mc, k: local_step_one_client(
                    p, m, pstate, mc, k)
                params_m, mom_m, _, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, micro_m, ks)
                new_pstate = pstate
            else:
                fn = local_step_one_client
                params_m, mom_m, new_pstate, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, pstate, micro_m, ks)
            return (params_m, mom_m, new_pstate, grads), losses

        grads0 = jax.tree.map(jnp.zeros_like, params_m)
        (params_m, mom_m, pstate, last_grads), losses = jax.lax.scan(
            scan_body, (params_m, mom_m, pstate, grads0), (micro, keys))
        return params_m, mom_m, pstate, last_grads, losses

    return local_step_one_client, run


# --------------------------------------------------------------------------- #
# SyncStrategy
# --------------------------------------------------------------------------- #


def participation_weights(spec: SyncSpec, key, n_clients: int):
    """Per-client sync weights: uniform 1/M, or 1/n_part on a sampled subset
    (FedAvg-style client sampling); weights always sum to 1."""
    M = n_clients
    n_part = max(1, int(round(spec.participation * M)))
    if n_part < M:
        perm = jax.random.permutation(jax.random.fold_in(key, 3), M)
        return jnp.zeros((M,)).at[perm[:n_part]].set(1.0 / n_part)
    return jnp.full((M,), 1.0 / M)


def make_sync(spec: SyncSpec, key, n_clients: int):
    """The sync average: (M, ...) leaf -> (...) weighted mean.

    With ``sync_dtype`` set, the optimization barriers pin the low-precision
    representation so BOTH legs of the sync (reduce + broadcast-back) move
    sync_dtype bytes; the master-dtype cast happens locally after (quantized
    averaging — same family as the quantization line of related work [19,20];
    sync noise ~2^-8 relative for bf16).
    """
    M = n_clients
    w_part = participation_weights(spec, key, M)

    def _wmean(p):
        wb = w_part.reshape((M,) + (1,) * (p.ndim - 1)).astype(p.dtype)
        return (p * wb).sum(axis=0)

    if spec.sync_dtype:
        sd = jnp.dtype(spec.sync_dtype)

        def avg(p):
            q = jax.lax.optimization_barrier(p.astype(sd))
            a = _wmean(q)
            return jax.lax.optimization_barrier(a)
    else:
        avg = _wmean
    return avg


def _broadcast_back(params_m, avg):
    """Scatter the averaged value back to every client in sync dtype; cast to
    the master dtype locally (cross-device FedAvg semantics: non-participants
    are overwritten too)."""
    return jax.tree.map(
        lambda p, a: jnp.broadcast_to(a[None], (p.shape[0],) + a.shape
                                      ).astype(p.dtype),
        params_m, avg)


# --------------------------------------------------------------------------- #
# ServerUpdate
# --------------------------------------------------------------------------- #


def _adaptive_server_update(spec: ServerSpec, server, x_prev, delta):
    """m/v/x update of Algorithm 2 [42] on the pseudo-gradient Δ."""
    m = jax.tree.map(lambda m_, d: spec.beta1 * m_ + (1 - spec.beta1) * d,
                     server["m"], delta)
    if spec.opt == "adagrad":
        v = jax.tree.map(lambda v_, d: v_ + d * d, server["v"], delta)
    elif spec.opt == "adam":
        v = jax.tree.map(
            lambda v_, d: spec.beta2 * v_ + (1 - spec.beta2) * d * d,
            server["v"], delta)
    else:  # yogi
        v = jax.tree.map(
            lambda v_, d: v_ - (1 - spec.beta2) * d * d
            * jnp.sign(v_ - d * d), server["v"], delta)
    x = jax.tree.map(
        lambda x_, m_, v_: x_ + spec.eta * m_ / (jnp.sqrt(v_) + spec.tau),
        x_prev, m, v)
    return x, {"m": m, "v": v}


# --------------------------------------------------------------------------- #
# The round
# --------------------------------------------------------------------------- #


def build_round_step(loss_fn: Callable, spec: EngineSpec):
    """loss_fn(params, microbatch) -> scalar.

    Returns ``round_step(state, batch, key)`` where each batch leaf is
    (M, H, ...): H microbatches per client per round. Returns (state, metrics).
    Metrics: loss, loss_per_client, client_drift (+ step_norm for adaptive
    servers).
    """
    grad_fn = jax.value_and_grad(loss_fn)
    cl, sy, sv, pc = spec.client, spec.sync, spec.server, spec.precond
    _, client_run = _client_loop(loss_fn, grad_fn, spec)

    def round_step(state, batch, key):
        M = jax.tree.leaves(state["params"])[0].shape[0]
        H = jax.tree.leaves(batch)[0].shape[1]

        # ---- ClientLoop: H local steps, vmap over M inside the scan --------
        keys = jax.random.split(key, (H, M))
        micro = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch)  # (H,M,..)
        mom0 = jax.tree.map(jnp.zeros_like, state["mom"]) \
            if cl.reset_momentum else state["mom"]
        params_m, mom_m, pstate, last_grads, losses = client_run(
            state["params"], mom0, state["precond"], micro, keys)

        drift_pre_sync = client_drift(params_m)

        # ---- SyncStrategy: the only cross-client traffic per round ---------
        avg = make_sync(sy, key, M)
        params_avg = jax.tree.map(avg, params_m)

        if sv.kind == "average":
            params_m = _broadcast_back(params_m, params_avg)
            params_avg = jax.tree.map(lambda x: x[0], params_m)
            if sy.average_momentum:
                mom_m = jax.tree.map(
                    lambda m: jnp.broadcast_to(avg(m)[None],
                                               m.shape).astype(m.dtype), mom_m)

        # ---- D update at sync (global scaling; Algorithm 1 line 4) ---------
        if cl.scaling == "global" and pc.kind != "identity":
            g_last = last_grads  # (M, ...) — grads of the sync step
            if cl.stat_source == "avg_grad":
                g_avg = jax.tree.map(avg, g_last)  # participation+dtype apply
                if pc.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1, 0], micro)
                    stat = PC.hutchinson_diag(loss_fn, params_avg, sync_micro,
                                              jax.random.fold_in(key, 7))
                elif pc.rule == "linear":
                    stat = jax.tree.map(jnp.abs, g_avg)
                else:
                    stat = PC.grad_stat(g_avg)
            else:  # avg_local
                if pc.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1], micro)  # (M,..)
                    hk = jax.random.split(jax.random.fold_in(key, 7), M)
                    stats = jax.vmap(lambda p, mc, k: PC.hutchinson_diag(
                        loss_fn, p, mc, k))(params_m, sync_micro, hk)
                elif pc.rule == "linear":
                    stats = jax.tree.map(jnp.abs, g_last)
                else:
                    stats = PC.grad_stat(g_last)
                stat = jax.tree.map(lambda s: s.mean(axis=0), stats)
            pstate = PC.update(pc, pstate, stat)

        metrics = {
            "loss": losses.mean(),
            "loss_per_client": losses[-1],
            "client_drift": drift_pre_sync,
        }

        # ---- ServerUpdate ---------------------------------------------------
        new_state = {"round": state["round"] + 1, "precond": pstate}
        if sv.kind == "adaptive":
            x_prev = jax.tree.map(lambda p: p[0], state["params"])
            delta = jax.tree.map(
                lambda a, x: a.astype(x.dtype) - x, params_avg, x_prev)
            x_new, server = _adaptive_server_update(sv, state["server"],
                                                    x_prev, delta)
            params_m = _broadcast_back(params_m, x_new)
            new_state["server"] = server
            metrics["step_norm"] = jnp.sqrt(sum(
                jnp.vdot(a - b, a - b).real for a, b in zip(
                    jax.tree.leaves(x_new), jax.tree.leaves(x_prev))))
        new_state["params"] = params_m
        new_state["mom"] = mom_m
        return new_state, metrics

    return round_step
