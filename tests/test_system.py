"""End-to-end system tests: the paper's experiment shape (heterogeneous
federated classification with SAVIC variants), the train driver, checkpoint
resume, and the theory-shape validation on quadratics."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_shape, pairs_to_run
from repro.core import PrecondConfig, SavicConfig, savic, theory
from repro.data import (ClassificationData, FederatedLoader, QuadraticLoader,
                        QuadraticProblem, main_class_partition)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# the paper's experiment, miniaturized: scaled beats unscaled on het. data
# --------------------------------------------------------------------------- #


def _mlp_loss(n_in, n_classes, width=64):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (n_in, width)) * (n_in ** -0.5),
            "b1": jnp.zeros((width,)),
            "w2": jax.random.normal(k2, (width, n_classes)) * (width ** -0.5),
            "b2": jnp.zeros((n_classes,)),
        }

    def loss(params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
        return (logz - gold).mean()

    return init, loss


def _train_cls(kind, scaling, rounds=12, seed=0):
    data = ClassificationData.make(n=4000, n_classes=10, seed=seed)
    parts = main_class_partition(data.y, 10, 0.5, seed=seed)
    loader = FederatedLoader(data.x, data.y.astype(np.int32), parts,
                             batch_size=32, seed=seed)
    init, loss = _mlp_loss(data.x.shape[1], 10)
    # α=1e-2 keeps Assumption 4's floor active: with the corrected Adam
    # debias schedule (β_1 = 0) D̂ tracks |g| from the first sync, so the
    # floor — not the D⁰=1 init — is what bounds the step early on.
    pc = PrecondConfig(kind=kind, alpha=1e-2)
    sv = SavicConfig(gamma=0.002, beta1=0.9, scaling=scaling)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    state = savic.init_state(jax.random.PRNGKey(seed), init, pc, sv, 10)
    key = jax.random.PRNGKey(seed + 1)
    losses = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H=4))
        state, met = step(state, batch, k)
        losses.append(float(met["loss"]))
    return losses


def test_scaled_beats_unscaled_heterogeneous():
    """The paper's Fig.1 claim, miniaturized: Adam-scaled SAVIC reaches lower
    loss than unscaled Local SGD in the same number of rounds."""
    sgd = _train_cls("identity", "global")
    adam = _train_cls("adam", "global")
    assert adam[-1] < sgd[-1], (adam[-1], sgd[-1])
    assert adam[-1] < adam[0]


def test_local_scaling_runs_and_converges():
    loc = _train_cls("adam", "local", rounds=8)
    assert loc[-1] < loc[0]


# --------------------------------------------------------------------------- #
# theory shape validation (Theorem 1) on quadratics
# --------------------------------------------------------------------------- #


def _quad_run(problem, gamma, H, rounds, kind="identity", seed=0):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        Qm, bm = Q[micro["cid"]], b[micro["cid"]]   # per-client objective
        return 0.5 * (x - bm) @ Qm @ (x - bm) + micro["z"] @ x

    pc = PrecondConfig(kind=kind, alpha=0.5 if kind != "identity" else 1e-8)
    sv = SavicConfig(gamma=gamma, beta1=0.0)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    M, d = problem.b.shape
    state = savic.init_state(jax.random.PRNGKey(seed),
                             lambda k: {"x": jnp.zeros(d)}, pc, sv, M)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    dists = []
    xstar = jnp.asarray(problem.x_star(), jnp.float32)
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, _ = step(state, jax.tree.map(jnp.asarray, loader.round_batch(H)), k)
        x = savic.average_params(state)["x"]
        dists.append(float(jnp.sum((x - xstar) ** 2)))
    return np.array(dists)


@pytest.fixture(scope="module")
def quad():
    return QuadraticProblem.make(d=20, M=4, mu=0.5, L=4.0, sigma=0.6, seed=3)


@pytest.mark.slow
def test_thm1_noise_ball_scales_with_gamma(quad):
    """Stationary E‖x−x*‖² grows ~linearly with γ (Theorem 1's γΓσ²/α²μM
    term). Both runs long enough that the geometric transient has died."""
    lo = _quad_run(quad, gamma=0.04, H=4, rounds=80)[-10:].mean()
    hi = _quad_run(quad, gamma=0.08, H=4, rounds=80)[-10:].mean()
    assert hi > 1.5 * lo, (lo, hi)


def test_thm1_geometric_transient(quad):
    """Early rounds contract ~(1-γμ/2Γ)^(H per round) for identity scaling."""
    gamma = 0.05
    d = _quad_run(quad, gamma=gamma, H=4, rounds=30)
    spec = theory.ProblemSpec(mu=quad.mu, L=quad.L, sigma2=quad.sigma ** 2,
                              alpha=1.0, Gamma=1.0, M=4, H=4)
    rate = theory.thm1_rate(spec, gamma) ** 4          # per round (H steps)
    # measured contraction during the transient (first 10 rounds)
    measured = (d[9] / d[0]) ** (1 / 9)
    assert measured < 1.0
    # within 2x of the predicted exponent (upper bound; constants loose)
    assert measured < rate ** 0.25, (measured, rate)


@pytest.mark.slow
def test_drift_term_needs_heterogeneity(quad):
    """Two facts about the (H−1) term, both validated:

    (a) identical-data quadratics with ADDITIVE noise have exactly linear
        update dynamics, so averaging commutes with local steps and the
        stationary error is H-independent — the theorem's drift term is an
        upper bound that is vacuous for this family;
    (b) with heterogeneous objectives (σ²_dif > 0) the classic client-drift
        bias appears and grows with H at fixed γ (Theorem 2's 9(H−1)/2α
        term).
    """
    # (a) identical data: H makes no difference (ratio ≈ 1)
    a1 = np.mean([_quad_run(quad, 0.08, 1, 320, seed=s)[-5:].mean()
                  for s in range(2)])
    a16 = np.mean([_quad_run(quad, 0.08, 16, 20, seed=s)[-5:].mean()
                   for s in range(2)])
    assert 0.4 < a16 / a1 < 2.5, (a1, a16)

    # (b) heterogeneous clients: H=16 ≫ H=1 stationary error
    het = QuadraticProblem.make(d=20, M=4, mu=0.5, L=4.0, sigma=0.2,
                                heterogeneity=6.0, seed=5)
    b1 = np.mean([_quad_run(het, 0.05, 1, 320, seed=s)[-5:].mean()
                  for s in range(2)])
    b16 = np.mean([_quad_run(het, 0.05, 16, 20, seed=s)[-5:].mean()
                   for s in range(2)])
    assert b16 > 2.0 * b1, (b1, b16)


# --------------------------------------------------------------------------- #
# drivers / launch
# --------------------------------------------------------------------------- #


def test_train_driver_and_checkpoint_resume(tmp_path):
    from repro.launch import train as train_mod
    args = ["--arch", "qwen2-0.5b", "--reduced", "--rounds", "2",
            "--h-local", "2", "--clients", "2", "--batch", "2", "--seq", "32",
            "--ckpt", str(tmp_path), "--ckpt-every", "1"]
    log1 = train_mod.main(args)
    assert len(log1) == 2
    # resume: runs only the remaining round
    log2 = train_mod.main(["--arch", "qwen2-0.5b", "--reduced", "--rounds",
                           "3", "--h-local", "2", "--clients", "2", "--batch",
                           "2", "--seq", "32", "--ckpt", str(tmp_path)])
    assert [l["round"] for l in log2] == [2]


def test_train_driver_engine_methods():
    """--method runs the non-SAVIC engine presets end-to-end (adaptive server
    state threads through the driver loop and metrics)."""
    from repro.launch import train as train_mod
    log = train_mod.main(["--arch", "qwen2-0.5b", "--reduced", "--method",
                          "local-adam", "--rounds", "2", "--h-local", "2",
                          "--clients", "2", "--batch", "2", "--seq", "32"])
    assert len(log) == 2
    assert all("step_norm" in l for l in log)
    assert np.isfinite(log[-1]["loss"])


def test_serve_driver():
    from repro.launch.serve import serve
    res = serve("qwen2-0.5b", reduced=True, batch=2, prompt_len=8, gen_len=4,
                verbose=False)
    assert res.tokens.shape == (2, 4)
    assert res.timings["cache_setup_s"] == 0.0     # reuse path: no replay
    assert res.timings["prefill_s"] > 0.0
    assert res.per_token_s.shape == (3,)           # gen_len - 1 decode steps


def test_dryrun_fused_sharded_artifact_schema():
    """The dry-run artifact's fused-path keys (DESIGN.md §7) come verbatim
    from BuiltStep meta (dryrun.run_one copies them): a model-/FSDP-sharded
    plan keeps ``use_fused_kernel`` and records ``flat_layout_sharded`` with
    the full per-shard schema — and no ``fused_kernel_fallback``."""
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built = build_train_step("qwen2-0.5b", shape, mesh, method="savic",
                             mode="plain", reduced=True, h_local=2,
                             use_fused_kernel=True)
    assert built.meta["engine_spec"].client.use_fused_kernel
    assert "fused_kernel_fallback" not in built.meta
    assert "flat_layout" not in built.meta
    lay = built.meta["flat_layout_sharded"]
    assert set(lay) >= {"n_shards", "axes", "axis_sizes", "n_local", "n_flat",
                        "leaves"}
    assert lay["n_flat"] == lay["n_shards"] * lay["n_local"]
    for leaf in lay["leaves"]:
        assert set(leaf) >= {"path", "global_shape", "local_shape", "size",
                             "offset", "split", "uneven_fallback"}
    import json as _json
    _json.dumps(lay)    # artifact must serialize


def test_dryrun_fused_fallback_only_for_non_fp32(monkeypatch):
    """``fused_kernel_fallback`` survives ONLY for genuinely ineligible
    builds (non-fp32 client state — the flat view is fp32 by contract);
    sharded plans are no longer a fallback reason."""
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch import steps as steps_mod
    from repro.launch.steps import _fused_non_fp32, build_train_step

    # the helper mirrors the engine's all_float32 trace-time gate
    f32 = {"x": jax.ShapeDtypeStruct((4,), jnp.float32)}
    bf16 = {"x": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    spec = savic.engine_spec(PrecondConfig(kind="adam", alpha=1e-2),
                             SavicConfig(gamma=1e-3, beta1=0.9))
    base = {"params": f32, "mom": f32,
            "precond": {"d": f32, "t": jax.ShapeDtypeStruct((), jnp.int32)}}
    assert _fused_non_fp32(base, spec) == ""
    assert _fused_non_fp32({**base, "mom": bf16}, spec) == "mom"
    assert _fused_non_fp32({**base, "precond": {"d": bf16, "t": base[
        "precond"]["t"]}}, spec) == "precond.d"

    # full launch path: doctor the client state to bf16 -> fallback meta
    orig = steps_mod.engine.init_state

    def bf16_init(key, init_params_fn, spec, n_clients):
        st = orig(key, init_params_fn, spec, n_clients)
        for name in ("params", "mom"):
            st[name] = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                                    st[name])
        return st

    monkeypatch.setattr(steps_mod.engine, "init_state", bf16_init)
    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built = build_train_step("qwen2-0.5b", shape, mesh, method="savic",
                             mode="plain", reduced=True, h_local=2,
                             use_fused_kernel=True)
    assert not built.meta["engine_spec"].client.use_fused_kernel
    assert "non-fp32 client state (params" \
        in built.meta["fused_kernel_fallback"]
    assert "flat_layout_sharded" not in built.meta
    assert "flat_layout" not in built.meta


def test_pairs_to_run_covers_assignment():
    pairs = pairs_to_run()
    archs = {a for a, _ in pairs}
    assert len(archs) == 10
    assert ("deepseek-67b", "long_500k") not in pairs      # full-attn skip
    assert ("mamba2-1.3b", "long_500k") in pairs
    assert len([p for p in pairs if p[1] == "train_4k"]) == 10
