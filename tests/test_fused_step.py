"""Flat-buffer fused local step — the kernel-differential suite (DESIGN.md §7).

Locks down the ``use_fused_kernel`` fast path of the round engine:

  * differential pinning: the fused flat-buffer client loop is BIT-IDENTICAL
    (fp32) to the unfused tree path for all six METHODS, and to the verbatim
    pre-PR engine snapshot (tests/_reference_engine.py);
  * the kernel family itself (``fused_step_flat``) matches the pure-jnp
    oracle (``ref.fused_step_ref``) bitwise for every PrecondConfig kind ×
    β_t schedule × rule-4 clip, local/global/identity D, external (Hutchinson)
    and in-kernel grad² stats — including negative rule-3 (OASIS) D state;
  * the engine-level kind × schedule × clip × scaling matrix (tier-2 @slow;
    a representative slice stays in tier-1) plus grad-clip / weight-decay /
    heterogeneous-H_m compositions;
  * flatten/unflatten round-trips on ragged leaf shapes (deterministic +
    hypothesis via the _hypothesis_compat shim);
  * the per-rule padding contract at n % BLOCK ∈ {0, 1, BLOCK−1} — sliced
    outputs bitwise, padded lanes never poison them;
  * non-fp32 client state falls back to the (identical) tree path;
  * launch layer: build_train_step threads use_fused_kernel and records the
    flat-view layout in BuiltStep meta without changing shardings.

NaN notes: adahessian feeds the raw (possibly negative) v⊙Hv stat into the
rule-2 √d magnitude, so some configs NaN by design; bitwise comparisons use
assert_array_equal (NaN == NaN), pinning that fused and unfused diverge
identically.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_engine as ref_engine
from _hypothesis_compat import given, settings, st
from repro.core import engine, savic
from repro.core.preconditioner import PrecondConfig
from repro.data import QuadraticLoader, QuadraticProblem
from repro.kernels import ops, ref
from repro.kernels import scaled_update as su
from repro.utils.flatten import FlatLayout

MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, build_round_step, init_state, spec, rounds=3, H=3, seed=0,
         n_clients=4, dtype=jnp.float32):
    loss = _quad_loss(problem)
    step = jax.jit(build_round_step(loss, spec))
    state = init_state(jax.random.PRNGKey(0),
                       lambda k: {"x": jnp.zeros(24, dtype)}, spec, n_clients)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
    return state, met


def _assert_state_bitwise(st_a, st_b):
    """Bitwise trajectory equality (NaN-positions included)."""
    np.testing.assert_array_equal(np.asarray(st_a["params"]["x"]),
                                  np.asarray(st_b["params"]["x"]))
    np.testing.assert_array_equal(np.asarray(st_a["mom"]["x"]),
                                  np.asarray(st_b["mom"]["x"]))
    if "d" in st_b["precond"]:
        np.testing.assert_array_equal(np.asarray(st_a["precond"]["d"]["x"]),
                                      np.asarray(st_b["precond"]["d"]["x"]))
        np.testing.assert_array_equal(np.asarray(st_a["precond"]["t"]),
                                      np.asarray(st_b["precond"]["t"]))
    if "server" in st_b:
        np.testing.assert_array_equal(np.asarray(st_a["server"]["v"]["x"]),
                                      np.asarray(st_b["server"]["v"]["x"]))
        np.testing.assert_array_equal(np.asarray(st_a["server"]["m"]["x"]),
                                      np.asarray(st_b["server"]["m"]["x"]))


# --------------------------------------------------------------------------- #
# differential: fused == unfused == pre-PR reference, all six METHODS
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("method", engine.METHODS)
def test_fused_bit_identical_all_methods(problem, method):
    """The flat-buffer fused client loop emits the same trajectory as the
    unfused tree path AND the verbatim pre-PR engine snapshot — bitwise."""
    spec_f = engine.method_spec(method, **MS_KW, use_fused_kernel=True)
    assert spec_f.client.use_fused_kernel
    spec_u = engine.method_spec(method, **MS_KW)
    spec_r = ref_engine.method_spec(method, **MS_KW)
    st_f, met_f = _run(problem, engine.build_round_step, engine.init_state,
                       spec_f)
    st_u, met_u = _run(problem, engine.build_round_step, engine.init_state,
                       spec_u)
    st_r, met_r = _run(problem, ref_engine.build_round_step,
                       ref_engine.init_state, spec_r)
    _assert_state_bitwise(st_f, st_u)
    _assert_state_bitwise(st_f, st_r)
    assert float(met_f["loss"]) == float(met_u["loss"]) == float(met_r["loss"])


FAST_ENGINE_CASES = [
    # a representative slice of the kind × schedule × clip × scaling matrix
    # stays in tier-1 (the full sweep is the @slow test below)
    dict(kind="oasis", scaling="local"),              # rule-3 + Hutchinson
    dict(kind="adahessian", scaling="local", beta_schedule="debias"),
    dict(kind="adagrad", scaling="local"),            # accumulate limit
    dict(kind="rmsprop", scaling="global", clip="add"),
    dict(kind="adam", scaling="local", clip="add", beta_schedule="const"),
]


@pytest.mark.parametrize("case", FAST_ENGINE_CASES,
                         ids=lambda c: "-".join(str(v) for v in c.values()))
def test_fused_bit_identical_representative_kinds(problem, case):
    pcf = {k: v for k, v in case.items()
           if k in ("kind", "clip", "beta_schedule")}
    pc = PrecondConfig(alpha=1e-2, **pcf)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling=case["scaling"],
        use_fused_kernel=fused))
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True))
    st_u, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False))
    _assert_state_bitwise(st_f, st_u)


@pytest.mark.slow
@pytest.mark.parametrize("kind,schedule,clip,scaling", list(itertools.product(
    ("adam", "rmsprop", "adagrad", "oasis", "adahessian"),
    ("const", "debias"), ("max", "add"), ("global", "local"))))
def test_fused_bit_identical_full_matrix(problem, kind, schedule, clip,
                                         scaling):
    """Acceptance sweep: every PrecondConfig kind × β_t schedule × rule-4
    clip × scaling mode, fused vs unfused, bitwise (tier-2)."""
    pc = PrecondConfig(kind=kind, alpha=1e-2, beta_schedule=schedule,
                       clip=clip)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling=scaling, use_fused_kernel=fused))
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True), rounds=2)
    st_u, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False), rounds=2)
    _assert_state_bitwise(st_f, st_u)


@pytest.mark.parametrize("extra", [
    dict(grad_clip=0.5),
    dict(weight_decay=0.01),
    dict(local_steps=(1, 3, 2, 3)),
    dict(grad_clip=0.3, weight_decay=0.05, local_steps=(2, 1, 3, 3)),
], ids=["clip", "wd", "hm", "clip-wd-hm"])
def test_fused_bit_identical_compositions(problem, extra):
    """grad-clip (tree-order norm), weight decay, and heterogeneous-H_m
    masking all compose with the fused path bitwise: clipped grads freeze
    into the sync-stat carry exactly as in the tree path, and frozen clients
    keep their step-H_m flat state."""
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling="local", use_fused_kernel=fused,
        **extra))
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True))
    st_u, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False))
    _assert_state_bitwise(st_f, st_u)


def test_fused_masked_hutchinson(problem):
    """H_m masking freezes the per-client D and t of a Hutchinson kind at
    exactly the client's budget — fused vs unfused bitwise."""
    pc = PrecondConfig(kind="oasis", alpha=1e-2)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling="local", use_fused_kernel=fused,
        local_steps=(2, 3, 1, 3)))
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True))
    st_u, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False))
    _assert_state_bitwise(st_f, st_u)
    # frozen clients really did stop advancing t
    assert st_f["precond"]["t"].shape == (4,)
    np.testing.assert_array_equal(np.asarray(st_f["precond"]["t"]),
                                  3 * np.asarray([2, 3, 1, 3]))


def test_non_fp32_state_falls_back_to_tree_path(problem):
    """The flat view is an fp32 buffer by contract: bf16 client state takes
    the (bit-identical-to-itself) tree path instead of silently upcasting."""
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling="global", use_fused_kernel=fused))
    st_f, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True), dtype=jnp.bfloat16)
    st_u, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False), dtype=jnp.bfloat16)
    assert st_f["params"]["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(st_f["params"]["x"], np.float32),
                                  np.asarray(st_u["params"]["x"], np.float32))


# --------------------------------------------------------------------------- #
# kernel family vs the pure-jnp oracle (jit-vs-jit: FMA-consistent)
# --------------------------------------------------------------------------- #


def _kernel_buffers(M=3, n=300, seed=0):
    k = jax.random.key(seed)
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (M, n))
               for i in range(3))
    d_signed = jax.random.uniform(jax.random.fold_in(k, 3), (M, n),
                                  minval=-2.0, maxval=2.0)
    h = jax.random.normal(jax.random.fold_in(k, 4), (M, n))  # negative ok
    t = jnp.array([0, 3, 7], jnp.int32)[:M]
    s = jnp.array([1.0, 0.4, 0.9], jnp.float32)[:M]
    return p, m, g, d_signed, h, t, s


@pytest.mark.parametrize("kind,schedule,clip", list(itertools.product(
    ("adam", "rmsprop", "adagrad", "oasis", "adahessian"),
    ("const", "debias"), ("max", "add"))))
def test_kernel_matrix_local_vs_oracle(kind, schedule, clip):
    """Full kernel-level matrix, local D update: kernel == oracle to ≤ 1 ulp.
    OASIS runs on SIGNED d (the |d| magnitude path); Hutchinson kinds take
    the external stat operand, the Adam family the in-kernel grad² stat.

    Tolerance note: this compares two SEPARATELY compiled programs (the
    interpret-mode grid loop vs a plain jit of the oracle), where XLA:CPU may
    contract multiply-adds into FMAs differently — a 1-ulp effect.  The
    bit-exactness contract that matters is same-program-shape: engine fused
    vs unfused above are bitwise, and the padding tests below pin the kernel
    bitwise against the oracle where contraction agrees."""
    p, m, g, d_signed, h, t, s = _kernel_buffers()
    hutch = kind in ("oasis", "adahessian")
    d = d_signed if kind == "oasis" else jnp.abs(d_signed)
    hstat = h if hutch else None
    kw = dict(gamma=0.05, beta1=0.9, alpha=1e-2, beta2=0.99, kind=kind,
              clip=clip, schedule=schedule, update_d=True, weight_decay=0.01)
    po, mo, do = ops.fused_local_step(p, m, g, d, hstat, t, s, **kw)
    pr, mr, dr = jax.jit(
        lambda *a: ref.fused_step_ref(*a, **kw))(p, m, g, d, hstat, t, s)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(do), np.asarray(dr), rtol=1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("kind", ["adam", "oasis", "identity"])
def test_kernel_global_and_identity_vs_oracle(kind):
    """Global (client-shared (n,)) D and the identity kind: no D output, one
    kernel covers all clients."""
    p, m, g, d_signed, h, t, s = _kernel_buffers()
    d = None if kind == "identity" else \
        (d_signed[0] if kind == "oasis" else jnp.abs(d_signed[0]))
    kw = dict(gamma=0.05, beta1=0.9, alpha=1e-2, beta2=0.99, kind=kind,
              clip="max", schedule="const", update_d=False)
    po, mo, do = ops.fused_local_step(p, m, g, d, None, None, s, **kw)
    pr, mr, dr = jax.jit(
        lambda *a: ref.fused_step_ref(*a, **kw))(p, m, g, d, None, None, s)
    assert do is None and dr is None
    np.testing.assert_array_equal(np.asarray(po), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mo), np.asarray(mr))


def test_kernel_rejects_bad_modes():
    p, m, g, d_signed, h, t, s = _kernel_buffers()
    with pytest.raises(ValueError):
        ops.fused_local_step(p, m, g, None, None, t, None, gamma=0.1,
                             beta1=0.9, alpha=1e-2, kind="adam",
                             update_d=True)
    with pytest.raises(ValueError):
        ops.fused_local_step(p, m, g, jnp.abs(d_signed), None, None, None,
                             gamma=0.1, beta1=0.9, alpha=1e-2, kind="adam",
                             schedule="debias", update_d=True)


# --------------------------------------------------------------------------- #
# padding contract at n % BLOCK ∈ {0, 1, BLOCK−1}
# --------------------------------------------------------------------------- #


BLK = 128   # exercise the boundary cheaply via an explicit small block


@pytest.mark.parametrize("n", [BLK, 2 * BLK, BLK + 1, 2 * BLK - 1])
@pytest.mark.parametrize("kind", ["adam", "oasis", "adagrad"])
def test_fused_padding_boundaries(n, kind):
    """Outputs are bitwise the oracle's at every block-boundary residue, per
    rule — incl. the OASIS |d| path on signed state. The kernel pads nothing
    (Pallas masks the partial tail block), so the implicitly-padded tail
    lanes must never leak NaN/Inf into real outputs."""
    M = 2
    k = jax.random.key(n * 7 + len(kind))
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (M, n))
               for i in range(3))
    d = jax.random.uniform(jax.random.fold_in(k, 3), (M, n), minval=-2.0,
                           maxval=2.0)
    if kind != "oasis":
        d = jnp.abs(d)
    h = jax.random.normal(jax.random.fold_in(k, 4), (M, n)) \
        if kind == "oasis" else None
    t = jnp.zeros((M,), jnp.int32)
    kw = dict(gamma=0.05, beta1=0.9, alpha=1e-2, beta2=0.99, kind=kind,
              clip="max", schedule="debias", update_d=True)
    po, mo, do = su.fused_step_flat(p, m, g, d, h, t, None, block=BLK,
                                    interpret=True, **kw)
    pr, mr, dr = jax.jit(
        lambda *a: ref.fused_step_ref(*a, **kw))(p, m, g, d, h, t, None)
    np.testing.assert_array_equal(np.asarray(po), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mo), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(do), np.asarray(dr))
    for out in (po, mo, do):
        assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("n", [BLK, BLK + 1, 2 * BLK - 1])
@pytest.mark.parametrize("squared", [True, False])
def test_scaled_update_flat_padding_boundaries(n, squared):
    """The original per-leaf kernel under the audited padding (d → 1.0 keeps
    D̂ = 1 in the pad for BOTH √d and |d| magnitudes) at the same residues."""
    k = jax.random.key(n + squared)
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (n,))
               for i in range(3))
    d = jax.random.uniform(jax.random.fold_in(k, 3), (n,), minval=-1.5,
                           maxval=1.5)
    if squared:
        d = jnp.abs(d)
    kw = dict(gamma=0.1, beta1=0.9, alpha=1e-3, squared=squared)
    po, mo = ops.scaled_update(p, m, g, d, **kw)
    pr, mr = jax.jit(
        lambda *a: ref.scaled_update_ref(*a, **kw))(p, m, g, d)
    np.testing.assert_array_equal(np.asarray(po), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mo), np.asarray(mr))


# --------------------------------------------------------------------------- #
# flatten/unflatten round-trips on ragged leaves
# --------------------------------------------------------------------------- #


RAGGED_SHAPES = [
    {"a": (3,), "b": (2, 5), "c": ()},
    {"w1": (17, 33), "b1": (33,), "w2": (33, 7), "b2": (7,)},
    {"x": (1,)},
]


@pytest.mark.parametrize("shapes", RAGGED_SHAPES,
                         ids=["mixed", "mlp", "single"])
@pytest.mark.parametrize("batch_dims", [0, 1])
def test_flat_layout_round_trip(shapes, batch_dims):
    k = jax.random.key(0)
    lead = (4,) if batch_dims else ()
    tree = {name: jax.random.normal(jax.random.fold_in(k, i), lead + shp)
            for i, (name, shp) in enumerate(shapes.items())}
    layout = FlatLayout.for_tree(tree, batch_dims=batch_dims)
    buf = layout.flatten(tree, batch_dims=batch_dims)
    assert buf.shape == lead + (layout.n_total,)
    assert layout.n_total == sum(
        int(np.prod(s)) if s else 1 for s in shapes.values())
    back = layout.unflatten(buf, batch_dims=batch_dims)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    desc = layout.describe()
    assert desc["n_total"] == layout.n_total
    assert [l["path"] for l in desc["leaves"]] == list(layout.paths)


@given(st.lists(st.lists(st.integers(min_value=1, max_value=5), min_size=0,
                         max_size=3), min_size=1, max_size=6),
       st.integers(min_value=0, max_value=99))
@settings(max_examples=25, deadline=None)
def test_flat_layout_round_trip_property(shapes, seed):
    k = jax.random.key(seed)
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), tuple(shp))
            for i, shp in enumerate(shapes)}
    layout = FlatLayout.for_tree(tree)
    back = layout.unflatten(layout.flatten(tree))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(min_value=1, max_value=300), st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_fused_padding_property(n, seed):
    """Any n (ragged vs the 128-lane block) comes back bitwise — the
    implicit tail-block masking holds for arbitrary residues."""
    k = jax.random.key(seed)
    M = 2
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (M, n))
               for i in range(3))
    d = jnp.abs(jax.random.normal(jax.random.fold_in(k, 3), (M, n))) + 0.1
    kw = dict(gamma=0.05, beta1=0.9, alpha=1e-2, beta2=0.99, kind="rmsprop",
              clip="max", schedule="const", update_d=True)
    po, mo, do = su.fused_step_flat(p, m, g, d, None, None, None, block=BLK,
                                    interpret=True, **kw)
    pr, mr, dr = jax.jit(
        lambda *a: ref.fused_step_ref(*a, **kw))(p, m, g, d, None, None, None)
    np.testing.assert_array_equal(np.asarray(po), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(do), np.asarray(dr))


# --------------------------------------------------------------------------- #
# launch layer: flat-view layout in BuiltStep meta, shardings unchanged
# --------------------------------------------------------------------------- #


def test_build_train_step_records_flat_layout():
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built_f = build_train_step("qwen2-0.5b", shape, mesh, method="local-adam",
                               reduced=True, h_local=2, use_fused_kernel=True)
    built_u = build_train_step("qwen2-0.5b", shape, mesh, method="local-adam",
                               reduced=True, h_local=2)
    assert built_f.meta["engine_spec"].client.use_fused_kernel
    lay = built_f.meta["flat_layout"]
    state_shape = built_f.args[0]
    n_params = sum(int(np.prod(s.shape[1:]))
                   for s in jax.tree.leaves(state_shape["params"]))
    assert lay["n_total"] == n_params
    assert "flat_layout" not in built_u.meta
    # the flat view is an in-round representation: state pytree, shardings
    # and donation are those of the tree path, unchanged
    assert jax.tree.structure(built_f.args[0]) \
        == jax.tree.structure(built_u.args[0])
    sf = jax.tree.map(str, built_f.in_shardings[0])
    uf = jax.tree.map(str, built_u.in_shardings[0])
    assert sf == uf
    assert built_f.donate == built_u.donate == (0,)


def test_build_train_step_sharded_params_take_shard_mapped_path():
    """The launch layer no longer strips the fused path on sharded plans
    (DESIGN.md §7): a plan that shards params within a client (here
    plain-mode FSDP) keeps ``use_fused_kernel`` and runs the fused step per
    shard via shard_map, recording the per-shard flat layout instead of a
    fallback (the full multi-device contract lives in
    tests/test_fused_sharded.py)."""
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             mode="plain", reduced=True, h_local=2,
                             use_fused_kernel=True)
    assert built.meta["engine_spec"].client.use_fused_kernel
    assert "fused_kernel_fallback" not in built.meta
    assert "flat_layout" not in built.meta
    lay = built.meta["flat_layout_sharded"]
    # plain mode on this 1x1 mesh: FSDP over ('model', 'data') extents 1 —
    # every leaf degenerates to one replicated shard block
    assert lay["n_shards"] == 1
    state_shape = built.args[0]
    n_params = sum(int(np.prod(s.shape[1:]))
                   for s in jax.tree.leaves(state_shape["params"]))
    assert lay["n_flat"] == n_params
