"""Matrix-core tests (DESIGN.md §11): deterministic expansion, axis-product
counts, fixed-param precedence, row construction, and the benchalot-style
``update-output`` invariant — CSV regeneration from stored JSON must be
byte-identical and must never invoke a runner."""
import json
import os

import pytest

from benchmarks import matrix
from benchmarks.matrix import BenchDef, MatrixConfig, make_row


def _cfg(**kw):
    base = dict(name="toy", axes={"method": ("a", "b"), "arm": ("x", "y", "z")},
                fixed={"rounds": 3, "clients": 8})
    base.update(kw)
    return MatrixConfig.make(**base)


# --------------------------------------------------------------------------- #
# expansion
# --------------------------------------------------------------------------- #


def test_expand_deterministic_order():
    a = matrix.expand(_cfg())
    b = matrix.expand(_cfg())
    assert [p.coords for p in a] == [p.coords for p in b]
    # declared axis order, last axis fastest
    assert [p.coords for p in a[:4]] == [
        {"method": "a", "arm": "x"}, {"method": "a", "arm": "y"},
        {"method": "a", "arm": "z"}, {"method": "b", "arm": "x"}]


def test_expand_axis_product_counts():
    assert len(matrix.expand(_cfg())) == 2 * 3
    assert len(matrix.expand(_cfg(samples=4))) == 2 * 3 * 4
    assert len(matrix.expand(_cfg(), limit=5)) == 5
    assert len(matrix.expand(_cfg(), select={"arm": ("y",)})) == 2


def test_expand_select_unknown_axis_and_empty():
    with pytest.raises(KeyError):
        matrix.expand(_cfg(), select={"nope": ("a",)})
    with pytest.raises(ValueError):
        matrix.expand(_cfg(), select={"arm": ("missing",)})


def test_expand_samples_seed_policy():
    pts = matrix.expand(_cfg(samples=3, seed0=7))
    assert [p.seed for p in pts[:3]] == [7, 8, 9]          # samples innermost
    assert [p.coords["sample"] for p in pts[:3]] == [0, 1, 2]
    assert "sample" in _cfg(samples=3).coord_keys()
    assert "sample" not in _cfg().coord_keys()


def test_fixed_param_override_precedence():
    pts = matrix.expand(_cfg(), overrides={"rounds": 99, "new_knob": 1})
    assert pts[0].fixed == {"rounds": 99, "clients": 8, "new_knob": 1}
    assert matrix.expand(_cfg())[0].fixed == {"rounds": 3, "clients": 8}


# --------------------------------------------------------------------------- #
# rows
# --------------------------------------------------------------------------- #


def test_make_row_partitions_numeric_vs_info():
    row = make_row({"method": "a"},
                   {"loss": 1.5, "rounds": 3, "flag": True,
                    "curve": [1, 2], "tag": "x"},
                   rev="r1")
    assert row["metrics"] == {"loss": 1.5, "rounds": 3}   # bools are not metrics
    assert row["info"] == {"flag": True, "curve": [1, 2], "tag": "x"}
    assert row["git_rev"] == "r1"


def test_make_row_scalarizes_numpy():
    np = pytest.importorskip("numpy")
    row = make_row({"k": np.float64(0.5)}, {"v": np.int64(3)}, rev="r")
    assert type(row["coords"]["k"]) is float
    assert type(row["metrics"]["v"]) is int


# --------------------------------------------------------------------------- #
# update-output: byte-identical CSV from stored JSON, zero reruns
# --------------------------------------------------------------------------- #


def _toy_doc(rev="r1"):
    return {
        "schema_version": matrix.SCHEMA_VERSION, "bench": "toy",
        "git_rev": rev, "config": {"rounds": 3},
        "axes": ["method", "arm"],
        "rows": [
            make_row({"method": "a", "arm": "x"}, {"loss": 0.5, "ms": 1.25},
                     rev=rev),
            make_row({"method": "a", "arm": "y"}, {"loss": 0.25}, rev=rev),
        ],
    }


def test_update_output_byte_identical_no_rerun(tmp_path, monkeypatch):
    out, res = str(tmp_path), str(tmp_path / "results")
    json_path, csv_path = matrix.write_outputs(_toy_doc(), out_dir=out,
                                               results_dir=res)
    first = open(csv_path, "rb").read()
    os.remove(csv_path)

    # a registry whose runner must NEVER fire during update-output
    def _boom(point, ctx):
        raise AssertionError("update-output invoked a runner")

    monkeypatch.setitem(matrix.REGISTRY, "toy", BenchDef(
        "toy", _cfg(), _boom,
        summary=lambda doc: [("n_rows", len(doc["rows"]))]))
    doc, regen = matrix.update_output(json_path, results_dir=res)
    assert open(regen, "rb").read() == first
    assert matrix.summarize(doc) == [("n_rows", 2)]


def test_render_csv_missing_metrics_are_empty_cells():
    csv = matrix.render_csv(_toy_doc())
    lines = csv.splitlines()
    assert lines[0] == "method,arm,loss,ms,git_rev"   # first-seen metric order
    assert lines[2] == "a,y,0.25,,r1"                 # missing ms -> empty


def test_write_outputs_rejects_invalid():
    doc = _toy_doc()
    doc["rows"][0]["git_rev"] = ""
    with pytest.raises(ValueError):
        matrix.write_outputs(doc, out_dir="/tmp/never", results_dir="/tmp/never")


def test_run_bench_tags_rows_and_merges_config(tmp_path, monkeypatch):
    cfg = MatrixConfig.make("toy", {"method": ("a", "b")}, fixed={"rounds": 2})

    def _run(point, ctx):
        ctx.setdefault("config_extra", {})["backend"] = "cpu"
        return [make_row(point.coords, {"loss": 1.0 if point.coords["method"]
                                        == "a" else 2.0})]

    monkeypatch.setitem(matrix.REGISTRY, "toy", BenchDef("toy", cfg, _run))
    doc = matrix.run_bench("toy", out_dir=str(tmp_path),
                           results_dir=str(tmp_path / "r"),
                           overrides={"rounds": 5})
    assert doc["config"]["rounds"] == 5                  # override precedence
    assert doc["config"]["backend"] == "cpu"             # ctx config_extra
    assert [r["coords"] for r in doc["rows"]] == [{"method": "a"},
                                                  {"method": "b"}]
    rev = doc["git_rev"]
    assert rev and all(r["git_rev"] == rev for r in doc["rows"])
    assert not matrix.validate_doc(json.load(
        open(tmp_path / "BENCH_toy.json")))
