"""Shard-mapped fused local step — the multi-device differential suite
(DESIGN.md §7, per-shard flat contract).

Two layers:

  * in-process (tier-1): ``ShardFlatLayout`` boundary behavior — uneven leaf
    splits (dim % shard count ∈ {0, 1, shards−1}), a leaf smaller than one
    shard, multi-axis entries, round-trip flatten/unflatten properties
    (deterministic + hypothesis via the _hypothesis_compat shim) — plus the
    degenerate 1-device shard_map engine path pinned bitwise against the
    unsharded fused and tree paths.
  * subprocess (tier-2 @slow; 8 host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, same pattern as
    tests/_sharding_worker.py): tests/_fused_sharded_worker.py pins the
    shard-mapped fused path BITWISE (fp32) against the live tree path and the
    verbatim pre-PR engine snapshot (tests/_reference_engine.py) for all six
    METHODS on model-, FSDP-, and mixed client×model plans, the H_m masking
    composition, the shard_map flatten/unflatten against the mesh-free
    reference, and the HLO collective pins: the per-step flat program carries
    ZERO collective bytes (the resharding blowup that motivated the old
    launch-layer gate can never silently return) while the naive global flat
    view measurably reshards.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from _hypothesis_compat import given, settings, st
from repro.core import engine
from repro.utils.flatten import FlatLayout, ShardedFlatPlan, ShardFlatLayout

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _worker(mode: str, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + os.path.join(ROOT, "tests")
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_fused_sharded_worker.py"), mode],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"ALL-OK {mode}" in r.stdout
    return r.stdout


# --------------------------------------------------------------------------- #
# ShardFlatLayout boundaries (in-process; layout + reference ops are mesh-free)
# --------------------------------------------------------------------------- #


MESH_SHAPE = {"model": 4, "data": 2}


def _rand_tree(shapes, lead=(), seed=0):
    k = jax.random.key(seed)
    return {name: jax.random.normal(jax.random.fold_in(k, i), lead + shp)
            for i, (name, shp) in enumerate(shapes.items())}


@pytest.mark.parametrize("dim,split", [
    (12, True),    # dim % shards == 0: split, local extent 3
    (13, False),   # dim % shards == 1: uneven -> replicated fallback
    (15, False),   # dim % shards == shards-1: uneven -> replicated fallback
])
def test_uneven_leaf_splits(dim, split):
    tree = _rand_tree({"w": (dim,)})
    lay = ShardFlatLayout.for_tree(tree, {"w": P("model")}, MESH_SHAPE,
                                   ("model",))
    leaf = lay.describe()["leaves"][0]
    assert leaf["split"] == split
    assert leaf["uneven_fallback"] == (not split)
    assert lay.n_local == (dim // 4 if split else dim)
    assert lay.n_flat == 4 * lay.n_local
    back = lay.unflatten_ref(lay.flatten_ref(tree))
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_leaf_smaller_than_one_shard():
    """A (2,) leaf under 4 shards cannot split: it rides replicated in every
    shard block, exactly as GSPMD keeps such leaves per device."""
    tree = _rand_tree({"w": (12,), "tiny": (2,)})
    lay = ShardFlatLayout.for_tree(tree, {"w": P("model"), "tiny": P("model")},
                                   MESH_SHAPE, ("model",))
    desc = {l["path"]: l for l in lay.describe()["leaves"]}
    assert desc["tiny"]["uneven_fallback"] and not desc["tiny"]["split"]
    assert lay.n_local == 12 // 4 + 2
    back = lay.unflatten_ref(lay.flatten_ref(tree))
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_multi_axis_entry_and_dim1_split():
    """P(('data', 'model')) splits a dim over both axes (major-first), and a
    dim-1 split leaves dim 0 intact — with batch dims preserved."""
    tree = _rand_tree({"a": (3, 16), "b": (16, 5)}, lead=(2,))
    specs = {"a": P(None, ("data", "model")), "b": P("model", None)}
    lay = ShardFlatLayout.for_tree(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                     tree), specs, MESH_SHAPE, ("data", "model"))
    desc = {l["path"]: l for l in lay.describe()["leaves"]}
    assert desc["a"]["local_shape"] == [3, 2]     # 16 / (2*4)
    assert desc["b"]["local_shape"] == [4, 5]     # 16 / 4, 'data' untouched
    assert lay.n_shards == 8
    buf = lay.flatten_ref(tree, batch_dims=1)
    assert buf.shape == (2, lay.n_flat)
    back = lay.unflatten_ref(buf, batch_dims=1)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_single_shard_degenerates_to_flat_layout():
    """n_shards == 1 (all extents 1): the shard-local view IS the global flat
    view — same n_total, same content order."""
    tree = _rand_tree({"w": (7, 3), "b": (5,)})
    lay = ShardFlatLayout.for_tree(tree, {"w": P(None, "model"), "b": P()},
                                   {"model": 1}, ("model",))
    flat = FlatLayout.for_tree(tree)
    assert lay.n_shards == 1 and lay.n_flat == flat.n_total
    np.testing.assert_array_equal(np.asarray(lay.flatten_ref(tree)),
                                  np.asarray(flat.flatten(tree)))


def test_alien_axis_rejected():
    tree = _rand_tree({"w": (8,)})
    with pytest.raises(ValueError, match="outside the shard axes"):
        ShardFlatLayout.for_tree(tree, {"w": P("data")}, MESH_SHAPE,
                                 ("model",))


def test_spec_leaf_count_mismatch_rejected():
    tree = _rand_tree({"w": (8,), "b": (3,)})
    with pytest.raises(ValueError, match="leaves"):
        ShardFlatLayout.for_tree(tree, {"w": P("model")}, MESH_SHAPE,
                                 ("model",))


@given(st.lists(st.tuples(st.integers(1, 24), st.booleans()), min_size=1,
                max_size=5),
       st.integers(min_value=1, max_value=4), st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_shard_flat_round_trip_property(dims, shards, seed):
    """Any mix of split/uneven/replicated 1-D leaves round-trips bitwise
    through the shard-local flat view, for any shard count."""
    shapes = {f"l{i}": (d,) for i, (d, _) in enumerate(dims)}
    specs = {f"l{i}": (P("model") if want else P())
             for i, (_, want) in enumerate(dims)}
    tree = _rand_tree(shapes, seed=seed)
    lay = ShardFlatLayout.for_tree(tree, specs, {"model": shards}, ("model",))
    buf = lay.flatten_ref(tree)
    assert buf.shape == (lay.n_flat,)
    back = lay.unflatten_ref(buf)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


# --------------------------------------------------------------------------- #
# degenerate 1-device shard_map engine path (tier-1 guard for the real thing)
# --------------------------------------------------------------------------- #


def _quad_problem():
    from repro.data import QuadraticProblem
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _run_engine(problem, spec, shard_plan=None, rounds=3, H=3, n_clients=4):
    from repro.data import QuadraticLoader
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    step = jax.jit(engine.build_round_step(loss, spec, shard_plan))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec, n_clients)
    loader = QuadraticLoader(problem, seed=0)
    key = jax.random.PRNGKey(1)
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
    return state, met


@pytest.mark.parametrize("method", ["savic", "fedadam", "local-adam"])
def test_one_device_shard_plan_bitwise(method):
    """The shard_map code path itself (flatten/kernel/unflatten inside
    shard_map) on a 1-device mesh: bitwise vs the unsharded fused path and
    the tree path — the in-process guard for the 8-device worker suite."""
    problem = _quad_problem()
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    params_one = {"x": jax.ShapeDtypeStruct((24,), jnp.float32)}
    plan = ShardedFlatPlan.build(mesh, params_one, {"x": P("model")},
                                 ("model",), client=("data",))
    kw = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)
    spec_f = engine.method_spec(method, **kw, use_fused_kernel=True)
    spec_u = engine.method_spec(method, **kw)
    st_s, met_s = _run_engine(problem, spec_f, shard_plan=plan)
    st_f, met_f = _run_engine(problem, spec_f)
    st_u, met_u = _run_engine(problem, spec_u)
    for st_b in (st_f, st_u):
        np.testing.assert_array_equal(np.asarray(st_s["params"]["x"]),
                                      np.asarray(st_b["params"]["x"]))
        np.testing.assert_array_equal(np.asarray(st_s["mom"]["x"]),
                                      np.asarray(st_b["mom"]["x"]))
        if "d" in st_b["precond"]:
            np.testing.assert_array_equal(
                np.asarray(st_s["precond"]["d"]["x"]),
                np.asarray(st_b["precond"]["d"]["x"]))
    assert float(met_s["loss"]) == float(met_f["loss"]) == float(met_u["loss"])


# --------------------------------------------------------------------------- #
# the 8-device subprocess suite (tier-2)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_sharded_differential_fast():
    """Representative slice: flatten-oracle pins + {savic, fedadam,
    local-adam} on the mixed client×model plan, bitwise."""
    _worker("fast")


@pytest.mark.slow
def test_sharded_differential_full_matrix():
    """Acceptance sweep: all six METHODS × {model, fsdp, mixed} plans,
    shard-mapped fused vs tree vs pre-PR reference, bitwise."""
    out = _worker("full", timeout=1200)
    for method in engine.METHODS:
        for plan in ("model", "fsdp", "mixed"):
            assert f"OK diff {plan}/{method}" in out


@pytest.mark.slow
def test_sharded_hlo_collective_pins():
    """HLO regression: the per-local-step program under a sharded plan
    contains NO collective touching the flat buffers (pinned at exactly 0
    bytes), the fused round program moves exactly the tree path's collective
    bytes (sync traffic only), and the naive global flat view — the measured
    blowup that motivated the old launch-layer gate — still reshards (> 0
    bytes per step), so the regression can never silently return."""
    out = _worker("hlo")
    rec = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT ")][0][len("RESULT "):])
    assert rec["step_collective_bytes_sharded"] == 0
    assert rec["step_collective_by_kind_sharded"] == {}
    assert rec["step_collective_bytes_naive"] > 0
    assert rec["round_collective_bytes_fused"] \
        == rec["round_collective_bytes_tree"]
