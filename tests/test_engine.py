"""Round-engine tests: regression against the pre-refactor monoliths,
SyncStrategy coverage (participation / quantized sync), adaptive-server
methods end-to-end, and the build_train_step method selector."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_fedopt as ref_fedopt
import _reference_savic as ref_savic
from repro.core import engine, fedopt, savic
from repro.core.preconditioner import PrecondConfig
from repro.core.savic import SavicConfig
from repro.data import QuadraticLoader, QuadraticProblem


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _trajectories(problem, step_a, state_a, step_b, state_b, rounds=6, H=5,
                  seed=0):
    """Run two round implementations on identical fixed-seed batches; return
    the per-round (params_a, params_b) pairs."""
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    out = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        state_a, met_a = step_a(state_a, batch, k)
        state_b, met_b = step_b(state_b, batch, k)
        out.append((state_a, state_b, met_a, met_b))
    return out


# --------------------------------------------------------------------------- #
# regression: engine-based SAVIC == pre-refactor monolith (fixed seed)
# --------------------------------------------------------------------------- #


SAVIC_REGRESSION_CASES = {
    "adam-global-momentum": (
        PrecondConfig(kind="adam", alpha=1e-2),
        dict(gamma=0.03, beta1=0.9)),
    "oasis-local": (
        PrecondConfig(kind="oasis", alpha=1e-2),
        dict(gamma=0.03, beta1=0.5, scaling="local")),
    "rmsprop-avg-local-stat": (
        PrecondConfig(kind="rmsprop", alpha=1e-2),
        dict(gamma=0.03, beta1=0.0, stat_source="avg_local")),
    "identity-participation-bf16": (
        PrecondConfig(kind="identity"),
        dict(gamma=0.03, beta1=0.0, participation=0.5,
             sync_dtype="bfloat16")),
}


@pytest.mark.parametrize("case", list(SAVIC_REGRESSION_CASES))
def test_savic_engine_matches_prerefactor(problem, case):
    """The engine emits the same program the monolithic savic.py did:
    trajectories agree bit-for-bit (asserted to fp32 tolerance) for every
    layer combination — scaling kind, momentum, stat source, participation,
    quantized sync."""
    pc, sv_kw = SAVIC_REGRESSION_CASES[case]
    loss = _quad_loss(problem)
    sv_new = SavicConfig(**sv_kw)
    sv_old = ref_savic.SavicConfig(**sv_kw)
    step_new = jax.jit(savic.build_round_step(loss, pc, sv_new))
    step_old = jax.jit(ref_savic.build_round_step(loss, pc, sv_old))
    init = lambda k: {"x": jnp.zeros(problem.b.shape[1])}
    st_new = savic.init_state(jax.random.PRNGKey(0), init, pc, sv_new, 4)
    st_old = ref_savic.init_state(jax.random.PRNGKey(0), init, pc, sv_old, 4)
    for st_n, st_o, met_n, met_o in _trajectories(problem, step_new, st_new,
                                                  step_old, st_old):
        np.testing.assert_allclose(np.asarray(st_n["params"]["x"]),
                                   np.asarray(st_o["params"]["x"]),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(met_n["loss"]), float(met_o["loss"]),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(met_n["client_drift"]),
                                   float(met_o["client_drift"]), rtol=1e-5,
                                   atol=1e-9)


@pytest.mark.parametrize("server_opt", ["adagrad", "adam", "yogi"])
def test_fedopt_engine_matches_prerefactor(problem, server_opt):
    """Engine-based FedOpt reproduces the pre-refactor trajectories to fp32
    tolerance (the engine averages post-step params then subtracts x_t, the
    monolith averaged per-client deltas — identical up to float summation
    order)."""
    loss = _quad_loss(problem)
    kw = dict(server_opt=server_opt, eta=0.1, eta_l=0.02, tau=1e-2)
    cfg_new = fedopt.FedOptConfig(**kw)
    cfg_old = ref_fedopt.FedOptConfig(**kw)
    step_new = jax.jit(fedopt.build_round_step(loss, cfg_new))
    step_old = jax.jit(ref_fedopt.build_round_step(loss, cfg_old))
    init = lambda k: {"x": jnp.zeros(problem.b.shape[1])}
    st_new = fedopt.init_state(jax.random.PRNGKey(0), init, cfg_new)
    st_old = ref_fedopt.init_state(jax.random.PRNGKey(0), init, cfg_old)
    for st_n, st_o, met_n, met_o in _trajectories(problem, step_new, st_new,
                                                  step_old, st_old):
        np.testing.assert_allclose(np.asarray(st_n["params"]["x"]),
                                   np.asarray(st_o["params"]["x"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_n["v"]["x"]),
                                   np.asarray(st_o["v"]["x"]),
                                   rtol=1e-5, atol=1e-8)
        np.testing.assert_allclose(float(met_n["step_norm"]),
                                   float(met_o["step_norm"]), rtol=1e-4)


# --------------------------------------------------------------------------- #
# SyncStrategy: participation weights + quantized sync error bound
# --------------------------------------------------------------------------- #


def test_participation_weights_sum_to_one():
    key = jax.random.PRNGKey(0)
    for M, part in [(4, 0.5), (8, 0.25), (8, 1.0), (5, 0.3), (3, 0.01)]:
        w = np.asarray(engine.participation_weights(
            engine.SyncSpec(participation=part), key, M))
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
        n_part = max(1, int(round(part * M)))
        assert (w > 0).sum() == n_part
        np.testing.assert_allclose(w[w > 0], 1.0 / n_part, rtol=1e-6)


def test_partial_participation_only_sampled_clients_enter_mean():
    """With participation<1 the sync average is the plain mean of exactly the
    sampled subset — non-participants contribute nothing."""
    M, d = 8, 16
    key = jax.random.PRNGKey(7)
    spec = engine.SyncSpec(participation=0.5)
    w = np.asarray(engine.participation_weights(spec, key, M))
    avg = engine.make_sync(spec, key, M)
    vals = jnp.asarray(np.random.default_rng(0).normal(size=(M, d)),
                       jnp.float32)
    got = np.asarray(avg(vals))
    sampled = np.where(w > 0)[0]
    assert len(sampled) == 4
    np.testing.assert_allclose(got, np.asarray(vals)[sampled].mean(axis=0),
                               rtol=1e-5, atol=1e-6)
    # and the weighted mean ignores non-participants entirely
    vals_poisoned = np.asarray(vals).copy()
    vals_poisoned[[m for m in range(M) if m not in set(sampled)]] = 1e9
    got_p = np.asarray(avg(jnp.asarray(vals_poisoned)))
    np.testing.assert_allclose(got_p, got, rtol=1e-6)


def test_sync_dtype_quantization_error_bounded():
    """bf16 sync average stays within the representation's relative error of
    the full-precision average (~2^-8 per element; bound used: 2^-7 on the
    value scale)."""
    M, d = 8, 256
    key = jax.random.PRNGKey(3)
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32) * 3.0)
    full = np.asarray(engine.make_sync(engine.SyncSpec(), key, M)(vals))
    quant = np.asarray(engine.make_sync(
        engine.SyncSpec(sync_dtype="bfloat16"), key, M)(vals),
        dtype=np.float32)
    scale = np.abs(np.asarray(vals)).max()
    err = np.abs(quant - full).max()
    assert err <= scale * 2.0 ** -7, (err, scale)
    assert err > 0.0   # it really is quantized, not a no-op


# --------------------------------------------------------------------------- #
# adaptive-server methods end-to-end through the engine
# --------------------------------------------------------------------------- #


def _run_method(problem, spec, rounds=40, H=5, seed=0):
    loss = _quad_loss(problem)
    step = jax.jit(engine.build_round_step(loss, spec))
    M, d = problem.b.shape
    state = engine.init_state(jax.random.PRNGKey(seed),
                              lambda k: {"x": jnp.zeros(d)}, spec, M)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    mets = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        state, met = step(state, batch, k)
        mets.append({k2: float(v) for k2, v in met.items()
                     if np.ndim(v) == 0})
    return state, mets


def test_fedadam_preset_converges(problem):
    spec = engine.method_spec("fedadam", eta=0.1, eta_l=0.02, tau=1e-2)
    state, mets = _run_method(problem, spec)
    assert "server" in state and "m" in state["server"]
    assert mets[-1]["loss"] < mets[0]["loss"]
    assert all("step_norm" in m for m in mets)


def test_local_adam_composed_scenario_converges(problem):
    """The new composed method (cf. 2409.13155): per-client Adam scaling
    updated every local step AND an adaptive Adam server on Δ."""
    spec = engine.method_spec("local-adam", pc_kind="adam", alpha=1e-2,
                              eta=0.05, eta_l=0.01, tau=1e-2)
    assert spec.client.scaling == "local"
    assert spec.server.kind == "adaptive"
    state, mets = _run_method(problem, spec, rounds=50)
    # local scaling state carries the client dim; server m/v do not
    assert state["precond"]["d"]["x"].shape == (4, 24)
    assert state["server"]["m"]["x"].shape == (24,)
    assert mets[-1]["loss"] < mets[0]["loss"]


def test_every_method_spec_resolves_and_steps(problem):
    """One round of every preset runs and returns finite metrics."""
    loss = _quad_loss(problem)
    loader = QuadraticLoader(problem, seed=0)
    for method in engine.METHODS:
        spec = engine.method_spec(method, gamma=0.01, alpha=1e-2,
                                  eta_l=0.01, eta=0.05)
        step = jax.jit(engine.build_round_step(loss, spec))
        state = engine.init_state(jax.random.PRNGKey(0),
                                  lambda k: {"x": jnp.zeros(24)}, spec, 4)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(3))
        state, met = step(state, batch, jax.random.PRNGKey(1))
        assert np.isfinite(float(met["loss"])), method
        assert int(state["round"]) == 1, method


# --------------------------------------------------------------------------- #
# launch layer: build_train_step method selector + sharding-spec derivation
# --------------------------------------------------------------------------- #


def _tiny_mesh():
    from jax.sharding import Mesh
    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


@pytest.mark.parametrize("method", ["savic", "fedadam", "local-adam"])
def test_build_train_step_method_selector(method):
    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built = build_train_step("qwen2-0.5b", shape, _tiny_mesh(), method=method,
                             reduced=True, h_local=2)
    assert built.meta["method"] == method
    state_shape = built.args[0]
    state_spec, _ = built.in_shardings
    if method == "savic":
        assert "server" not in state_shape
    else:
        # adaptive server: m/v shaped like ONE replica, specs derived
        p0 = jax.tree.leaves(state_shape["params"])[0]
        m0 = jax.tree.leaves(state_shape["server"]["m"])[0]
        assert m0.shape == p0.shape[1:]
        assert jax.tree.structure(state_spec["server"]["m"]) \
            == jax.tree.structure(state_shape["server"]["m"])
    if method == "local-adam":
        # per-client D: leading client dim on both d and t
        p0 = jax.tree.leaves(state_shape["params"])[0]
        d0 = jax.tree.leaves(state_shape["precond"]["d"])[0]
        assert d0.shape[0] == p0.shape[0]
        assert state_shape["precond"]["t"].shape == (p0.shape[0],)


def test_build_train_step_fedadam_executes():
    """Acceptance: build_train_step(..., method='fedadam') runs end-to-end —
    compile with the derived shardings and take one real round step."""
    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    mesh = _tiny_mesh()
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             reduced=True, h_local=2)
    with mesh:
        fn = jax.jit(built.fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings)
        key = jax.random.PRNGKey(0)
        spec = engine.method_spec("fedadam")
        from repro.configs import get_config
        from repro.models import ModelCallConfig, build as build_model
        model = build_model(get_config("qwen2-0.5b", reduced=True),
                            ModelCallConfig())
        state = engine.init_state(key, model.init, spec, 1)
        batch = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) if s.dtype != jnp.int32
            else jnp.ones(s.shape, jnp.int32), built.args[1])
        new_state, metrics = fn(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(new_state["round"]) == 1
