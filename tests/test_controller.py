"""Adaptive communication-budget controller — the differential harness.

Locks down core/controller.py + its engine threading (DESIGN.md §10):
  * identity contract: the disabled default ``ControllerSpec()`` emits the
    BIT-EXACT pre-controller program (vs tests/_reference_engine.py) for all
    six METHODS, and adds no state leaf / no metric;
  * ``controller_step`` replays bitwise against the numpy float32 oracle
    (tests/_reference_controller.py) over long random observation streams;
  * engine integration: a full adaptive run's knob trajectory is reproduced
    by the oracle FROM THE LOGGED METRICS ALONE — the logs are a complete
    replay record;
  * a frozen controller (h_min = h_max, k_min = k_max, no buffer) is
    bitwise-identical to the equivalent static spec: knob plumbing through
    masking adds no arithmetic;
  * checkpoint round-trip: the ``ctrl`` leaf rides the state pytree bitwise;
  * server m/v compression (ServerSpec.sync_dtype/sync_k): identity default,
    top-|m| shared-mask semantics with the v_init floor, wire accounting;
  * spec/build validation and the straggler-skip budget rule.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_controller as ref_ctrl
import _reference_engine as ref_engine
from repro.checkpoint import restore, save
from repro.core import controller as CTRL
from repro.core import engine
from repro.data import QuadraticLoader, QuadraticProblem
from repro.utils.tree import tree_paths


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, spec, rounds=4, H=3, seed=0, n_clients=4, collect=False):
    loss = _quad_loss(problem)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec, n_clients)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    mets = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
        if collect:
            mets.append(jax.tree.map(np.asarray, met))
    return (state, mets) if collect else (state, met)


MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)


# --------------------------------------------------------------------------- #
# identity: disabled controller == pre-controller engine, bitwise, 6 methods
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("method", engine.METHODS)
def test_disabled_controller_bit_identical_to_prepr_engine(problem, method):
    """``ControllerSpec()`` (the default, disabled) changes NOTHING: state and
    metrics agree bitwise with the verbatim pre-controller engine snapshot."""
    spec_new = engine.method_spec(method, **MS_KW,
                                  controller=engine.ControllerSpec())
    assert not spec_new.controller.enabled
    spec_ref = ref_engine.method_spec(method, **MS_KW)

    loss = _quad_loss(problem)
    st_new = engine.init_state(jax.random.PRNGKey(0),
                               lambda k: {"x": jnp.zeros(24)}, spec_new, 4)
    st_ref = ref_engine.init_state(jax.random.PRNGKey(0),
                                   lambda k: {"x": jnp.zeros(24)}, spec_ref, 4)
    assert "ctrl" not in st_new
    step_new = jax.jit(engine.build_round_step(loss, spec_new))
    step_ref = jax.jit(ref_engine.build_round_step(loss, spec_ref))
    loader_a, loader_b = (QuadraticLoader(problem, seed=0) for _ in range(2))
    key = jax.random.PRNGKey(1)
    for _ in range(4):
        key, k = jax.random.split(key)
        ba = jax.tree.map(jnp.asarray, loader_a.round_batch(3))
        bb = jax.tree.map(jnp.asarray, loader_b.round_batch(3))
        st_new, met_new = step_new(st_new, ba, k)
        st_ref, met_ref = step_ref(st_ref, bb, k)
    got = dict(tree_paths(st_new))
    for p, leaf in tree_paths(st_ref):
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(leaf),
                                      err_msg=p)
    assert float(met_new["loss"]) == float(met_ref["loss"])
    assert "ctrl_h_m" not in met_new


# --------------------------------------------------------------------------- #
# controller_step == numpy oracle, bitwise
# --------------------------------------------------------------------------- #


def _assert_ctrl_state_matches(jstate, nstate, msg=""):
    """Integer knobs + k bitwise; EMA floats to 1 ulp (LLVM FMA contraction
    of the traced mul+add — see _reference_controller's module docstring)."""
    want = dict(tree_paths(nstate))
    for p, leaf in tree_paths(jstate):
        if "ema" in p:
            np.testing.assert_allclose(np.asarray(leaf), want[p], rtol=3e-7,
                                       err_msg=f"{msg} leaf {p}")
        else:
            np.testing.assert_array_equal(np.asarray(leaf), want[p],
                                          err_msg=f"{msg} leaf {p}")


CTRL_SPECS = [
    CTRL.ControllerSpec(enabled=True, h_min=1, h_max=6, noise_target=0.5,
                        k_min=0.1, resid_guard=0.4,
                        step_times=(1.0, 1.3, 2.0, 2.6)),
    CTRL.ControllerSpec(enabled=True, h_min=2, h_max=8, noise_target=2.0,
                        h_growth=2.0, ema=0.5, k_min=0.25, k_max=0.5,
                        k_shrink=0.5, k_growth=2.0, buffer_max=3,
                        spread_per_slot=0.8, step_times=(1.0, 1.7, 3.4, 4.2)),
    CTRL.ControllerSpec(enabled=True, h_min=1, h_max=4),  # homogeneous
]


@pytest.mark.parametrize("si", range(len(CTRL_SPECS)))
def test_controller_step_matches_numpy_oracle(si):
    """40 steps of random observations: jit-traced controller_step and the
    numpy oracle agree — integer knobs and k bitwise, EMAs to 1 ulp."""
    spec = CTRL_SPECS[si]
    M = len(spec.step_times) or 4
    rng = np.random.default_rng(si)
    jstate = CTRL.init_ctrl_state(spec, M)
    nstate = ref_ctrl.init_ctrl_state(spec, M)
    _assert_ctrl_state_matches(jstate, nstate, "init")
    step = jax.jit(lambda s, o: CTRL.controller_step(spec, s, o))
    for t in range(40):
        d2a = np.float32(rng.uniform(1e-4, 2.0))
        payload = np.float32(rng.uniform(0.0, 3.0)) \
            if rng.random() > 0.2 else np.float32(0.0)
        obs = {"delta_sq_mean": np.float32(d2a * rng.uniform(0.5, 4.0)),
               "delta_sq_avg": d2a,
               "payload_sq": payload,
               "resid_sq": np.float32(payload * rng.uniform(0.0, 0.9))}
        jstate, jknobs = step(jstate, {k: jnp.asarray(v)
                                       for k, v in obs.items()})
        nstate, nknobs = ref_ctrl.controller_step(spec, nstate, obs)
        _assert_ctrl_state_matches(jstate, nstate, f"step {t}")
        for kk in jknobs:
            np.testing.assert_array_equal(np.asarray(jknobs[kk]), nknobs[kk],
                                          err_msg=f"step {t} knob {kk}")


# --------------------------------------------------------------------------- #
# engine integration: the logged metrics are a complete replay record
# --------------------------------------------------------------------------- #


def test_engine_trajectory_replayed_by_oracle_from_logs(problem):
    """Run a full adaptive round loop (GNS-driven H_t growth + EF-guarded k
    schedule) and reproduce the ENTIRE knob trajectory with the numpy oracle
    using only what the engine logged per round."""
    ctrl = CTRL.ControllerSpec(enabled=True, h_min=1, h_max=5,
                               noise_target=1e-3, resid_guard=0.3,
                               k_min=0.1, k_max=1.0,
                               step_times=(1.0, 1.3, 2.0, 2.6))
    spec = engine.method_spec(
        "fedadam", **MS_KW,
        compression=engine.CompressionSpec(op="topk", k=0.5,
                                           error_feedback=True),
        controller=ctrl)
    state, mets = _run(problem, spec, rounds=10, H=6, collect=True)

    s = ref_ctrl.init_ctrl_state(ctrl, 4)
    grew = False
    for r, met in enumerate(mets):
        # the metrics report THIS round's realized knobs = state before update
        np.testing.assert_array_equal(met["ctrl_h_m"], s["h_m"],
                                      err_msg=f"round {r} h_m")
        assert int(met["ctrl_h_t"]) == int(s["h_t"]), r
        np.testing.assert_array_equal(met["ctrl_k"], s["k"], err_msg=str(r))
        assert int(met["ctrl_b_eff"]) == 0  # depth unmanaged (buffer_max=0)
        obs = {"delta_sq_mean": met["delta_sq_mean"],
               "delta_sq_avg": met["delta_sq_avg"],
               "payload_sq": met["payload_sq"],
               "resid_sq": met["compression_err"]}
        s, _ = ref_ctrl.controller_step(ctrl, s, obs)
        np.testing.assert_allclose(met["ctrl_gns_ema"], s["gns_ema"],
                                   rtol=3e-7, err_msg=f"round {r} gns_ema")
        grew = grew or int(s["h_t"]) > ctrl.h_min
    # the schedule actually moved (otherwise this test pins nothing)
    assert grew, "H_t never grew — raise rounds or lower noise_target"
    assert int(mets[-1]["ctrl_h_t"]) > ctrl.h_min
    # realized H_m always obeys the budget rule for its round's H_t
    for met in mets:
        np.testing.assert_array_equal(
            met["ctrl_h_m"],
            ref_ctrl.budget_h(ctrl, int(met["ctrl_h_t"]), 4))


def test_straggler_skip_with_buffer(problem):
    """With a staleness buffer the budget rule drops its >=1 floor: at
    H_t = 1 the slow clients sit out (H_m = 0), the applied delta is rescaled
    to the active subset, and the loss stays finite."""
    ctrl = CTRL.ControllerSpec(enabled=True, h_min=1, h_max=4,
                               noise_target=0.05, buffer_max=3,
                               step_times=(1.0, 1.3, 2.0, 2.6))
    spec = engine.method_spec(
        "fedavg", eta_l=0.01,
        compression=engine.CompressionSpec(op="topk", k=0.5,
                                           error_feedback=True),
        asynchrony=engine.AsyncSpec(buffer_rounds=3), controller=ctrl)
    state, mets = _run(problem, spec, rounds=6, H=4, collect=True)
    h0 = mets[0]["ctrl_h_m"]
    np.testing.assert_array_equal(h0, [1, 0, 0, 0])   # budget 1.0·min(t)
    assert int(mets[0]["ctrl_b_eff"]) == 3            # half_up(2.6/1.0)
    assert all(np.isfinite(float(m["loss"])) for m in mets)
    assert np.isfinite(np.asarray(state["params"]["x"])).all()
    # replay holds under the buffered/skipping configuration too
    s = ref_ctrl.init_ctrl_state(ctrl, 4)
    for r, met in enumerate(mets):
        np.testing.assert_array_equal(met["ctrl_h_m"], s["h_m"],
                                      err_msg=f"round {r}")
        s, _ = ref_ctrl.controller_step(
            ctrl, s, {"delta_sq_mean": met["delta_sq_mean"],
                      "delta_sq_avg": met["delta_sq_avg"],
                      "payload_sq": met["payload_sq"],
                      "resid_sq": met["compression_err"]})


# --------------------------------------------------------------------------- #
# frozen controller == static spec, bitwise (knob plumbing adds no arithmetic)
# --------------------------------------------------------------------------- #


def test_frozen_controller_bit_identical_to_static_spec(problem):
    """h_min = h_max and k_min = k_max freeze every knob at its static value;
    the dynamic masking/compression path must then be BITWISE the static
    program (binary-exact k so f32 k·n == double k·n)."""
    comp = engine.CompressionSpec(op="topk", k=0.25, error_feedback=True)
    ctrl = CTRL.ControllerSpec(enabled=True, h_min=3, h_max=3,
                               k_min=0.25, k_max=0.25)
    spec_dyn = engine.method_spec("savic", **MS_KW, compression=comp,
                                  controller=ctrl)
    spec_sta = engine.method_spec("savic", **MS_KW, compression=comp)
    st_d, _ = _run(problem, spec_dyn, rounds=4, H=3)
    st_s, _ = _run(problem, spec_sta, rounds=4, H=3)
    for grp in ("params", "mom", "ef"):
        np.testing.assert_array_equal(np.asarray(st_d[grp]["x"]),
                                      np.asarray(st_s[grp]["x"]), err_msg=grp)
    # the frozen knobs really were the static values all along
    np.testing.assert_array_equal(np.asarray(st_d["ctrl"]["h_m"]),
                                  np.full((4,), 3, np.int32))
    assert float(st_d["ctrl"]["k"]) == 0.25


# --------------------------------------------------------------------------- #
# checkpoint: the ctrl leaf rides the state pytree bitwise
# --------------------------------------------------------------------------- #


def test_ctrl_state_checkpoint_roundtrip(tmp_path, problem):
    ctrl = CTRL.ControllerSpec(enabled=True, h_min=1, h_max=4,
                               noise_target=0.05, resid_guard=0.3,
                               step_times=(1.0, 1.5, 2.0, 2.5))
    spec = engine.method_spec(
        "fedadam", **MS_KW,
        compression=engine.CompressionSpec(op="topk", k=0.5,
                                           error_feedback=True),
        controller=ctrl)
    state, _ = _run(problem, spec, rounds=3, H=4)
    assert "ctrl" in state and int(state["ctrl"]["t"]) == 3
    save(str(tmp_path), 3, state)
    out, step = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, state))
    assert step == 3
    got = dict(tree_paths(out))
    for p, leaf in tree_paths(state):
        assert got[p].dtype == leaf.dtype, p
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(leaf),
                                      err_msg=p)


# --------------------------------------------------------------------------- #
# server m/v compression (ServerSpec.sync_dtype / sync_k)
# --------------------------------------------------------------------------- #


def test_server_sync_identity_default_bit_exact(problem):
    """sync_identity() (the default) leaves the adaptive server untouched."""
    sp_a = engine.method_spec("fedadam", **MS_KW)
    assert sp_a.server.sync_identity()
    sp_b = engine.method_spec("fedadam", **MS_KW, server_sync_dtype="",
                              server_sync_k=1.0)
    st_a, _ = _run(problem, sp_a)
    st_b, _ = _run(problem, sp_b)
    np.testing.assert_array_equal(np.asarray(st_a["params"]["x"]),
                                  np.asarray(st_b["params"]["x"]))


def test_server_state_topk_mask_and_v_floor():
    """sync_k keeps ONE shared top-|m| index set for m AND v; a dropped
    coordinate zeroes m and floors v at v_init (default tau^2)."""
    sv = engine.ServerSpec(kind="adaptive", opt="adam", sync_k=0.5)
    m = {"x": jnp.asarray([5.0, -0.1, 3.0, 0.2, -4.0, 0.3])}
    v = {"x": jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])}
    mc, vc = engine._compress_server_state(sv, m, v)
    np.testing.assert_array_equal(np.asarray(mc["x"]),
                                  [5.0, 0.0, 3.0, 0.0, -4.0, 0.0])
    v0 = sv.tau ** 2
    np.testing.assert_allclose(np.asarray(vc["x"]),
                               [1.0, v0, 3.0, v0, 5.0, v0], rtol=1e-6)
    # exactly k_count coordinates survive — the shared-mask contract
    assert int((np.asarray(mc["x"]) != 0).sum()) == engine._k_count(0.5, 6)
    # explicit v_init overrides the floor
    sv2 = engine.ServerSpec(kind="adaptive", opt="adam", sync_k=0.5,
                            v_init=7.5)
    _, vc2 = engine._compress_server_state(sv2, m, v)
    assert float(np.asarray(vc2["x"])[1]) == 7.5


def test_server_state_compression_converges(problem):
    """bf16 QDQ + top-50% m/v still trains: final loss within 10% of the
    uncompressed fedadam run on the same budget."""
    st_a, met_a = _run(problem, engine.method_spec("fedadam", **MS_KW),
                       rounds=12)
    st_b, met_b = _run(problem, engine.method_spec(
        "fedadam", **MS_KW, server_sync_dtype="bfloat16", server_sync_k=0.5),
        rounds=12)
    la, lb = float(met_a["loss"]), float(met_b["loss"])
    assert np.isfinite(lb)
    assert abs(lb - la) <= 0.10 * abs(la), (la, lb)


def test_server_state_bytes_accounting():
    params = {"x": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    out = engine.bytes_on_wire(
        engine.method_spec("fedadam", server_sync_k=0.1), params)
    # 100 kept coords × ((m, v) fp32 pair + int32 index)
    assert out["server_state_bytes"] == 100 * (2 * 4 + 4)
    assert out["server_state_uncompressed_bytes"] == 2 * 1000 * 4
    out2 = engine.bytes_on_wire(
        engine.method_spec("fedadam", server_sync_dtype="bfloat16"), params)
    assert out2["server_state_bytes"] == 2 * 1000 * 2
    # server-to-server leg: NOT folded into the client->server total
    assert out2["total_bytes"] == engine.bytes_on_wire(
        engine.method_spec("fedadam"), params)["total_bytes"]
    # averaging servers have no adaptive state to compress
    with pytest.raises(ValueError):
        engine.method_spec("fedavg", server_sync_k=0.5)
    with pytest.raises(ValueError):
        engine.ServerSpec(kind="average", sync_dtype="bfloat16")
    with pytest.raises(ValueError):
        engine.ServerSpec(kind="adaptive", sync_k=0.0)


# --------------------------------------------------------------------------- #
# spec validation + the budget rule units
# --------------------------------------------------------------------------- #


def test_controller_spec_validation():
    for bad in [dict(h_min=0), dict(h_min=5, h_max=4), dict(ema=1.0),
                dict(ema=0.0), dict(k_min=0.0), dict(k_min=0.6, k_max=0.5),
                dict(k_max=1.5), dict(k_shrink=0.0), dict(k_growth=0.5),
                dict(h_growth=1.0), dict(resid_guard=0.0),
                dict(spread_per_slot=0.0), dict(buffer_max=-1),
                dict(step_times=(1.0, -2.0))]:
        with pytest.raises(ValueError):
            CTRL.ControllerSpec(enabled=True, **bad)
    with pytest.raises(ValueError):
        engine.EngineSpec(controller="yes")  # must be a ControllerSpec


def test_build_time_conflicts_raise(problem):
    loss = _quad_loss(problem)
    ctrl = CTRL.ControllerSpec(enabled=True, h_max=2)
    # controller owns H_m: a static local_steps bake conflicts
    with pytest.raises(ValueError, match="local_steps"):
        engine.build_round_step(loss, engine.method_spec(
            "fedavg", local_steps=(1, 2, 2, 1), controller=ctrl))
    # GNS needs every client's delta
    with pytest.raises(ValueError, match="participation"):
        engine.build_round_step(loss, engine.method_spec(
            "fedavg", participation=0.5, controller=ctrl))
    # b_eff masks WITHIN the allocated FIFO
    with pytest.raises(ValueError, match="buffer_max"):
        engine.build_round_step(loss, engine.method_spec(
            "fedavg", asynchrony=engine.AsyncSpec(buffer_rounds=2),
            controller=dataclasses.replace(ctrl, buffer_max=4)))
    # h_max must fit in the round's H microbatches (trace-time)
    step = engine.build_round_step(loss, engine.method_spec(
        "fedavg", controller=CTRL.ControllerSpec(enabled=True, h_max=8)))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)},
                              engine.method_spec(
                                  "fedavg",
                                  controller=CTRL.ControllerSpec(
                                      enabled=True, h_max=8)), 4)
    batch = jax.tree.map(jnp.asarray,
                         QuadraticLoader(problem, seed=0).round_batch(3))
    with pytest.raises(ValueError, match="h_max"):
        step(state, batch, jax.random.PRNGKey(1))


def test_budget_rule_units():
    # no buffer: the >=1 floor of local_steps_from_times is kept
    sp = CTRL.ControllerSpec(enabled=True, h_max=8,
                             step_times=(1.0, 2.0, 8.0))
    np.testing.assert_array_equal(np.asarray(CTRL.budget_h(sp, 4, 3)),
                                  [4, 2, 1])
    # with a buffer the floor drops to 0: stragglers sit the round out
    spb = dataclasses.replace(sp, buffer_max=2)
    np.testing.assert_array_equal(np.asarray(CTRL.budget_h(spb, 4, 3)),
                                  [4, 2, 0])
    # homogeneous trace: everyone runs the full budget
    sph = CTRL.ControllerSpec(enabled=True, h_max=8)
    np.testing.assert_array_equal(np.asarray(CTRL.budget_h(sph, 3, 4)),
                                  [3, 3, 3, 3])
    # oracle agrees on all three
    for s, h, n in [(sp, 4, 3), (spb, 4, 3), (sph, 3, 4)]:
        np.testing.assert_array_equal(np.asarray(CTRL.budget_h(s, h, n)),
                                      ref_ctrl.budget_h(s, h, n))
    # step_times length must match the client count
    with pytest.raises(ValueError, match="step_times"):
        CTRL.budget_h(sp, 4, 5)


def test_buffer_depth_and_half_up():
    # half-up, not banker's: 2.5 rounds to 3 (round() gives 2)
    assert CTRL.half_up(2.5) == 3 and round(2.5) == 2
    assert CTRL.half_up(0.5) == 1
    assert CTRL.half_up(1.49) == 1
    mk = lambda **kw: CTRL.ControllerSpec(enabled=True, **kw)
    assert CTRL.buffer_depth(mk(buffer_max=0)) == 1
    assert CTRL.buffer_depth(mk(buffer_max=4)) == 1          # homogeneous
    assert CTRL.buffer_depth(
        mk(buffer_max=4, step_times=(1.0, 2.5))) == 3        # half_up(2.5)
    assert CTRL.buffer_depth(
        mk(buffer_max=2, step_times=(1.0, 9.0))) == 2        # clipped
    assert CTRL.buffer_depth(
        mk(buffer_max=4, step_times=(1.0, 2.0), spread_per_slot=0.5)) == 4


def test_k_schedule_freezes_without_payload():
    """No compression => payload_sq = 0 => k and resid_ema never move."""
    sp = CTRL.ControllerSpec(enabled=True, h_max=4, k_min=0.1)
    s = ref_ctrl.init_ctrl_state(sp, 4)
    for t in range(5):
        s, _ = ref_ctrl.controller_step(
            sp, s, {"delta_sq_mean": 3.0, "delta_sq_avg": 1.0,
                    "payload_sq": 0.0, "resid_sq": 0.0})
        assert float(s["k"]) == 1.0 and float(s["resid_ema"]) == 0.0
    js = CTRL.init_ctrl_state(sp, 4)
    for t in range(5):
        js, _ = CTRL.controller_step(
            sp, js, {"delta_sq_mean": jnp.float32(3.0),
                     "delta_sq_avg": jnp.float32(1.0),
                     "payload_sq": jnp.float32(0.0),
                     "resid_sq": jnp.float32(0.0)})
    assert float(js["k"]) == 1.0 and float(js["resid_ema"]) == 0.0


# --------------------------------------------------------------------------- #
# launch layer: ctrl leaf + metrics threading through build_train_step
# --------------------------------------------------------------------------- #


def test_build_train_step_threads_controller():
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    ctrl = engine.ControllerSpec(enabled=True, h_min=1, h_max=2,
                                 buffer_max=0)
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             reduced=True, h_local=2, het_model="lognormal",
                             controller=ctrl)
    spec = built.meta["engine_spec"]
    assert spec.controller.enabled
    # the sampled trace was adopted as the controller's step_times, and no
    # static H_m bake conflicts with the controller
    assert len(spec.controller.step_times) == built.meta["clients"]
    assert spec.client.local_steps is None
    assert built.meta["controller"]["h_max"] == 2
    state_shape = built.args[0]
    assert "ctrl" in state_shape
    assert state_shape["ctrl"]["h_m"].shape == (built.meta["clients"],)
    state_spec, _ = built.in_shardings
    assert set(state_spec["ctrl"]) == set(state_shape["ctrl"])
