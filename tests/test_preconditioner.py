"""Property tests for the preconditioner family — Lemma 1 / Assumption 4."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.preconditioner import (PrecondConfig, beta_t, bounds, dhat,
                                       grad_stat, hutchinson_diag, init_state,
                                       precondition, update)

KINDS = ["adam", "rmsprop", "adagrad", "oasis", "adahessian"]


def _tree(vals):
    return {"a": jnp.asarray(vals, jnp.float32)}


@settings(max_examples=40, deadline=None)
@given(
    kind=st.sampled_from(["adam", "rmsprop", "oasis"]),
    alpha=st.floats(1e-6, 1e-1),
    gamma_cap=st.floats(0.5, 50.0),
    steps=st.integers(1, 12),
    data=st.data(),
)
def test_lemma1_bounds(kind, alpha, gamma_cap, steps, data):
    """Item 1 of Lemma 1: with |H^t| ≤ Γ elementwise, D̂^t stays in [α, Γ']
    where Γ' = max(Γ, D̂⁰=1): diagonal, non-negative, bounded."""
    cfg = PrecondConfig(kind=kind, alpha=alpha)
    d = 16
    state = init_state(cfg, _tree(np.zeros(d)))
    cap = max(gamma_cap, 1.0)
    for _ in range(steps):
        h = data.draw(st.lists(st.floats(-gamma_cap, gamma_cap),
                               min_size=d, max_size=d))
        h = np.asarray(h, np.float32)
        stat = _tree(h**2) if cfg.rule == "squared" else _tree(np.abs(h))
        state = update(cfg, state, stat)
        dh = dhat(cfg, state)["a"]
        assert np.all(np.asarray(dh) >= alpha - 1e-7)
        assert np.all(np.asarray(dh) <= cap + alpha + 1e-5)


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(["rmsprop", "oasis"]),
       beta=st.floats(0.5, 0.999))
def test_lemma1_drift_ratio(kind, beta):
    """Items 2/3: D̂^{t+1} ⪯ (1 + (1-β)C) D̂^t with C = Γ²/2α² (rule 2) or
    2Γ/α (rule 3)."""
    alpha, Gamma = 0.1, 2.0
    cfg = PrecondConfig(kind=kind, alpha=alpha, beta2=beta)
    rng = np.random.default_rng(0)
    state = init_state(cfg, _tree(np.zeros(32)))
    for _ in range(8):
        prev = np.asarray(dhat(cfg, state)["a"])
        h = rng.uniform(-Gamma, Gamma, size=32).astype(np.float32)
        stat = _tree(h**2) if cfg.rule == "squared" else _tree(h)
        state = update(cfg, state, stat)
        cur = np.asarray(dhat(cfg, state)["a"])
        C = Gamma**2 / (2 * alpha**2) if cfg.rule == "squared" \
            else 2 * Gamma / alpha
        ratio_bound = 1.0 + (1.0 - beta) * C
        assert np.all(cur <= prev * ratio_bound + 1e-6)


def test_identity_is_noop():
    cfg = PrecondConfig(kind="identity")
    state = init_state(cfg, _tree(np.ones(4)))
    g = _tree(np.array([1.0, -2.0, 3.0, -4.0]))
    out = precondition(cfg, state, g)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(g["a"]))


def test_adam_debias_schedule():
    """β_t = (β-β^{t+1})/(1-β^{t+1}) starts at 0 and -> β."""
    cfg = PrecondConfig(kind="adam", beta2=0.999)
    b0 = float(beta_t(cfg, jnp.int32(0)))
    b_inf = float(beta_t(cfg, jnp.int32(10_000)))
    assert b0 < b_inf < 0.999 + 1e-6
    assert abs(b_inf - 0.999) < 1e-4


@pytest.mark.parametrize("beta", [0.9, 0.99, 0.999])
def test_adam_debias_first_two_betas_pinned(beta):
    """The documented schedule, exactly: for the update at 0-based step t,
    β_t = (β − β^{t+1}) / (1 − β^{t+1}). Pins the first two values:

        β_0 = (β − β) / (1 − β)   = 0          (first update: D² = H², the
                                                debiased-Adam v̂_1 = g₁²)
        β_1 = (β − β²) / (1 − β²) = β / (1+β)

    Regression for the historical off-by-one ((β − β^{t+2})/(1 − β^{t+2}),
    which gave β_0 = β/(1+β) and never hit the documented sequence).
    """
    cfg = PrecondConfig(kind="adam", beta2=beta)
    b0 = float(beta_t(cfg, jnp.int32(0)))
    b1 = float(beta_t(cfg, jnp.int32(1)))
    np.testing.assert_allclose(b0, 0.0, atol=1e-7)
    # fp32 cancellation in (β−β²)/(1−β²) costs ~1e-5 relative at β=0.999
    np.testing.assert_allclose(b1, beta / (1.0 + beta), rtol=1e-4)
    # and the debiased update really uses the full new stat at t=0
    state = init_state(cfg, _tree(np.zeros(4)))
    state = update(cfg, state, _tree(np.full(4, 9.0)))   # H² = 9
    np.testing.assert_allclose(np.asarray(dhat(cfg, state)["a"]), 3.0,
                               rtol=1e-6)


def test_adagrad_accumulates():
    cfg = PrecondConfig(kind="adagrad", alpha=1e-3)
    state = init_state(cfg, _tree(np.zeros(3)))
    for _ in range(5):
        state = update(cfg, state, _tree(np.ones(3)))
    # D² = 1 (init) + 5 -> D̂ = sqrt(6)
    np.testing.assert_allclose(np.asarray(dhat(cfg, state)["a"]),
                               np.sqrt(6.0), rtol=1e-5)


def test_hutchinson_unbiased_on_quadratic():
    """E[v ⊙ Qv] = diag(Q) exactly for Rademacher v on a quadratic."""
    d = 12
    rng = np.random.default_rng(1)
    A = rng.normal(size=(d, d))
    Q = (A @ A.T / d + np.eye(d)).astype(np.float32)

    def loss(params, batch):
        x = params["x"]
        return 0.5 * x @ jnp.asarray(Q) @ x

    params = {"x": jnp.asarray(rng.normal(size=d).astype(np.float32))}
    ests = []
    for i in range(200):
        est = hutchinson_diag(loss, params, None, jax.random.PRNGKey(i))
        ests.append(np.asarray(est["x"]))
    mean = np.mean(ests, axis=0)
    np.testing.assert_allclose(mean, np.diag(Q), rtol=0.25, atol=0.05)


def test_bounds_reporting():
    cfg = PrecondConfig(kind="rmsprop", alpha=0.01)
    state = init_state(cfg, _tree(np.zeros(8)))
    state = update(cfg, state, _tree(np.linspace(0, 4, 8) ** 2))
    lo, hi = bounds(cfg, state)
    assert float(lo) >= 0.01 - 1e-8
    assert float(hi) <= np.sqrt(0.999 + 0.001 * 16.0) + 1e-5


# --------------------------------------------------------------------------- #
# Lemma 1 bounds THROUGH the fused flat-buffer kernel (DESIGN.md §7): the
# same α ≤ D̂ ≤ Γ' invariant when D evolves inside fused_local_step — rule-2
# (in-kernel grad² stat), rule-3 with NEGATIVE Hutchinson stats, and the
# clip="add" branch (previously untested)
# --------------------------------------------------------------------------- #


def _fused_d_evolution(cfg: PrecondConfig, stats, d0):
    """Evolve d with the FUSED kernel (stats (T, M, n); external for rule-3 /
    Hutchinson, in-kernel g² for rule-2) and return the final d buffer."""
    import jax.numpy as jnp

    from repro.kernels import ops
    M, n = stats.shape[1:]
    p = jnp.zeros((M, n))
    m = jnp.zeros((M, n))
    d = jnp.asarray(d0, jnp.float32)
    for step, h in enumerate(stats):
        t = jnp.full((M,), step, jnp.int32)
        if cfg.uses_hutchinson or cfg.rule == "linear":
            g, hstat = jnp.zeros((M, n)), jnp.asarray(h, jnp.float32)
        else:
            # rule-2 in-kernel stat: the kernel squares g itself
            g, hstat = jnp.sqrt(jnp.asarray(h, jnp.float32)), None
        p, m, d = ops.fused_local_step(
            p, m, g, d, hstat, t, None, gamma=0.0, beta1=0.0,
            alpha=cfg.alpha, beta2=cfg.beta2, kind=cfg.kind, clip=cfg.clip,
            schedule=cfg.schedule, update_d=True)
    return d


def _assert_lemma1(cfg: PrecondConfig, d, gamma_cap):
    """α ≤ D̂ ≤ Γ' (+α for the "add" clip), via preconditioner.bounds."""
    state = {"d": _tree(np.asarray(d[0])), "t": np.int32(1)}
    lo, hi = bounds(cfg, state)
    cap = max(gamma_cap, 1.0)
    if cfg.clip == "add":
        assert float(lo) >= cfg.alpha - 1e-7
        assert float(hi) <= cap + cfg.alpha + 1e-4
    else:
        assert float(lo) >= cfg.alpha - 1e-7
        assert float(hi) <= cap + 1e-4


@pytest.mark.parametrize("kind,clip", [("adam", "max"), ("adam", "add"),
                                       ("rmsprop", "max"), ("rmsprop", "add"),
                                       ("oasis", "max"), ("oasis", "add")])
def test_lemma1_bounds_through_fused_updates(kind, clip):
    """Deterministic: |H| ≤ Γ elementwise keeps D̂ in [α, Γ'] through fused
    kernel updates — including OASIS driven by NEGATIVE Hutchinson stats and
    the additive rule-4 clip."""
    alpha, Gamma, n, T = 0.05, 3.0, 48, 8
    # fast EMA (β₂ = 0.5) so the signed rule-3 state actually goes negative
    # within T steps; Lemma 1's bound is β-independent
    cfg = PrecondConfig(kind=kind, alpha=alpha, clip=clip, beta2=0.5)
    rng = np.random.default_rng(1)
    raw = rng.uniform(-Gamma, Gamma, size=(T, 1, n)).astype(np.float32)
    stats = raw if cfg.rule == "linear" else raw ** 2   # rule-2 wants H²
    d = _fused_d_evolution(cfg, stats, np.ones((1, n), np.float32))
    if cfg.rule == "linear":
        assert float(np.min(np.asarray(d))) < 0.0   # signed D really occurs
    _assert_lemma1(cfg, d, Gamma)


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["adam", "oasis"]),
       clip=st.sampled_from(["max", "add"]),
       alpha=st.floats(1e-4, 1e-1), gamma_cap=st.floats(0.5, 20.0),
       steps=st.integers(1, 6), seed=st.integers(0, 99))
def test_lemma1_bounds_through_fused_updates_property(kind, clip, alpha,
                                                      gamma_cap, steps, seed):
    cfg = PrecondConfig(kind=kind, alpha=alpha, clip=clip)
    rng = np.random.default_rng(seed)
    raw = rng.uniform(-gamma_cap, gamma_cap,
                      size=(steps, 1, 16)).astype(np.float32)
    stats = raw if cfg.rule == "linear" else raw ** 2
    d = _fused_d_evolution(cfg, stats, np.ones((1, 16), np.float32))
    _assert_lemma1(cfg, d, gamma_cap)
