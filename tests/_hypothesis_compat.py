"""Optional-hypothesis shim (tier-1 robustness).

``hypothesis`` is a dev-only dependency that is not guaranteed in every
container. Importing it unconditionally used to kill collection of the whole
suite under ``pytest -x``. Import ``given``/``settings``/``st`` from here
instead: when hypothesis is installed they are the real thing; when it is
missing, ``@given(...)`` turns into a per-test skip marker, so the
deterministic tests in the same module still run.
"""
import pytest

try:
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _NullStrategies:
        """st.anything(...) -> None placeholder (never executed)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda f: f
