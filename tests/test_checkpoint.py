"""Checkpoint layer: crashed-save hygiene and a full engine-state round-trip
(server/ef/buffer leaves — every optional state group at once)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.core import engine
from repro.utils.tree import tree_paths


def _orphan_tmp(ckpt_dir, step):
    """Simulate a save that crashed mid-write."""
    d = os.path.join(str(ckpt_dir), f"step_{step:08d}.tmp")
    os.makedirs(d)
    with open(os.path.join(d, "data.bin"), "wb") as f:
        f.write(b"partial garbage")
    return d


def test_crashed_save_tmp_cleaned_on_next_save(tmp_path):
    state = {"x": jnp.arange(4, dtype=jnp.float32)}
    _orphan_tmp(tmp_path, 7)
    assert latest_step(str(tmp_path)) is None        # tmp never counts
    save(str(tmp_path), 9, state)
    left = os.listdir(tmp_path)
    assert not any(d.endswith(".tmp") for d in left), left
    assert latest_step(str(tmp_path)) == 9

    # crashed re-save of an EXISTING step: stale tmp goes, checkpoint stays
    _orphan_tmp(tmp_path, 9)
    save(str(tmp_path), 12, state)
    left = os.listdir(tmp_path)
    assert not any(d.endswith(".tmp") for d in left), left
    out, step = restore(str(tmp_path), state)
    assert step == 12
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(state["x"]))


def test_orphan_tmps_do_not_accumulate(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in range(3):
        _orphan_tmp(tmp_path, 100 + s)
    save(str(tmp_path), 1, state)
    assert sum(d.endswith(".tmp") for d in os.listdir(tmp_path)) == 0


def test_engine_state_roundtrip_server_ef_buffer(tmp_path):
    """Save/restore an engine state carrying every optional group: adaptive
    ``server`` (m, v), error-feedback ``ef`` residual, async ``buffer`` FIFO."""
    spec = engine.method_spec(
        "fedadam",
        compression=engine.CompressionSpec(op="topk", k=0.5,
                                           error_feedback=True),
        asynchrony=engine.AsyncSpec(buffer_rounds=2))

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w": jax.random.normal(k1, (3, 4)),
                "b": jax.random.normal(k2, (4,))}

    state = engine.init_state(jax.random.PRNGKey(0), init, spec, n_clients=3)
    assert {"server", "ef", "buffer"} <= set(state)
    # non-trivial leaf values everywhere (zeros round-trip trivially)
    state = jax.tree.map(
        lambda x: x + jnp.arange(x.size, dtype=x.dtype).reshape(x.shape)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, state)

    save(str(tmp_path), 5, state)
    out, step = restore(str(tmp_path),
                        jax.tree.map(jnp.zeros_like, state))
    assert step == 5
    got = dict(tree_paths(out))
    for p, leaf in tree_paths(state):
        assert got[p].dtype == leaf.dtype, p
        np.testing.assert_array_equal(np.asarray(got[p]), np.asarray(leaf),
                                      err_msg=p)
