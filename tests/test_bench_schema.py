"""Golden-schema regression tests (DESIGN.md §11): every committed
BENCH_*.json and results/bench/*.csv must validate against the uniform row
schema — required keys, axis-coordinate completeness, git_rev presence
(pre-PR-8 history is backfilled as "unknown", never absent), numeric metric
types — and the CSV must be the byte-exact render of its JSON document."""
import glob
import json
import os

import pytest

from benchmarks import matrix

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSONS = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
BENCH_CSVS = sorted(glob.glob(os.path.join(ROOT, "results", "bench", "*.csv")))


def _bench_name(path):
    return os.path.basename(path)[len("BENCH_"):-len(".json")]


def test_committed_artifacts_exist():
    assert BENCH_JSONS, "no BENCH_*.json at the repo root"
    assert BENCH_CSVS, "no results/bench/*.csv"


@pytest.mark.parametrize("path", BENCH_JSONS, ids=_bench_name)
def test_bench_json_validates(path):
    errs = matrix.validate_doc(json.load(open(path)))
    assert not errs, f"{os.path.basename(path)}:\n" + "\n".join(errs)


@pytest.mark.parametrize("path", BENCH_JSONS, ids=_bench_name)
def test_bench_rows_tagged_with_git_rev(path):
    doc = json.load(open(path))
    for i, row in enumerate(doc["rows"]):
        assert row.get("git_rev"), f"rows[{i}] untagged"
        # coordinate completeness: every row addresses the full axis tuple
        assert set(row["coords"]) == set(doc["axes"])


@pytest.mark.parametrize("path", BENCH_CSVS,
                         ids=lambda p: os.path.basename(p)[:-4])
def test_bench_csv_is_render_of_json(path):
    name = os.path.basename(path)[:-len(".csv")]
    json_path = os.path.join(ROOT, f"BENCH_{name}.json")
    assert os.path.exists(json_path), (
        f"{os.path.basename(path)} has no BENCH_{name}.json store of record")
    doc = json.load(open(json_path))
    assert open(path).read() == matrix.render_csv(doc), (
        f"{name}.csv is not the byte-exact render of BENCH_{name}.json — "
        "regenerate with: python -m benchmarks.matrix update-output "
        f"--bench {name}")


def test_every_json_has_csv_mirror():
    for path in BENCH_JSONS:
        name = _bench_name(path)
        assert os.path.join(ROOT, "results", "bench",
                            f"{name}.csv") in BENCH_CSVS, (
            f"BENCH_{name}.json has no results/bench/{name}.csv mirror")


# --------------------------------------------------------------------------- #
# validator rejection cases — new rows cannot regress below the schema
# --------------------------------------------------------------------------- #


def _valid_doc():
    return {"schema_version": 1, "bench": "t", "git_rev": "r",
            "config": {}, "axes": ["m"],
            "rows": [{"coords": {"m": "a"}, "metrics": {"v": 1.0},
                      "git_rev": "r"}]}


def test_validator_accepts_valid():
    assert matrix.validate_doc(_valid_doc()) == []


@pytest.mark.parametrize("mutate, frag", [
    (lambda d: d.pop("schema_version"), "schema_version"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d.pop("git_rev"), "git_rev"),
    (lambda d: d.update(axes=[]), "axes"),
    (lambda d: d.update(axes=["m", "m"]), "axes"),
    (lambda d: d["rows"][0].pop("git_rev"), "git_rev"),
    (lambda d: d["rows"][0].update(git_rev=""), "git_rev"),
    (lambda d: d["rows"][0].update(coords={}), "coordinate completeness"),
    (lambda d: d["rows"][0].update(coords={"m": "a", "extra": 1}),
     "coordinate completeness"),
    (lambda d: d["rows"][0].update(metrics={}), "metrics"),
    (lambda d: d["rows"][0].update(metrics={"v": "fast"}), "not numeric"),
    (lambda d: d["rows"][0].update(metrics={"v": True}), "not numeric"),
    (lambda d: d["rows"][0].update(metrics={"v": float("nan")}), "NaN"),
    (lambda d: d["rows"][0].update(unexpected=1), "unknown keys"),
    (lambda d: d["rows"].append(dict(d["rows"][0])), "duplicate"),
], ids=["no_version", "bad_version", "no_doc_rev", "empty_axes", "dup_axes",
        "untagged_row", "empty_rev", "no_coords", "extra_coord",
        "empty_metrics", "string_metric", "bool_metric", "nan_metric",
        "unknown_key", "dup_coords"])
def test_validator_rejects(mutate, frag):
    doc = _valid_doc()
    mutate(doc)
    errs = matrix.validate_doc(doc)
    assert errs and any(frag in e for e in errs), errs


def test_assert_valid_raises_with_bench_name():
    doc = _valid_doc()
    doc["rows"][0]["git_rev"] = ""
    with pytest.raises(ValueError, match="git_rev"):
        matrix.assert_valid(doc)


# --------------------------------------------------------------------------- #
# timing classification — wall-clock fields are noise, not regressions
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("name", [
    "round_ms_mean", "round_ms_first", "us_fused_oracle", "ttft_s",
    "p99_token_s", "wall_tok_per_s", "tok_per_s", "tokens_per_s_per_device",
    "round_wall_s_mean", "seconds"])
def test_timing_metrics(name):
    assert matrix.is_timing_metric(name)


@pytest.mark.parametrize("name", [
    "sim_time_to_target", "sim_round_time", "final_loss", "rounds",
    "wire_bytes_per_round", "compression_x", "collective_bytes_sharded",
    "hbm_reduction_x", "tok_s_dev_roofline", "makespan_steps",
    "tok_per_step", "b_eff"])
def test_comparable_metrics(name):
    assert not matrix.is_timing_metric(name)
