"""Verbatim pre-refactor snapshots of core/savic.py and core/fedopt.py.

Frozen at the commit that introduced core/engine.py; the engine regression
tests in test_engine.py pin the refactored round to these trajectories.
Not a test module (underscore prefix) - imported by tests only.
"""
"""SAVIC — Algorithm 1: Local SGD with preconditioning via scaling.

A *round* = H local steps on each of M clients followed by one synchronization
(parameter averaging) — the H-th step is the averaged one, exactly matching
Algorithm 1's sync timestep. The preconditioner D̂ is updated only at sync and
is identical on every client (*global scaling*, the analyzed setting); the
experimental *local scaling* variant (per-client D updated every local step)
is also implemented.

Distribution contract (see sharding/partitioner.py): every state leaf carries
a leading client dim M sharded over the plan's client axes — except the global
D, which is client-replicated (no M dim), matching the algorithm. Local steps
are ``vmap`` over M inside a ``lax.scan`` over H: XLA provably emits no
cross-client collective inside the scan; the sync ``mean`` over M is the only
cross-client traffic per round. That is the paper's communication saving,
realized on the mesh.
"""
import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import preconditioner as PC
from repro.core.preconditioner import PrecondConfig


@dataclasses.dataclass(frozen=True)
class SavicConfig:
    gamma: float = 0.1                 # step size γ
    beta1: float = 0.9                 # heavy-ball momentum (paper's exps: 0.9)
    scaling: str = "global"            # "global" (Algorithm 1) | "local"
    # D-stat at sync: "avg_grad" (H from the client-averaged sync gradient) |
    # "avg_local" (average of per-client stats)
    stat_source: str = "avg_grad"
    average_momentum: bool = True      # average momentum buffers at sync
    weight_decay: float = 0.0
    grad_clip: float = 0.0             # global-norm clip per local step (0=off)
    use_fused_kernel: bool = False     # Pallas scaled_update kernel (TPU)
    # sync compression (beyond-paper; cf. the quantization line of related
    # work [19,20]): all-reduce params/momentum in this dtype ("" = full)
    sync_dtype: str = ""
    # partial participation (beyond-paper; the compared Algorithm 2 of [42]
    # samples a client subset per round): fraction of clients whose updates
    # enter the sync average; non-participants keep local state but are
    # overwritten by the average (cross-device FedAvg semantics). 1.0 = all.
    participation: float = 1.0


def init_state(key, init_params_fn, pc_cfg: PrecondConfig, sv_cfg: SavicConfig,
               n_clients: int):
    """Build the SAVIC train state. x_0^m = x_0 (identical start, Algorithm 1)."""
    params = init_params_fn(key)
    params_m = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape), params)
    mom = jax.tree.map(jnp.zeros_like, params_m)
    if sv_cfg.scaling == "local":
        pstate = PC.init_state(pc_cfg, params_m)      # per-client D (leading M)
        if "d" in pstate:
            pstate["t"] = jnp.zeros((n_clients,), jnp.int32)  # per-client t
    else:
        pstate = PC.init_state(pc_cfg, params)        # global D (no M)
    return {
        "params": params_m,
        "mom": mom,
        "precond": pstate,
        "round": jnp.int32(0),
    }


def _clip(grads, max_norm):
    if not max_norm:
        return grads
    nrm = jnp.sqrt(sum(jnp.vdot(g, g).real
                       for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / nrm)
    return jax.tree.map(lambda g: g * scale, grads)


def _apply_update(params, mom, grads, pstate, pc_cfg, sv_cfg):
    """x ← x − γ D̂^{-1} m,  m ← β₁ m + g   (heavy-ball, scaled)."""
    g = grads
    if sv_cfg.weight_decay:
        g = jax.tree.map(lambda gi, p: gi + sv_cfg.weight_decay * p, g, params)
    mom = jax.tree.map(lambda m, gi: sv_cfg.beta1 * m + gi, mom, g)
    if sv_cfg.use_fused_kernel and pc_cfg.kind != "identity":
        from repro.kernels import ops as kops
        params = kops.scaled_update_tree(params, mom, pstate["d"],
                                         sv_cfg.gamma, pc_cfg.alpha,
                                         squared=pc_cfg.rule == "squared")
    else:
        direction = PC.precondition(pc_cfg, pstate, mom)
        params = jax.tree.map(lambda p, d: p - sv_cfg.gamma * d,
                              params, direction)
    return params, mom


def build_round_step(loss_fn: Callable, pc_cfg: PrecondConfig,
                     sv_cfg: SavicConfig):
    """loss_fn(params, microbatch) -> scalar.

    Returns ``round_step(state, batch, key)`` where each batch leaf is
    (M, H, ...): H microbatches per client per round. Returns (state, metrics).
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def local_step_one_client(params, mom, pstate, micro, key):
        """One SGD-with-scaling step on one client. pstate: client's view."""
        loss, grads = grad_fn(params, micro)
        grads = _clip(grads, sv_cfg.grad_clip)
        if sv_cfg.scaling == "local" and pc_cfg.kind != "identity":
            stat = (PC.hutchinson_diag(loss_fn, params, micro, key)
                    if pc_cfg.uses_hutchinson else PC.grad_stat(grads))
            if pc_cfg.rule == "linear" and not pc_cfg.uses_hutchinson:
                stat = jax.tree.map(jnp.abs, grads)
            pstate = PC.update(pc_cfg, pstate, stat)
        params, mom = _apply_update(params, mom, grads, pstate, pc_cfg, sv_cfg)
        return params, mom, pstate, loss, grads

    def round_step(state, batch, key):
        M = jax.tree.leaves(state["params"])[0].shape[0]
        H = jax.tree.leaves(batch)[0].shape[1]
        local_global_d = sv_cfg.scaling == "global"
        n_part = max(1, int(round(sv_cfg.participation * M)))

        def scan_body(carry, xs):
            params_m, mom_m, pstate, _ = carry
            micro_m, keys = xs  # (M, ...) microbatch slice, (M,) keys

            if local_global_d:
                fn = lambda p, m, mc, k: local_step_one_client(
                    p, m, pstate, mc, k)
                params_m, mom_m, _, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, micro_m, keys)
                new_pstate = pstate
            else:
                fn = local_step_one_client
                params_m, mom_m, new_pstate, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, pstate, micro_m, keys)
            return (params_m, mom_m, new_pstate, grads), losses

        keys = jax.random.split(key, (H, M))
        micro = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch)  # (H,M,...)
        grads0 = jax.tree.map(jnp.zeros_like, state["params"])
        (params_m, mom_m, pstate, last_grads), losses = jax.lax.scan(
            scan_body,
            (state["params"], state["mom"], state["precond"], grads0),
            (micro, keys))

        drift_pre_sync = _drift(params_m)
        # ---- partial participation: sample n_part clients for the average ---
        if n_part < M:
            perm = jax.random.permutation(jax.random.fold_in(key, 3), M)
            w_part = jnp.zeros((M,)).at[perm[:n_part]].set(1.0 / n_part)
        else:
            w_part = jnp.full((M,), 1.0 / M)
        # ---- synchronization: average the post-step client variables --------
        def _wmean(p):
            wb = w_part.reshape((M,) + (1,) * (p.ndim - 1)).astype(p.dtype)
            return (p * wb).sum(axis=0)

        if sv_cfg.sync_dtype:
            sd = jnp.dtype(sv_cfg.sync_dtype)

            def avg(p):
                # the barrier pins the low-precision representation so BOTH
                # legs of the sync (reduce + broadcast-back) move sync_dtype
                # bytes; the f32 cast happens locally after (quantized
                # averaging — same family as the quantization line of related
                # work [19,20]; sync noise ~2^-8 relative)
                q = jax.lax.optimization_barrier(p.astype(sd))
                a = _wmean(q)
                return jax.lax.optimization_barrier(a)
        else:
            avg = _wmean
        params_avg = jax.tree.map(avg, params_m)
        # broadcast back in sync_dtype; cast to master dtype locally
        params_m = jax.tree.map(
            lambda p, a: jnp.broadcast_to(a[None], (p.shape[0],) + a.shape
                                          ).astype(p.dtype),
            params_m, params_avg)
        params_avg = jax.tree.map(
            lambda x: x[0], params_m)
        if sv_cfg.average_momentum:
            mom_m = jax.tree.map(
                lambda m: jnp.broadcast_to(avg(m)[None],
                                           m.shape).astype(m.dtype), mom_m)

        # ---- D update at sync (global scaling; Algorithm 1 line 4) ----------
        if local_global_d and pc_cfg.kind != "identity":
            g_last = last_grads  # (M, ...) — grads of the sync step
            if sv_cfg.stat_source == "avg_grad":
                g_avg = jax.tree.map(avg, g_last)  # participation+dtype apply
                if pc_cfg.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1, 0], micro)
                    stat = PC.hutchinson_diag(loss_fn, params_avg, sync_micro,
                                              jax.random.fold_in(key, 7))
                elif pc_cfg.rule == "linear":
                    stat = jax.tree.map(jnp.abs, g_avg)
                else:
                    stat = PC.grad_stat(g_avg)
            else:  # avg_local
                if pc_cfg.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1], micro)  # (M,...)
                    hk = jax.random.split(jax.random.fold_in(key, 7), M)
                    stats = jax.vmap(lambda p, mc, k: PC.hutchinson_diag(
                        loss_fn, p, mc, k))(params_m, sync_micro, hk)
                elif pc_cfg.rule == "linear":
                    stats = jax.tree.map(jnp.abs, g_last)
                else:
                    stats = PC.grad_stat(g_last)
                stat = jax.tree.map(lambda s: s.mean(axis=0), stats)
            pstate = PC.update(pc_cfg, pstate, stat)

        new_state = {
            "params": params_m,
            "mom": mom_m,
            "precond": pstate,
            "round": state["round"] + 1,
        }
        metrics = {
            "loss": losses.mean(),
            "loss_per_client": losses[-1],
            "client_drift": drift_pre_sync,
        }
        return new_state, metrics

    return round_step


def _drift(params_m):
    """(1/M)Σ‖x^m − x̂‖² — the V_t of the analysis (0 right after sync)."""
    def per_leaf(p):
        mean = p.mean(axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)
    return sum(jax.tree.leaves(jax.tree.map(per_leaf, params_m)))


def average_params(state):
    return jax.tree.map(lambda p: p[0], state["params"])
