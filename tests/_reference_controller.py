"""Numpy oracle for core/controller.py (DESIGN.md §10).

Replays ``init_ctrl_state``/``controller_step`` step-for-step so an engine
run's knob trajectory can be reproduced from its logged per-round
observations alone (tests/test_controller.py pins this). The replay
contract:

  * every INTEGER knob — t, H_t, H_m, b_eff — replays BITWISE: the
    controller routes them through exact python-int lookup tables
    (``budget_table``/``growth_table``), so float32 rounding never reaches a
    floor();
  * ``k`` replays bitwise too (single float32 multiplies, no add chains);
  * the float EMAs (gns_ema, resid_ema) replay to within 1 ulp: LLVM may
    contract the traced mul+add into an FMA (single rounding) that separate
    numpy ops cannot reproduce. A 1-ulp EMA difference can flip a threshold
    comparison only when the EMA lands exactly on noise_target/resid_guard —
    measure-zero, and deterministic for fixed test data.

Keep in lockstep with src/repro/core/controller.py; do not import jax here
(the whole point is an independent implementation).
"""
from __future__ import annotations

import math

import numpy as np

_TINY = np.float32(1e-12)


def half_up(x: float) -> int:
    return int(math.floor(x + 0.5))


def buffer_depth(spec) -> int:
    if spec.buffer_max <= 0:
        return 1
    spread = (max(spec.step_times) / min(spec.step_times)
              if spec.step_times else 1.0)
    return max(1, min(spec.buffer_max, half_up(spread / spec.spread_per_slot)))


def budget_table(spec, n_clients: int) -> tuple:
    ts = spec.step_times or (1.0,) * n_clients
    assert len(ts) == n_clients
    lo = 0 if spec.buffer_max > 0 else 1
    tmin = min(ts)
    return tuple(
        tuple(max(lo, min(h, int(math.floor(h * tmin / t + 1e-6))))
              for t in ts)
        for h in range(spec.h_max + 1))


def growth_table(spec) -> tuple:
    return tuple(
        min(spec.h_max, max(h + 1, half_up(h * spec.h_growth)))
        for h in range(spec.h_max + 1))


def budget_h(spec, h_t, n_clients: int) -> np.ndarray:
    return np.asarray(budget_table(spec, n_clients)[int(h_t)], np.int32)


def init_ctrl_state(spec, n_clients: int) -> dict:
    return {
        "t": np.int32(0),
        "gns_ema": np.float32(0.0),
        "resid_ema": np.float32(0.0),
        "h_t": np.int32(spec.h_min),
        "h_m": budget_h(spec, spec.h_min, n_clients),
        "k": np.float32(spec.k_max),
        "b_eff": np.int32(buffer_depth(spec)),
    }


def _ema_update(ema: float, old: np.float32, new: np.float32) -> np.float32:
    return np.float32(np.float32(ema) * old + np.float32(1.0 - ema) * new)


def controller_step(spec, ctrl_state: dict, obs: dict):
    M = ctrl_state["h_m"].shape[0]
    first = int(ctrl_state["t"]) == 0

    # -- gradient-noise scale -> monotone H_t growth ------------------------
    d2m = np.float32(obs["delta_sq_mean"])
    d2a = np.float32(obs["delta_sq_avg"])
    gns = np.maximum(d2m - d2a, np.float32(0.0)) / np.maximum(d2a, _TINY)
    gns_ema = gns if first else _ema_update(spec.ema,
                                            np.float32(ctrl_state["gns_ema"]),
                                            gns)
    h_t = int(ctrl_state["h_t"])
    if gns_ema > np.float32(spec.noise_target):
        h_t = growth_table(spec)[h_t]
    h_m = budget_h(spec, h_t, M)

    # -- EF-residual-norm guard -> compression-k schedule -------------------
    payload = np.float32(obs["payload_sq"])
    resid = np.float32(obs["resid_sq"])
    ratio = np.sqrt(resid / np.maximum(payload, _TINY))
    resid_ema = np.float32(ctrl_state["resid_ema"])
    k = np.float32(ctrl_state["k"])
    if payload > 0.0:
        resid_ema = ratio if first else _ema_update(spec.ema, resid_ema,
                                                    ratio)
        if resid_ema > np.float32(spec.resid_guard):
            k = np.minimum(np.float32(k * np.float32(spec.k_growth)),
                           np.float32(spec.k_max))
        else:
            k = np.maximum(np.float32(k * np.float32(spec.k_shrink)),
                           np.float32(spec.k_min))

    new_state = {
        "t": np.int32(ctrl_state["t"] + 1),
        "gns_ema": np.float32(gns_ema),
        "resid_ema": np.float32(resid_ema),
        "h_t": np.int32(h_t),
        "h_m": h_m,
        "k": np.float32(k),
        "b_eff": np.int32(buffer_depth(spec)),
    }
    knobs = {"h_m": h_m, "k": new_state["k"], "b_eff": new_state["b_eff"]}
    return new_state, knobs
