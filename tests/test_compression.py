"""Compressed-communication layer — the differential test harness.

Locks down engine.CompressionSpec (DESIGN.md §4):
  * differential pinning: compression "none" (and every identity-resolving
    spec) is bit-identical to the pre-PR engine snapshot
    (tests/_reference_engine.py) for all six METHODS;
  * operator identities at k=dim;
  * EF topk converges on the Section 5 heterogeneous quadratic where plain
    topk stalls (within 2% of the uncompressed final loss);
  * the fused Pallas quantize_update kernel is bit-equal to the inline path
    and to the pure-jnp oracle;
  * property-style invariants (int8/randk unbiasedness, topk+EF residual
    identity, participation weights under compression) — deterministic
    versions plus hypothesis variants via _hypothesis_compat;
  * spec validation (the SyncSpec.__post_init__ fix).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _reference_engine as ref_engine
from _hypothesis_compat import given, settings, st
from repro.core import engine
from repro.data import QuadraticLoader, QuadraticProblem
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, build_round_step, init_state, spec, rounds=4, H=3, seed=0,
         n_clients=4):
    loss = _quad_loss(problem)
    step = jax.jit(build_round_step(loss, spec))
    state = init_state(jax.random.PRNGKey(0),
                       lambda k: {"x": jnp.zeros(24)}, spec, n_clients)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
    return state, met


MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)


# --------------------------------------------------------------------------- #
# differential: none-compression == pre-PR engine, bit-for-bit, all 6 methods
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("method", engine.METHODS)
def test_none_compression_bit_identical_to_prepr_engine(problem, method):
    """The compression layer's identity path emits the exact pre-PR program:
    trajectories agree BITWISE with the verbatim engine snapshot."""
    spec_new = engine.method_spec(method, **MS_KW)
    assert spec_new.sync.compression.is_identity()
    spec_ref = ref_engine.method_spec(method, **MS_KW)
    st_new, met_new = _run(problem, engine.build_round_step,
                           engine.init_state, spec_new)
    st_ref, met_ref = _run(problem, ref_engine.build_round_step,
                           ref_engine.init_state, spec_ref)
    np.testing.assert_array_equal(np.asarray(st_new["params"]["x"]),
                                  np.asarray(st_ref["params"]["x"]))
    np.testing.assert_array_equal(np.asarray(st_new["mom"]["x"]),
                                  np.asarray(st_ref["mom"]["x"]))
    if "server" in st_ref:
        np.testing.assert_array_equal(np.asarray(st_new["server"]["v"]["x"]),
                                      np.asarray(st_ref["server"]["v"]["x"]))
    assert float(met_new["loss"]) == float(met_ref["loss"])
    assert "ef" not in st_new
    assert "compression_err" not in met_new


@pytest.mark.parametrize("op,ef", [("topk", False), ("topk", True),
                                   ("randk", False), ("randk", True)])
def test_identity_settings_bit_identical(problem, op, ef):
    """topk/randk at k=dim (k=1.0) resolve to the identity and reproduce the
    uncompressed engine trajectory bit-for-bit — with or without EF (the
    residual would stay zero, so no ef leaf is carried)."""
    comp = engine.CompressionSpec(op=op, k=1.0, error_feedback=ef)
    assert comp.is_identity()
    spec_c = engine.method_spec("savic", **MS_KW, compression=comp)
    spec_n = engine.method_spec("savic", **MS_KW)
    st_c, _ = _run(problem, engine.build_round_step, engine.init_state, spec_c)
    st_n, _ = _run(problem, engine.build_round_step, engine.init_state, spec_n)
    np.testing.assert_array_equal(np.asarray(st_c["params"]["x"]),
                                  np.asarray(st_n["params"]["x"]))
    assert "ef" not in st_c


def test_operator_identity_at_full_k():
    """The operators themselves (not just the engine short-circuit) are exact
    at k=dim: compress_tree returns bitwise-identical leaves."""
    key = jax.random.PRNGKey(5)
    tree = {"a": jax.random.normal(key, (4, 13)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 3, 7))}
    for op in ("topk", "randk"):
        out = engine.compress_tree(engine.CompressionSpec(op=op, k=1.0),
                                   tree, jax.random.PRNGKey(9))
        for k_ in tree:
            np.testing.assert_array_equal(np.asarray(out[k_]),
                                          np.asarray(tree[k_]))


def test_int8_fused_kernel_bit_identical_to_inline(problem):
    """use_fused_kernel=True routes int8-stochastic through the Pallas
    quantize_update kernel; trajectories must be BITWISE equal to the inline
    jnp path (same formula, same uniforms)."""
    mk = lambda fused: engine.method_spec(
        "savic", **MS_KW, compression=engine.CompressionSpec(
            op="int8-stochastic", use_fused_kernel=fused))
    st_a, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(False))
    st_b, _ = _run(problem, engine.build_round_step, engine.init_state,
                   mk(True))
    np.testing.assert_array_equal(np.asarray(st_a["params"]["x"]),
                                  np.asarray(st_b["params"]["x"]))


# --------------------------------------------------------------------------- #
# EF convergence: Section 5 heterogeneous quadratic
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def hetero():
    """The Section 5 heterogeneous quadratic (thm2 benchmark family): client
    optima differ, so per-client round deltas conflict and plain topk's bias
    never vanishes — the canonical EF stall scenario."""
    prob = QuadraticProblem.make(d=24, M=8, mu=0.5, L=4.0, sigma=0.1,
                                 heterogeneity=6.0, seed=2)
    Q = jnp.asarray(prob.Q, jnp.float32)
    b = jnp.asarray(prob.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        Qm, bm = Q[micro["cid"]], b[micro["cid"]]
        return 0.5 * (x - bm) @ Qm @ (x - bm) + micro["z"] @ x

    return prob, loss


def _run_hetero(hetero, comp, rounds=200, H=5, seed=0):
    prob, loss = hetero
    spec = engine.method_spec("fedavg", eta_l=0.02, compression=comp)
    step = jax.jit(engine.build_round_step(loss, spec))
    state = engine.init_state(jax.random.PRNGKey(0),
                              lambda k: {"x": jnp.zeros(24)}, spec, 8)
    loader = QuadraticLoader(prob, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    tail = []
    for r in range(rounds):
        key, k = jax.random.split(key)
        state, met = step(state, jax.tree.map(jnp.asarray,
                                              loader.round_batch(H)), k)
        if r >= rounds - 10:
            tail.append(float(met["loss"]))
    return float(np.mean(tail)), state


@pytest.mark.slow
def test_error_feedback_fixes_topk_stall(hetero):
    """Acceptance: plain topk (k=6/24) stalls above the uncompressed loss;
    with the EF residual it matches the uncompressed final loss within 2%."""
    none_loss, _ = _run_hetero(hetero, engine.CompressionSpec())
    plain_loss, _ = _run_hetero(hetero, engine.CompressionSpec(op="topk",
                                                               k=0.25))
    ef_loss, ef_state = _run_hetero(hetero, engine.CompressionSpec(
        op="topk", k=0.25, error_feedback=True))
    assert plain_loss > none_loss * 1.05, (plain_loss, none_loss)
    assert abs(ef_loss - none_loss) <= 0.02 * none_loss, (ef_loss, none_loss)
    # the residual buffer is live client state: per-client, nonzero
    assert ef_state["ef"]["x"].shape == (8, 24)
    assert float(jnp.abs(ef_state["ef"]["x"]).max()) > 0.0


@pytest.mark.slow
def test_randk_ef_is_contractive_and_stable(hetero):
    """Under EF, randk drops its dim/k unbiasedness rescale: the rescaled
    operator is non-contractive and the residual would amplify ~(dim/k − 1)×
    per round into NaN. Masking randk + EF must stay finite and beat plain
    rescaled randk."""
    # operator level: no rescale with EF -> exact-complement residual
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 50))
    c = engine.compress_tree(
        engine.CompressionSpec(op="randk", k=0.1, error_feedback=True),
        {"x": x}, jax.random.PRNGKey(4))["x"]
    kept = np.asarray(c)[np.asarray(c) != 0]
    assert set(kept).issubset(set(np.asarray(x).ravel()))  # unscaled values
    np.testing.assert_array_equal(np.asarray(c + (x - c)), np.asarray(x))
    # engine level: 120 rounds stay finite and near the uncompressed loss
    none_loss, _ = _run_hetero(hetero, engine.CompressionSpec(), rounds=120)
    ef_loss, ef_state = _run_hetero(hetero, engine.CompressionSpec(
        op="randk", k=0.25, error_feedback=True), rounds=120)
    assert np.isfinite(ef_loss)
    assert float(jnp.abs(ef_state["ef"]["x"]).max()) < 1e3
    assert ef_loss <= none_loss * 1.10, (ef_loss, none_loss)


@pytest.mark.slow
def test_int8_stochastic_tracks_uncompressed(hetero):
    """8-bit stochastic sync is unbiased and ~2⁻⁸-relative noise: final loss
    stays within 2% of uncompressed on the same trajectory budget."""
    none_loss, _ = _run_hetero(hetero, engine.CompressionSpec(), rounds=60)
    int8_loss, _ = _run_hetero(hetero, engine.CompressionSpec(
        op="int8-stochastic"), rounds=60)
    assert abs(int8_loss - none_loss) <= 0.02 * none_loss


# --------------------------------------------------------------------------- #
# quantize_update kernel vs pure-jnp oracle
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("n", [17, 4096, 8 * 128 * 16, 8 * 128 * 16 + 3])
def test_quantize_update_matches_ref(n):
    k = jax.random.key(n)
    x = jax.random.normal(jax.random.fold_in(k, 0), (n,)) * 3.0
    u = jax.random.uniform(jax.random.fold_in(k, 1), (n,))
    scale = jnp.abs(x).max() / 127.0
    q, dec = ops.quantize_update(x, u, scale)
    qr, decr = ref.quantize_update_ref(x, u, scale)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(decr))
    # wire-format contract: int8 payload, decode is exactly q·scale
    assert q.dtype == jnp.int8
    assert int(np.abs(np.asarray(q)).max()) <= 127
    np.testing.assert_array_equal(
        np.asarray(dec), np.asarray(q, np.float32) * float(scale))


def test_quantize_update_zero_scale_decodes_zero():
    x = jnp.zeros((300,))
    u = jax.random.uniform(jax.random.PRNGKey(0), (300,))
    q, dec = ops.quantize_update(x, u, jnp.float32(0.0))
    assert not np.asarray(q).any()
    assert not np.asarray(dec).any()


# --------------------------------------------------------------------------- #
# property-style invariants (deterministic + hypothesis via the compat shim)
# --------------------------------------------------------------------------- #


def _int8_mean_over_seeds(x, n_seeds=4096):
    spec = engine.CompressionSpec(op="int8-stochastic")
    keys = jax.random.split(jax.random.PRNGKey(0), n_seeds)
    dec = jax.vmap(lambda k: engine.compress_tree(spec, {"x": x[None]},
                                                  k)["x"][0])(keys)
    return np.asarray(dec.mean(axis=0))


def test_int8_stochastic_is_unbiased():
    """E[decode(encode(x))] = x: mean over seeds within a few standard errors
    of the stochastic-rounding noise (≤ scale/2 per draw)."""
    x = jnp.asarray(np.random.default_rng(3).normal(size=(64,)) * 2.0,
                    jnp.float32)
    scale = float(jnp.abs(x).max()) / 127.0
    mean = _int8_mean_over_seeds(x)
    np.testing.assert_allclose(mean, np.asarray(x),
                               atol=6 * scale / 2 / np.sqrt(4096))


def test_randk_is_unbiased():
    """randk rescales by dim/k so E[C(x)] = x."""
    x = jnp.asarray(np.random.default_rng(4).normal(size=(32,)),
                    jnp.float32)
    spec = engine.CompressionSpec(op="randk", k=0.25)
    keys = jax.random.split(jax.random.PRNGKey(1), 8192)
    dec = jax.vmap(lambda k: engine.compress_tree(spec, {"x": x[None]},
                                                  k)["x"][0])(keys)
    se = np.sqrt(3.0) * np.abs(np.asarray(x)) / np.sqrt(8192)
    np.testing.assert_allclose(np.asarray(dec.mean(axis=0)), np.asarray(x),
                               atol=float(6 * se.max() + 1e-4))


def test_topk_ef_residual_identity():
    """compress(x) + residual == x BITWISE for topk: the operator masks (each
    entry is kept exactly or dropped exactly), so the EF residual is the exact
    complement — nothing is lost between wire and buffer."""
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 97))
    c = engine.compress_tree(engine.CompressionSpec(op="topk", k=0.1),
                             {"x": x}, jax.random.PRNGKey(8))["x"]
    residual = x - c
    np.testing.assert_array_equal(np.asarray(c + residual), np.asarray(x))
    # every client keeps EXACTLY k_count entries — no tie over-keeping
    kept = (np.asarray(c) != 0).sum(axis=1)
    np.testing.assert_array_equal(kept, np.full((4,), engine._k_count(0.1,
                                                                      97)))
    assert engine._k_count(0.1, 97) == 10


def test_topk_exact_k_under_ties():
    """Tied scores used to over-keep (a >= threshold mask kept every tied
    entry); the scatter of lax.top_k indices keeps EXACTLY k_count, breaking
    ties low-index-first, and the measured payload equals the analytic
    accounting."""
    x = jnp.asarray([[1.0, 1.0, 1.0, 1.0],
                     [2.0, -2.0, 2.0, -2.0],
                     [0.0, 0.0, 0.0, 0.0]])      # all-zero row: still exact-k
    comp = engine.CompressionSpec(op="topk", k=0.5)
    c = np.asarray(engine.compress_tree(comp, {"x": x},
                                        jax.random.PRNGKey(0))["x"])
    kc = engine._k_count(0.5, 4)
    assert kc == 2
    # exactly kc survivors per client, lowest indices among the ties
    np.testing.assert_array_equal(c[0], [1.0, 1.0, 0.0, 0.0])
    np.testing.assert_array_equal(c[1], [2.0, -2.0, 0.0, 0.0])
    np.testing.assert_array_equal((c != 0).sum(axis=1), [kc, kc, 0])
    # measured wire bytes == analytic bytes_on_wire (ties included)
    measured = engine.measured_wire_bytes(comp, {"x": jnp.asarray(c)})
    analytic = engine.bytes_on_wire(
        engine.method_spec("fedavg", compression=comp),
        {"x": jax.ShapeDtypeStruct((4,), jnp.float32)})["delta_bytes"]
    assert analytic == kc * (4 + 4) == 16
    # rows 0/1 moved exactly the analytic payload; the zero row moved less
    np.testing.assert_array_equal(measured[:2], [analytic, analytic])


def test_k_count_and_participation_round_half_up():
    """Both code paths round half-integers UP (floor(x + 0.5)); python
    round()'s banker's rounding sent k=0.5 of a 5-element leaf to 2 kept
    entries and participation=0.5 of M=5 to 2 sampled clients."""
    assert engine._k_count(0.5, 5) == 3          # round(2.5) would give 2
    assert engine._k_count(0.3, 5) == 2          # floor(1.5 + 0.5)
    assert engine._k_count(0.1, 1000) == 100     # unchanged on exact cases
    assert engine._k_count(0.25, 30) == 8        # floor(7.5 + 0.5)
    c = engine.compress_tree(engine.CompressionSpec(op="topk", k=0.5),
                             {"x": jnp.arange(1.0, 6.0)[None]},
                             jax.random.PRNGKey(0))["x"]
    assert int((np.asarray(c) != 0).sum()) == 3
    w = np.asarray(engine.participation_weights(
        engine.SyncSpec(participation=0.5), jax.random.PRNGKey(2), 5))
    assert (w > 0).sum() == 3                    # round(2.5) would give 2
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    np.testing.assert_allclose(w[w > 0], 1.0 / 3.0, rtol=1e-6)


def test_participation_weights_sum_to_one_under_compression():
    """Client sampling composes with compression: the weights are unchanged
    by the compression layer and still sum to 1; a compressed partial-
    participation round still broadcasts one agreed point to every client."""
    key = jax.random.PRNGKey(0)
    for M, part in [(4, 0.5), (8, 0.25), (5, 0.3)]:
        w = np.asarray(engine.participation_weights(
            engine.SyncSpec(participation=part,
                            compression=engine.CompressionSpec(op="topk",
                                                               k=0.1)),
            key, M))
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    prob = QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)
    spec = engine.method_spec(
        "fedavg", eta_l=0.01, participation=0.5,
        compression=engine.CompressionSpec(op="topk", k=0.2,
                                           error_feedback=True))
    state, _ = _run(prob, engine.build_round_step, engine.init_state, spec)
    p = np.asarray(state["params"]["x"])
    np.testing.assert_array_equal(p, np.broadcast_to(p[:1], p.shape))


@given(st.integers(min_value=1, max_value=12),
       st.floats(min_value=0.01, max_value=1.0))
@settings(max_examples=25, deadline=None)
def test_participation_weights_property(M, part):
    w = np.asarray(engine.participation_weights(
        engine.SyncSpec(participation=part,
                        compression=engine.CompressionSpec(op="randk",
                                                           k=0.5)),
        jax.random.PRNGKey(1), M))
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


@given(st.integers(min_value=1, max_value=200))
@settings(max_examples=20, deadline=None)
def test_topk_ef_identity_property(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 31))
    c = engine.compress_tree(engine.CompressionSpec(op="topk", k=0.13),
                             {"x": x}, jax.random.PRNGKey(seed + 1))["x"]
    np.testing.assert_array_equal(np.asarray(c + (x - c)), np.asarray(x))


@given(st.integers(min_value=0, max_value=100))
@settings(max_examples=10, deadline=None)
def test_int8_unbiased_property(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 1.5
    scale = float(jnp.abs(x).max()) / 127.0
    mean = _int8_mean_over_seeds(x, n_seeds=2048)
    np.testing.assert_allclose(mean, np.asarray(x),
                               atol=8 * scale / 2 / np.sqrt(2048) + 1e-7)


# --------------------------------------------------------------------------- #
# spec validation (the SyncSpec/__post_init__ fix) + bytes-on-wire accounting
# --------------------------------------------------------------------------- #


def test_spec_validation_rejects_unknowns():
    with pytest.raises(ValueError):
        engine.CompressionSpec(op="gzip")
    with pytest.raises(ValueError):
        engine.CompressionSpec(k=0.0)
    with pytest.raises(ValueError):
        engine.CompressionSpec(k=1.5)
    with pytest.raises(ValueError):
        engine.SyncSpec(sync_dtype="float999")
    with pytest.raises(ValueError):
        engine.SyncSpec(participation=0.0)
    with pytest.raises(ValueError):
        engine.SyncSpec(participation=1.5)
    with pytest.raises(ValueError):
        engine.SyncSpec(compression="topk")  # must be a CompressionSpec
    # valid settings still construct (matches ClientLoopSpec behavior)
    engine.SyncSpec(sync_dtype="bfloat16", participation=0.5,
                    compression=engine.CompressionSpec(op="randk", k=0.5))


def test_bytes_on_wire_matches_measured_payload():
    """The analytic accounting equals the ENCODED payload measured from the
    real arrays compress_tree emits — per client, for every operator (the
    analytic side was previously untested against actual compressions)."""
    key = jax.random.PRNGKey(11)
    M = 4
    tree = {"a": jax.random.normal(key, (M, 157)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 10, 3))}
    params_one = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)
    for op, k in [("topk", 0.1), ("topk", 0.37), ("randk", 0.25),
                  ("int8-stochastic", 1.0)]:
        comp = engine.CompressionSpec(op=op, k=k)
        c = engine.compress_tree(comp, tree, jax.random.fold_in(key, 2))
        measured = engine.measured_wire_bytes(comp, c)
        spec = engine.method_spec("fedavg", compression=comp)
        analytic = engine.bytes_on_wire(spec, params_one)["delta_bytes"]
        # continuous deltas: no threshold ties, no exact-zero survivors —
        # every client's encoded payload is exactly the analytic count
        np.testing.assert_array_equal(measured, np.full((M,), analytic),
                                      err_msg=f"{op} k={k}")
    # identity: every element moves at elem_bytes
    ident = engine.measured_wire_bytes(engine.CompressionSpec(), tree)
    np.testing.assert_array_equal(ident, np.full((M,), (157 + 30) * 4))
    np.testing.assert_array_equal(
        engine.measured_wire_bytes(engine.CompressionSpec(), tree,
                                   elem_bytes=2),
        np.full((M,), (157 + 30) * 2))


@given(st.sampled_from(["topk", "randk", "int8-stochastic"]),
       st.floats(min_value=0.01, max_value=1.0),
       st.integers(min_value=1, max_value=60),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=40, deadline=None)
def test_measured_equals_analytic_wire_bytes_property(op, k, n, seed):
    """measured_wire_bytes == bytes_on_wire's analytic per-client payload for
    every (operator × k × leaf shape): continuous deltas keep exactly
    _k_count entries, so the two accountings agree to the byte."""
    M = 3
    x = jax.random.normal(jax.random.PRNGKey(seed), (M, n))
    comp = engine.CompressionSpec(op=op, k=k)
    c = engine.compress_tree(comp, {"x": x}, jax.random.PRNGKey(seed + 1))
    measured = engine.measured_wire_bytes(comp, c)
    analytic = engine.bytes_on_wire(
        engine.method_spec("fedavg", compression=comp),
        {"x": jax.ShapeDtypeStruct((n,), jnp.float32)})["delta_bytes"]
    np.testing.assert_array_equal(measured, np.full((M,), analytic),
                                  err_msg=f"{op} k={k} n={n}")


def test_bytes_on_wire_accounting():
    params = {"x": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    fedavg = lambda **kw: engine.method_spec("fedavg", **kw)
    assert engine.bytes_on_wire(fedavg(), params)["total_bytes"] == 4000
    topk = engine.bytes_on_wire(
        fedavg(compression="topk", compression_k=0.1), params)
    assert topk["total_bytes"] == 100 * (4 + 4)      # (value, index) pairs
    assert topk["compression_x"] == 5.0
    int8 = engine.bytes_on_wire(
        fedavg(compression="int8-stochastic"), params)
    assert int8["total_bytes"] == 1000 + 4           # payload + scale
    # momentum rides uncompressed under an averaging server (savic default)
    savic_bf16 = engine.bytes_on_wire(
        engine.method_spec("savic", sync_dtype="bfloat16"), params)
    assert savic_bf16["momentum_bytes"] == 2000
    assert savic_bf16["total_bytes"] == 4000


# --------------------------------------------------------------------------- #
# launch layer: EF leaf threading through build_train_step shardings
# --------------------------------------------------------------------------- #


def test_build_train_step_threads_compression_and_ef_sharding():
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig
    from repro.launch.steps import build_train_step

    dev = np.array(jax.devices("cpu")[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    comp = engine.CompressionSpec(op="topk", k=0.1, error_feedback=True)
    built = build_train_step("qwen2-0.5b", shape, mesh, method="fedadam",
                             reduced=True, h_local=2, compression=comp)
    assert built.meta["engine_spec"].sync.compression == comp
    state_shape = built.args[0]
    assert "ef" in state_shape
    p0 = jax.tree.leaves(state_shape["params"])[0]
    e0 = jax.tree.leaves(state_shape["ef"])[0]
    assert e0.shape == p0.shape           # per-client: leading M dim
    state_spec, _ = built.in_shardings
    # ef sharded exactly like params (DESIGN.md §2/§4)
    assert jax.tree.structure(state_spec["ef"]) \
        == jax.tree.structure(state_shape["ef"])
    assert str(jax.tree.leaves(state_spec["ef"])[0]) \
        == str(jax.tree.leaves(state_spec["params"])[0])
