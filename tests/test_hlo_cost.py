"""Trip-count-aware HLO cost model vs analytically known counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo_cost import analyze, xla_cost_properties
from repro.utils.hlo import collective_bytes


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_matmul_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return y
    c = _compile(f, jnp.ones((256, 512)), jnp.ones((512, 512)))
    r = analyze(c.as_text())
    assert r["flops"] == 16 * 2 * 256 * 512 * 512
    assert not r["unknown_trip_loops"]


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    c = _compile(g, jnp.ones((128, 256)), jnp.ones((256, 256)))
    r = analyze(c.as_text())
    assert r["flops"] == 12 * 2 * 128 * 256 * 256


def test_plain_matmul_and_bytes():
    def f(a, b):
        return a @ b
    c = _compile(f, jnp.ones((64, 128)), jnp.ones((128, 32)))
    r = analyze(c.as_text())
    assert r["flops"] == 2 * 64 * 128 * 32
    assert r["bytes"] >= 4 * (64 * 128 + 128 * 32 + 64 * 32)


def test_cost_analysis_undercounts_but_we_do_not():
    """The reason this module exists."""
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y
    c = _compile(f, jnp.ones((128, 128)))
    xla = xla_cost_properties(c)["flops"]
    ours = analyze(c.as_text())["flops"]
    assert ours == pytest.approx(8 * xla, rel=1e-6)


def test_xla_cost_properties_normalizes_list_returns():
    """Regression: newer jaxlib's cost_analysis() returns a LIST (one dict
    per executable) — the CI container does this — while older versions
    return the dict directly. xla_cost_properties must flatten every shape
    to one plain dict."""
    class Fake:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    assert xla_cost_properties(Fake([{"flops": 7.0}]))["flops"] == 7.0
    assert xla_cost_properties(Fake(({"flops": 3.0},)))["flops"] == 3.0
    assert xla_cost_properties(Fake({"flops": 5.0}))["flops"] == 5.0
    assert xla_cost_properties(Fake([])) == {}
    assert xla_cost_properties(Fake(None)) == {}
    # and against a REAL compiled executable on this container's jaxlib:
    # whatever shape cost_analysis() returns, the result is one flat dict
    c = _compile(lambda a, b: a @ b, jnp.ones((16, 16)), jnp.ones((16, 16)))
    cost = xla_cost_properties(c)
    assert isinstance(cost, dict) and cost.get("flops", 0) > 0


def test_collective_parser_smoke():
    # single-device module: no collectives
    c = _compile(lambda x: x * 2, jnp.ones((8,)))
    total, kinds, counts = collective_bytes(c.as_text())
    assert total == 0 and kinds == {} and counts == {}
