"""Subprocess worker for sharding tests (needs its own XLA device count —
jax locks the device count at first init, so the main pytest process keeps 1
device and this worker gets 8)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import PrecondConfig, SavicConfig, savic
from repro.models import ModelCallConfig, build, sample_batch
from repro.sharding import AxisPlan, batch_pspecs, params_pspecs


def main(arch: str):
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         devices=jax.devices()[:8])
    plan = AxisPlan(client=("data",), batch=(), model=("model",))
    cfg = get_config(arch, reduced=True)
    model = build(cfg, ModelCallConfig(dtype=jnp.float32))
    pc = PrecondConfig(kind="adam", alpha=1e-6)
    sv = SavicConfig(gamma=1e-3, beta1=0.9)
    step = savic.build_round_step(model.loss, pc, sv)

    M, H, B, S = 2, 2, 2, 32
    state = savic.init_state(jax.random.PRNGKey(0), model.init, pc, sv, M)
    micro = sample_batch(cfg, jax.random.PRNGKey(1), B, S)
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (M, H) + x.shape), micro)
    key = jax.random.PRNGKey(2)

    # ---- single-device reference ---------------------------------------------
    ref_state, ref_met = jax.jit(step)(state, batch, key)
    ref_loss = float(ref_met["loss"])

    # ---- sharded --------------------------------------------------------------
    pspec = params_pspecs(cfg, jax.eval_shape(lambda: state["params"]), mesh,
                          plan, client_dim=True)
    dspec = params_pspecs(cfg, jax.eval_shape(lambda: state["precond"]["d"]),
                          mesh, plan, client_dim=False)
    state_spec = {"params": pspec, "mom": pspec,
                  "precond": {"d": dspec, "t": P()}, "round": P()}
    bspec = batch_pspecs(jax.eval_shape(lambda: batch), mesh, plan,
                         client_dim=True)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    with mesh:
        sharded = jax.jit(step, in_shardings=(ns(state_spec), ns(bspec), None))
        out_state, met = sharded(state, batch, key)
    loss = float(met["loss"])
    assert abs(loss - ref_loss) < 5e-3, (loss, ref_loss)

    # params equal too (averaging and update independent of placement)
    for a, b in zip(jax.tree.leaves(out_state["params"]),
                    jax.tree.leaves(ref_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-4)
    print(f"OK {arch} sharded_loss={loss:.5f} ref={ref_loss:.5f}")


if __name__ == "__main__":
    main(sys.argv[1])
