"""SAVIC Algorithm-1 behaviour: equivalences, convergence, drift, baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedopt
from repro.core.preconditioner import PrecondConfig
from repro.core import savic
from repro.core.savic import SavicConfig
from repro.data import QuadraticLoader, QuadraticProblem


def _quad_loss(problem):
    Q = jnp.asarray(problem.Q, jnp.float32)      # (M,d,d) — use client 0's Q
    b = jnp.asarray(problem.b, jnp.float32)

    def loss(params, micro):
        x = params["x"]
        # identical-data quadratic + unbiased noise in the linear term
        return 0.5 * (x - b[0]) @ Q[0] @ (x - b[0]) + micro["z"] @ x

    return loss


def _run(problem, pc, sv, rounds=40, H=5, seed=0):
    loss = _quad_loss(problem)
    step = jax.jit(savic.build_round_step(loss, pc, sv))
    M = problem.Q.shape[0]
    state = savic.init_state(jax.random.PRNGKey(seed),
                             lambda k: {"x": jnp.zeros(problem.b.shape[1])},
                             pc, sv, M)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    hist = []
    for r in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(H))
        state, met = step(state, batch, k)
        hist.append(float(met["loss"]))
    return state, hist, met


@pytest.fixture(scope="module")
def problem():
    return QuadraticProblem.make(d=24, M=4, mu=0.5, L=5.0, sigma=0.3, seed=0)


def test_identity_matches_local_sgd_manual(problem):
    """SAVIC with D=I and β₁=0 must reproduce hand-rolled Local SGD exactly."""
    pc = PrecondConfig(kind="identity")
    sv = SavicConfig(gamma=0.05, beta1=0.0)
    loss = _quad_loss(problem)
    step = savic.build_round_step(loss, pc, sv)
    M, d = problem.b.shape
    state = savic.init_state(jax.random.PRNGKey(0),
                             lambda k: {"x": jnp.zeros(d)}, pc, sv, M)
    loader = QuadraticLoader(problem, seed=0)
    batch = jax.tree.map(jnp.asarray, loader.round_batch(3))
    key = jax.random.PRNGKey(1)
    new_state, _ = jax.jit(step)(state, batch, key)

    # manual local SGD: x_m <- x_m - γ g, then average
    Q0 = jnp.asarray(problem.Q[0], jnp.float32)
    b0 = jnp.asarray(problem.b[0], jnp.float32)
    xs = np.zeros((M, d), np.float32)
    for h in range(3):
        for m in range(M):
            g = np.asarray(Q0 @ (xs[m] - b0)) + np.asarray(batch["z"][m, h])
            xs[m] = xs[m] - 0.05 * g
    avg = xs.mean(axis=0)
    got = np.asarray(new_state["params"]["x"])
    np.testing.assert_allclose(got, np.broadcast_to(avg, (M, d)), rtol=2e-5,
                               atol=2e-6)


def test_drift_zero_after_sync(problem):
    pc = PrecondConfig(kind="adam", alpha=1e-4)
    sv = SavicConfig(gamma=0.02, beta1=0.9)
    state, _, met = _run(problem, pc, sv, rounds=3)
    p = np.asarray(state["params"]["x"])
    assert np.allclose(p, p[0:1], atol=1e-7), "clients identical after sync"
    assert float(met["client_drift"]) > 0.0, "drift measured pre-sync"


@pytest.mark.parametrize("kind", ["identity", "adam", "rmsprop", "oasis"])
def test_convergence_all_preconditioners(problem, kind):
    # α=1e-2: with the corrected Adam debias (β_1=0) the floor must carry
    # the early-round stability that the D⁰=1 init used to provide.
    pc = PrecondConfig(kind=kind, alpha=1e-2)
    sv = SavicConfig(gamma=0.03, beta1=0.0)
    state, hist, _ = _run(problem, pc, sv, rounds=60)
    x = np.asarray(savic.average_params(state)["x"])
    xstar = problem.x_star()
    assert np.linalg.norm(x - xstar) < 0.3, (kind, np.linalg.norm(x - xstar))
    assert hist[-1] < hist[0]


def test_local_scaling_converges(problem):
    pc = PrecondConfig(kind="adam", alpha=1e-3)
    sv = SavicConfig(gamma=0.03, beta1=0.0, scaling="local")
    state, hist, _ = _run(problem, pc, sv, rounds=60)
    x = np.asarray(savic.average_params(state)["x"])
    assert np.linalg.norm(x - problem.x_star()) < 0.4


def test_global_d_has_no_client_dim(problem):
    pc = PrecondConfig(kind="adam", alpha=1e-3)
    sv = SavicConfig(gamma=0.03)
    state = savic.init_state(jax.random.PRNGKey(0),
                             lambda k: {"x": jnp.zeros(24)}, pc, sv, 4)
    assert state["precond"]["d"]["x"].shape == (24,)
    sv_local = SavicConfig(gamma=0.03, scaling="local")
    state_l = savic.init_state(jax.random.PRNGKey(0),
                               lambda k: {"x": jnp.zeros(24)}, pc, sv_local, 4)
    assert state_l["precond"]["d"]["x"].shape == (4, 24)


def test_more_local_steps_bigger_drift(problem):
    """V_t grows with H (Lemma 2: E[V_t] ≤ (H-1)γ²σ²/α)."""
    pc = PrecondConfig(kind="identity")
    drifts = []
    for H in (2, 8):
        sv = SavicConfig(gamma=0.05, beta1=0.0)
        _, _, met = _run(problem, pc, sv, rounds=5, H=H)
        drifts.append(float(met["client_drift"]))
    assert drifts[1] > drifts[0]


# --------------------------------------------------------------------------- #
# FedOpt baseline ([42]) — including the paper's §5.2 τ→0 critique
# --------------------------------------------------------------------------- #


def _fed_run(problem, cfg, rounds=30, K=5, seed=0):
    loss = _quad_loss(problem)
    step = jax.jit(fedopt.build_round_step(loss, cfg))
    state = fedopt.init_state(jax.random.PRNGKey(seed),
                              lambda k: {"x": jnp.zeros(problem.b.shape[1])},
                              cfg)
    loader = QuadraticLoader(problem, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    mets = []
    for _ in range(rounds):
        key, k = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, loader.round_batch(K))
        state, met = step(state, batch, k)
        mets.append({k2: float(v) for k2, v in met.items()})
    return state, mets


@pytest.mark.parametrize("server_opt", ["adagrad", "adam", "yogi"])
def test_fedopt_converges(problem, server_opt):
    cfg = fedopt.FedOptConfig(server_opt=server_opt, eta=0.1, eta_l=0.02,
                              tau=1e-2)
    state, mets = _fed_run(problem, cfg, rounds=40)
    assert mets[-1]["loss"] < mets[0]["loss"]


def test_fedopt_tau_zero_paper_5_2(problem):
    """Paper §5.2 critique, both directions.

    With v_{-1} = 1 (the setting of the paper's chain of conclusions 1-6) and
    η_l ~ τ, the server step is m_t/(√v_t+τ) ~ τ → the iterates freeze as
    τ→0. With v_{-1} = τ² (the paper's proposed resolution), Δ/(√v+τ) ~ const
    and the step size stays O(1).
    """
    # stall: v_{-1} = 1
    stall = []
    for tau in (1e-1, 1e-5):
        cfg = fedopt.FedOptConfig(server_opt="adagrad", eta=0.05,
                                  eta_l=0.5 * tau, tau=tau, beta1=0.0,
                                  v_init=1.0)
        _, mets = _fed_run(problem, cfg, rounds=5)
        stall.append(np.mean([m["step_norm"] for m in mets]))
    assert stall[1] < stall[0] * 1e-2, stall

    # resolved: v_{-1} = τ² (the default)
    ok = []
    for tau in (1e-1, 1e-5):
        cfg = fedopt.FedOptConfig(server_opt="adagrad", eta=0.05,
                                  eta_l=0.5 * tau, tau=tau, beta1=0.0)
        _, mets = _fed_run(problem, cfg, rounds=5)
        ok.append(np.mean([m["step_norm"] for m in mets]))
    assert 0.2 < ok[1] / ok[0] < 5.0, ok


def test_sync_dtype_bf16_still_converges(problem):
    """Beyond-paper sync compression: bf16 quantized averaging still
    converges to a comparable neighborhood (precision note in §Perf C2)."""
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    sv = SavicConfig(gamma=0.03, beta1=0.0, sync_dtype="bfloat16")
    state, hist, _ = _run(problem, pc, sv, rounds=60)
    x = np.asarray(savic.average_params(state)["x"])
    assert np.linalg.norm(x - problem.x_star()) < 0.5


def test_partial_participation(problem):
    """FedAvg-style client sampling: converges with participation<1 and the
    full-participation path is numerically unchanged."""
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    sv_half = SavicConfig(gamma=0.03, beta1=0.0, participation=0.5)
    state, hist, _ = _run(problem, pc, sv_half, rounds=60)
    x = np.asarray(savic.average_params(state)["x"])
    assert np.linalg.norm(x - problem.x_star()) < 0.5

    # participation=1.0 must equal plain mean exactly
    sv_full = SavicConfig(gamma=0.03, beta1=0.0, participation=1.0)
    s1, _, _ = _run(problem, pc, sv_full, rounds=3)
    sv_ref = SavicConfig(gamma=0.03, beta1=0.0)
    s2, _, _ = _run(problem, pc, sv_ref, rounds=3)
    np.testing.assert_allclose(np.asarray(s1["params"]["x"]),
                               np.asarray(s2["params"]["x"]), rtol=1e-6)
