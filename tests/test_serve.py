"""Serving-path pins (DESIGN.md §8): prefill-cache reuse, the fused decode
kernels, per-slot vector positions, and continuous batching.

What is pinned bitwise and what is pinned by tolerance is deliberate:

* kernel vs oracle, vector-pos vs scalar-pos, and windowed vs full decode are
  BITWISE — same math, same accumulation order by construction.
* prefill-cache reuse vs prompt replay is pinned on greedy token ids plus
  softmax probabilities: bitwise equality is unattainable here because XLA
  picks different gemm accumulation orders for the (B,S,d) prefill matmuls
  than for the (B,1,d) decode matmuls, so the two caches differ in the last
  bf16 ulp. The tolerance budget matches tests/test_models._AGREE_TOL.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.models import ModelCallConfig, build, sample_batch

# one arch per served model family; MoE runs exact (capacity buffers are
# batch-shared, so dropped-token interference would couple decode slots)
FAMILY_ARCHS = [
    ("qwen2-0.5b", {}),                          # dense transformer
    ("deepseek-67b", {}),                        # MLA
    ("mamba2-1.3b", {}),                         # SSM
    ("zamba2-2.7b", {}),                         # hybrid (shared attn)
    ("qwen2-moe-a2.7b", {"exact_moe": True}),    # MoE
]
FAMILY_IDS = [a for a, _ in FAMILY_ARCHS]

# max |Δp| on softmax probs, per arch (matches test_models._AGREE_TOL)
_REUSE_TOL = {"qwen2-0.5b": 2e-3, "deepseek-67b": 2e-3, "mamba2-1.3b": 5e-3,
              "zamba2-2.7b": 2e-2, "qwen2-moe-a2.7b": 8e-2, "qwen3-4b": 2e-3}


def _model(arch, **kw):
    cfg = get_config(arch, reduced=True)
    call = ModelCallConfig(dtype=jnp.float32, **kw)
    return cfg, build(cfg, call)


def _replay_cache(model, params, toks, cache_len):
    """The old serve path: feed the prompt token-by-token through decode."""
    B, S = toks.shape
    cache = model.init_cache(B, cache_len)
    decode = jax.jit(model.decode)
    logits = None
    for t in range(S):
        logits, cache = decode(params, cache, toks[:, t], jnp.int32(t))
    return logits, cache


def _probs(logits, vocab):
    return np.asarray(jax.nn.softmax(logits[:, :vocab].astype(jnp.float32),
                                     axis=-1))


# --------------------------------------------------------------------------- #
# prefill-cache reuse vs prompt replay
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch,kw", FAMILY_ARCHS, ids=FAMILY_IDS)
def test_prefill_cache_reuse_matches_replay(arch, kw):
    """model.prefill_cache's decode-layout cache continues at pos=S exactly
    like a cache built by replaying the prompt: same greedy continuation, and
    per-step softmax probs within the family tolerance."""
    cfg, model = _model(arch, **kw)
    B, S, G = 2, 8, 5
    params = model.init(jax.random.PRNGKey(0))
    batch = sample_batch(cfg, jax.random.PRNGKey(1), B, S)
    clen = S + G
    lg_r, cache_r = jax.jit(model.prefill_cache, static_argnums=2)(
        params, batch, clen)
    lg_p, cache_p = _replay_cache(model, params, batch["tokens"], clen)
    decode = jax.jit(model.decode)
    tol = _REUSE_TOL[arch]
    for g in range(G):
        d = np.abs(_probs(lg_r, cfg.vocab_size)
                   - _probs(lg_p, cfg.vocab_size)).max()
        assert d < tol, (arch, g, d)
        tok_r = jnp.argmax(lg_r, -1).astype(jnp.int32)
        tok_p = jnp.argmax(lg_p, -1).astype(jnp.int32)
        assert np.array_equal(np.asarray(tok_r), np.asarray(tok_p)), (arch, g)
        if g == G - 1:
            break
        lg_r, cache_r = decode(params, cache_r, tok_r, jnp.int32(S + g))
        lg_p, cache_p = decode(params, cache_p, tok_p, jnp.int32(S + g))


def test_prefill_cache_ring_placement_matches_replay():
    """Prompt longer than the decode window: prefill_to_decode_cache must
    place the surviving tail into ring slots exactly where a token-by-token
    fill would have left them (slot = pos % C), or decode's k_pos
    reconstruction dereferences the wrong cells."""
    arch, W, S, G = "qwen3-4b", 8, 16, 4
    cfg, model = _model(arch, decode_window=W)
    params = model.init(jax.random.PRNGKey(0))
    batch = sample_batch(cfg, jax.random.PRNGKey(1), 2, S)
    lg_r, cache_r = jax.jit(model.prefill_cache, static_argnums=2)(
        params, batch, S + G)
    lg_p, cache_p = _replay_cache(model, params, batch["tokens"], S + G)
    decode = jax.jit(model.decode)
    tol = _REUSE_TOL[arch]
    for g in range(G):
        d = np.abs(_probs(lg_r, cfg.vocab_size)
                   - _probs(lg_p, cfg.vocab_size)).max()
        assert d < tol, (g, d)
        tok_r = jnp.argmax(lg_r, -1).astype(jnp.int32)
        tok_p = jnp.argmax(lg_p, -1).astype(jnp.int32)
        assert np.array_equal(np.asarray(tok_r), np.asarray(tok_p)), g
        if g == G - 1:
            break
        lg_r, cache_r = decode(params, cache_r, tok_r, jnp.int32(S + g))
        lg_p, cache_p = decode(params, cache_p, tok_p, jnp.int32(S + g))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-4b", "gemma3-4b",
                                  "zamba2-2.7b"])
def test_windowed_decode_bitwise_equals_full_for_short_seq(arch):
    """decode_window=W with every position < W is a no-op: the serve driver
    must produce BITWISE the tokens of the unwindowed path (same ring size,
    same mask — a windowing bug would show as a changed token stream)."""
    from repro.launch.serve import serve
    W, S, G = 24, 6, 5
    kw = dict(reduced=True, batch=2, prompt_len=S, gen_len=G,
              cache_len=S + G, seed=0, verbose=False)
    full = serve(arch, decode_window=0, **kw)
    win = serve(arch, decode_window=W, **kw)
    assert np.array_equal(full.tokens, win.tokens)


# --------------------------------------------------------------------------- #
# fused Pallas decode kernels vs their jnp oracles (bitwise)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,H,Hk,C,D,Dv,cap", [
    (2, 8, 2, 48, 64, 64, 0.0),       # GQA rep=4
    (3, 4, 4, 8, 32, 16, 30.0),       # MHA, Dv != D, softcapped
    (1, 16, 4, 96, 128, 128, 0.0),    # deep ring
])
def test_decode_attention_kernel_bitwise(B, H, Hk, C, D, Dv, cap):
    ks = jax.random.split(jax.random.key(C + D), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, C, Hk, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, C, Hk, Dv), jnp.bfloat16)
    pos = jax.random.randint(ks[3], (B,), 0, C, jnp.int32)
    bias = jnp.where(jnp.arange(C)[None] <= pos[:, None], 0.0, -1e30)
    out_k = ops.decode_attention(q, k, v, bias, softcap=cap)
    out_r = jax.jit(lambda *a: kref.decode_attention_ref(*a, softcap=cap))(
        q, k, v, bias)
    assert out_k.dtype == jnp.float32
    assert np.array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("B,d,V,v_real,greedy", [
    (2, 64, 4096, 4000, True),        # pad-vocab masking
    (4, 128, 8192, 8192, False),      # gumbel sampling
    (1, 32, 2048, 100, False),        # tiny real vocab
])
def test_decode_sample_kernel_bitwise(B, d, V, v_real, greedy):
    ks = jax.random.split(jax.random.key(V + B), 3)
    y = jax.random.normal(ks[0], (B, d), jnp.float32)
    table = jax.random.normal(ks[1], (V, d), jnp.float32) * 0.05
    noise = jnp.zeros((B, V), jnp.float32) if greedy \
        else jax.random.gumbel(ks[2], (B, V), jnp.float32)
    tok_k = ops.decode_sample(y, table, noise, scale=d ** -0.5, v_real=v_real)
    tok_r = jax.jit(lambda *a: kref.decode_sample_ref(
        *a, scale=d ** -0.5, v_real=v_real))(y, table, noise)
    assert np.array_equal(np.asarray(tok_k), np.asarray(tok_r))
    assert int(np.asarray(tok_k).max()) < v_real


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma3-4b"])
def test_fused_decode_kernel_token_parity(arch):
    """End-to-end: use_decode_kernel routes decode attention AND the sampling
    tail through the Pallas kernels; the greedy token stream must match the
    unfused model exactly (gemma3 also exercises the softcap path)."""
    cfg, m0 = _model(arch)
    _, m1 = _model(arch, use_decode_kernel=True)
    params = m0.init(jax.random.key(0))
    B, S, G = 2, 12, 6
    batch = sample_batch(cfg, jax.random.PRNGKey(1), B, S)
    lg, c0 = jax.jit(m0.prefill_cache, static_argnums=2)(params, batch, S + G)
    c1 = jax.tree.map(lambda x: x, c0)
    noise = jnp.zeros((B, lg.shape[-1]), jnp.float32)
    t0 = t1 = jnp.argmax(lg, -1).astype(jnp.int32)
    d0, d1 = jax.jit(m0.decode_sample), jax.jit(m1.decode_sample)
    for g in range(G):
        t0, c0 = d0(params, c0, t0, jnp.int32(S + g), noise)
        t1, c1 = d1(params, c1, t1, jnp.int32(S + g), noise)
        assert np.array_equal(np.asarray(t0), np.asarray(t1)), (arch, g)


# --------------------------------------------------------------------------- #
# per-slot vector positions (continuous batching's decode contract)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch,kw", FAMILY_ARCHS, ids=FAMILY_IDS)
def test_vector_pos_decode_bitwise_matches_scalar(arch, kw):
    """decode with pos = full((B,), p) must be BITWISE the scalar-pos decode:
    the vector branch is the same math with per-row indices, so any
    accumulation-order drift here would silently skew every served slot."""
    cfg, model = _model(arch, **kw)
    B, S, G = 2, 8, 4
    params = model.init(jax.random.PRNGKey(0))
    batch = sample_batch(cfg, jax.random.PRNGKey(1), B, S)
    _, cache_s = jax.jit(model.prefill_cache, static_argnums=2)(
        params, batch, S + G)
    cache_v = jax.tree.map(lambda x: x, cache_s)
    decode = jax.jit(model.decode)
    tok_s = tok_v = jnp.zeros((B,), jnp.int32)
    for g in range(G):
        lg_s, cache_s = decode(params, cache_s, tok_s, jnp.int32(S + g))
        lg_v, cache_v = decode(params, cache_v, tok_v,
                               jnp.full((B,), S + g, jnp.int32))
        assert np.array_equal(np.asarray(lg_s), np.asarray(lg_v)), (arch, g)
        tok_s = jnp.argmax(lg_s, -1).astype(jnp.int32)
        tok_v = jnp.argmax(lg_v, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-4b", "gemma3-4b",
                                  "deepseek-67b", "mamba2-1.3b",
                                  "zamba2-2.7b", "qwen2-moe-a2.7b",
                                  "deepseek-v2-236b"])
def test_decode_cache_is_dtype_and_shape_fixed_point(arch):
    """One decode step must return a cache with the leaf dtypes/shapes of
    init_cache: the continuous-batching slot insert (dynamic_update_slice of a
    fresh prefill cache into the live ring) requires the cache pytree to be a
    fixed point of the step, and any silent upcast would also defeat the
    donated serve-step buffer reuse."""
    cfg, model = _model(arch)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 12)
    _, c2 = jax.jit(model.decode)(params, cache, jnp.zeros((2,), jnp.int32),
                                  jnp.zeros((2,), jnp.int32))
    assert jax.tree.map(lambda x: (x.shape, x.dtype), cache) \
        == jax.tree.map(lambda x: (x.shape, x.dtype), c2)


# --------------------------------------------------------------------------- #
# continuous batching: slot ring vs solo / static, zero recompilation
# --------------------------------------------------------------------------- #


@pytest.mark.slow
@pytest.mark.parametrize("arch,kw",
                         [("qwen2-0.5b", {}),
                          ("qwen2-moe-a2.7b", {"exact_moe": True})],
                         ids=["qwen2-0.5b", "qwen2-moe-a2.7b"])
def test_continuous_batching_matches_solo_and_static(arch, kw):
    """Every request served through the slot ring gets EXACTLY the greedy
    tokens it would get served alone (admission/eviction and neighbor churn
    must not leak across slots), the static-batching baseline on the same
    trace agrees, and nothing recompiled across request churn."""
    from repro.launch.serve import (poisson_trace, request_prompt, serve,
                                    serve_continuous, serve_static)
    S, G, n, rate, seed = 8, 6, 6, 0.7, 0
    tkw = dict(reduced=True, slots=3, n_requests=n, prompt_len=S, gen_len=G,
               arrival_rate=rate, seed=seed, verbose=False, **kw)
    rc = serve_continuous(arch, **tkw)
    rs = serve_static(arch, **tkw)
    assert all(v == 1 for v in rc.metrics["jit_cache_sizes"].values()), \
        rc.metrics["jit_cache_sizes"]        # zero recompilation
    cfg = get_config(arch, reduced=True)
    _, gens = poisson_trace(n, rate, seed, G)
    for r in range(n):
        assert np.array_equal(rc.tokens[r], rs.tokens[r]), r
        solo = serve(arch, reduced=True, batch=1, prompt_len=S,
                     gen_len=int(gens[r]), cache_len=S + G,
                     prompt=request_prompt(cfg, seed, r, S), seed=seed,
                     verbose=False, **kw)
        assert np.array_equal(solo.tokens[0], rc.tokens[r]), r
    # admission/eviction actually happened: some request was queued or the
    # ring turned over (n > slots guarantees at least one eviction+reuse)
    assert rc.metrics["makespan_steps"] >= max(int(g) for g in gens)


@pytest.mark.slow
def test_serve_replay_driver_differential():
    """The driver-level differential: serve (cache reuse) and serve_replay
    emit identical greedy tokens, and the phase attribution is honest —
    reuse pays prefill with zero cache setup, replay pays cache setup with
    zero prefill."""
    from repro.launch.serve import serve, serve_replay
    kw = dict(reduced=True, batch=2, prompt_len=8, gen_len=5, seed=0,
              verbose=False)
    reuse = serve("qwen2-0.5b", **kw)
    replay = serve_replay("qwen2-0.5b", **kw)
    assert np.array_equal(reuse.tokens, replay.tokens)
    assert reuse.timings["cache_setup_s"] == 0.0
    assert reuse.timings["prefill_s"] > 0.0
    assert replay.timings["prefill_s"] == 0.0
    assert replay.timings["cache_setup_s"] > 0.0
