"""Verbatim pre-refactor snapshots of core/savic.py and core/fedopt.py.

Frozen at the commit that introduced core/engine.py; the engine regression
tests in test_engine.py pin the refactored round to these trajectories.
Not a test module (underscore prefix) - imported by tests only.
"""
"""FedOpt baseline — Algorithm 2 of Reddi et al. [42] (the paper §5.2 compares
against it): FedAdaGrad / FedAdam / FedYogi.

Clients run K plain local SGD steps from the server point x_t; the server
treats Δ_t = mean_m (x_{m,K} - x_t) as a pseudo-gradient and applies an
adaptive update:

    m_t = β₁ m_{t-1} + (1-β₁) Δ_t
    v_t = v_{t-1} + Δ_t²                     (FedAdaGrad)
    v_t = β₂ v_{t-1} + (1-β₂) Δ_t²           (FedAdam)
    v_t = v_{t-1} - (1-β₂) Δ_t² sign(v_{t-1}-Δ_t²)   (FedYogi)
    x_{t+1} = x_t + η m_t / (√v_t + τ)

This module exists so the paper's §5.2 critique is testable: the benchmark
harness sweeps τ→0 and shows the iterate stalls (x_{t+1} ≈ x_t) when
v_{-1} = τ², as the paper argues.
"""
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FedOptConfig:
    server_opt: str = "adam"       # adagrad | adam | yogi
    eta: float = 0.1               # server lr η
    eta_l: float = 0.05            # client lr η_l
    beta1: float = 0.9
    beta2: float = 0.999
    tau: float = 1e-3              # adaptivity floor τ
    v_init: float = None           # v_{-1}; default τ² (the paper's pain point)
    client_momentum: float = 0.0


def init_state(key, init_params_fn, cfg: FedOptConfig):
    params = init_params_fn(key)
    v0 = cfg.v_init if cfg.v_init is not None else cfg.tau ** 2
    return {
        "params": params,
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(lambda p: jnp.full_like(p, v0), params),
        "round": jnp.int32(0),
    }


def build_round_step(loss_fn: Callable, cfg: FedOptConfig):
    """Returns round_step(state, batch, key); batch leaves (M, K, ...)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def client_run(params0, micro_k):
        """K local SGD steps for one client; micro_k leaves (K, ...)."""

        def step(carry, micro):
            p, mom = carry
            loss, g = grad_fn(p, micro)
            mom = jax.tree.map(lambda m, gi: cfg.client_momentum * m + gi,
                               mom, g)
            p = jax.tree.map(lambda pi, mi: pi - cfg.eta_l * mi, p, mom)
            return (p, mom), loss

        mom0 = jax.tree.map(jnp.zeros_like, params0)
        (p, _), losses = jax.lax.scan(step, (params0, mom0), micro_k)
        delta = jax.tree.map(lambda a, b: a - b, p, params0)
        return delta, losses

    def round_step(state, batch, key):
        del key
        deltas, losses = jax.vmap(lambda mk: client_run(state["params"], mk))(
            batch)                                   # (M, ...) pytree
        delta = jax.tree.map(lambda d: d.mean(axis=0), deltas)

        m = jax.tree.map(lambda m_, d: cfg.beta1 * m_ + (1 - cfg.beta1) * d,
                         state["m"], delta)
        if cfg.server_opt == "adagrad":
            v = jax.tree.map(lambda v_, d: v_ + d * d, state["v"], delta)
        elif cfg.server_opt == "adam":
            v = jax.tree.map(
                lambda v_, d: cfg.beta2 * v_ + (1 - cfg.beta2) * d * d,
                state["v"], delta)
        elif cfg.server_opt == "yogi":
            v = jax.tree.map(
                lambda v_, d: v_ - (1 - cfg.beta2) * d * d
                * jnp.sign(v_ - d * d), state["v"], delta)
        else:
            raise ValueError(cfg.server_opt)
        params = jax.tree.map(
            lambda x, m_, v_: x + cfg.eta * m_ / (jnp.sqrt(v_) + cfg.tau),
            state["params"], m, v)
        new_state = {"params": params, "m": m, "v": v,
                     "round": state["round"] + 1}
        step_norm = jnp.sqrt(sum(jnp.vdot(a - b, a - b).real for a, b in zip(
            jax.tree.leaves(params), jax.tree.leaves(state["params"]))))
        return new_state, {"loss": losses.mean(), "step_norm": step_norm}

    return round_step
