"""models/flash.py (custom-VJP flash attention) vs dense reference —
forward, gradients, windows, softcap, hypothesis shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.flash import flash_attention_bshd
from repro.models.layers import _sdpa_dense


def _rand(key, *shape):
    return jax.random.normal(key, shape)


@settings(max_examples=15, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    nblk=st.sampled_from([2, 4]),
    blk=st.sampled_from([32, 64]),
    H=st.sampled_from([1, 4]),
    D=st.sampled_from([16, 64]),
)
def test_flash_forward_matches_dense(B, nblk, blk, H, D):
    S = nblk * blk
    k0 = jax.random.key(S * H + D)
    q, k, v = (_rand(jax.random.fold_in(k0, i), B, S, H, D) for i in range(3))
    pos = jnp.arange(S, dtype=jnp.int32)
    o1 = flash_attention_bshd(q, k, v, pos, pos, bq=blk, bk=blk)
    o2 = _sdpa_dense(q, k, v, pos, pos, 0, 0.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (48, 0.0), (0, 30.0),
                                            (48, 30.0)])
def test_flash_grads_match_dense(window, softcap):
    B, S, H, D = 2, 128, 2, 32
    k0 = jax.random.key(window + int(softcap))
    q, k, v = (_rand(jax.random.fold_in(k0, i), B, S, H, D) for i in range(3))
    pos = jnp.arange(S, dtype=jnp.int32)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_bshd(
            q, k, v, pos, pos, window=window or None, softcap=softcap,
            bq=32, bk=32)))

    def f_dense(q, k, v):
        return jnp.sum(jnp.sin(_sdpa_dense(q, k, v, pos, pos, window,
                                           softcap)))

    g1 = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_dense, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_flash_traced_per_layer_window():
    """window as a traced scalar inside scan (gemma3 pattern) must work."""
    B, S, H, D = 1, 64, 2, 16
    k0 = jax.random.key(0)
    q, k, v = (_rand(jax.random.fold_in(k0, i), B, S, H, D) for i in range(3))
    pos = jnp.arange(S, dtype=jnp.int32)

    def per_layer(carry, win):
        o = flash_attention_bshd(q, k, v, pos, pos, window=win, bq=32, bk=32)
        return carry + jnp.sum(o), None

    wins = jnp.array([16, 2**30], jnp.int32)
    tot, _ = jax.lax.scan(per_layer, jnp.float32(0.0), wins)
    o16 = _sdpa_dense(q, k, v, pos, pos, 16, 0.0)
    ofull = _sdpa_dense(q, k, v, pos, pos, 0, 0.0)
    np.testing.assert_allclose(float(tot),
                               float(jnp.sum(o16) + jnp.sum(ofull)), rtol=1e-4)


def test_flash_uneven_kv_longer_than_q():
    """decode-style: Sq=block, Sk long (used by long-prefill incremental)."""
    B, H, D = 1, 2, 32
    Sq, Sk = 64, 256
    k0 = jax.random.key(3)
    q = _rand(jax.random.fold_in(k0, 0), B, Sq, H, D)
    k = _rand(jax.random.fold_in(k0, 1), B, Sk, H, D)
    v = _rand(jax.random.fold_in(k0, 2), B, Sk, H, D)
    qpos = jnp.arange(Sk - Sq, Sk, dtype=jnp.int32)
    kpos = jnp.arange(Sk, dtype=jnp.int32)
    o1 = flash_attention_bshd(q, k, v, qpos, kpos, bq=64, bk=64)
    o2 = _sdpa_dense(q, k, v, qpos, kpos, 0, 0.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)
