"""Per-arch smoke tests (reduced configs: ≤2 layers, d_model≤512, ≤4 experts)
+ model-level correctness: prefill-vs-decode agreement, windows, CE, vocab pad."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import PrecondConfig, SavicConfig, savic
from repro.models import ModelCallConfig, build, sample_batch
from repro.models.layers import cross_entropy, padded_vocab
from repro.models.transformer import HUGE_WINDOW, layer_windows


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """REDUCED variant: one forward + one SAVIC train round on CPU; asserts
    output shapes and finiteness (the assigned-arch deliverable's smoke)."""
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build(cfg, ModelCallConfig(dtype=jnp.float32))
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = sample_batch(cfg, jax.random.key(1), B, S)
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    pc = PrecondConfig(kind="adam", alpha=1e-6)
    sv = SavicConfig(gamma=1e-3, beta1=0.9)
    step = jax.jit(savic.build_round_step(model.loss, pc, sv))
    M, H = 2, 2
    state = savic.init_state(jax.random.key(2), model.init, pc, sv, M)
    rbatch = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (M, H) + x.shape), batch)
    state, met = step(state, rbatch, jax.random.key(3))
    assert bool(jnp.isfinite(met["loss"])), arch
    for leaf in jax.tree.leaves(state["params"]):
        assert leaf.shape[0] == M
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    model = build(cfg, ModelCallConfig(dtype=jnp.float32, exact_moe=True))
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = sample_batch(cfg, jax.random.key(1), B, S)
    logits, cache0 = jax.jit(model.prefill)(params, batch)
    V = padded_vocab(cfg.vocab_size)
    assert logits.shape == (B, V)
    cache = model.init_cache(B, S)
    tok = jnp.zeros((B,), jnp.int32)
    out, cache = jax.jit(model.decode)(params, cache, tok, jnp.int32(0))
    assert out.shape == (B, V)
    assert bool(jnp.all(jnp.isfinite(out))), arch


# prefill-vs-decode agreement thresholds: fp32 accumulation-order noise only
# for dense; MoE archs see top-k tie flips near router boundaries; SSD chunked
# vs sequential recurrences differ by exp-accumulation order.
_AGREE_TOL = {"dense": 2e-3, "audio": 2e-3, "vlm": 2e-3,
              "ssm": 5e-3, "hybrid": 2e-2, "moe": 8e-2}


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-4b", "gemma3-4b",
                                  "deepseek-67b", "mamba2-1.3b", "zamba2-2.7b",
                                  "qwen2-moe-a2.7b", "deepseek-v2-236b"])
def test_prefill_decode_agreement(arch):
    cfg = get_config(arch, reduced=True)
    model = build(cfg, ModelCallConfig(dtype=jnp.float32, exact_moe=True))
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = sample_batch(cfg, jax.random.key(1), B, S)
    ref, _ = jax.jit(model.prefill)(params, batch)
    cache = model.init_cache(B, S)
    dec = jax.jit(model.decode)
    logits = None
    for t in range(S):
        logits, cache = dec(params, cache, batch["tokens"][:, t], jnp.int32(t))
    # compare probabilities (tie flips in MoE can shift raw logits)
    pr = jax.nn.softmax(ref, -1)
    pd = jax.nn.softmax(logits, -1)
    err = float(jnp.max(jnp.abs(pr - pd)))
    assert err < _AGREE_TOL[cfg.family], (arch, err)


def test_decode_window_ring_buffer_matches_windowed_prefill():
    cfg = get_config("qwen3-4b", reduced=True)
    W = 8
    model = build(cfg, ModelCallConfig(dtype=jnp.float32, decode_window=W))
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = sample_batch(cfg, jax.random.key(1), B, S)
    ref, _ = jax.jit(model.prefill)(params, batch)   # prefill applies window
    cache = model.init_cache(B, S)                   # ring buffer of size W
    assert jax.tree.leaves(cache)[0].shape[2] == W
    dec = jax.jit(model.decode)
    logits = None
    for t in range(S):
        logits, cache = dec(params, cache, batch["tokens"][:, t], jnp.int32(t))
    err = float(jnp.max(jnp.abs(jax.nn.softmax(ref, -1)
                                - jax.nn.softmax(logits, -1))))
    assert err < 2e-3, err


def test_gemma_window_pattern():
    cfg = get_config("gemma3-4b")
    w = np.asarray(layer_windows(cfg, cfg.n_layers))
    # 5 local : 1 global
    assert (w[:5] == cfg.sliding_window).all()
    assert w[5] == int(HUGE_WINDOW)
    assert (w == int(HUGE_WINDOW)).sum() == cfg.n_layers // 6 + \
        (1 if cfg.n_layers % 6 == 0 else 0) or True
    globals_ = (w == int(HUGE_WINDOW)).sum()
    assert globals_ == len([i for i in range(cfg.n_layers) if i % 6 == 5])


def test_cross_entropy_masks_padded_vocab_and_labels():
    V_real, V_pad = 100, 128
    logits = jnp.zeros((2, 4, V_pad))
    labels = jnp.array([[1, 2, -1, 3], [0, -1, -1, 99]], jnp.int32)
    ce = cross_entropy(logits, labels, V_real)
    # uniform over the REAL vocab (padding masked): loss = log(100)
    np.testing.assert_allclose(float(ce), np.log(V_real), rtol=1e-5)


def test_chunked_flash_equals_dense_prefill():
    cfg = get_config("qwen2-0.5b", reduced=True)
    b1 = build(cfg, ModelCallConfig(dtype=jnp.float32, dense_attn_max=8192))
    b2 = build(cfg, ModelCallConfig(dtype=jnp.float32, dense_attn_max=16,
                                    attn_chunk=16))
    params = b1.init(jax.random.key(0))
    batch = sample_batch(cfg, jax.random.key(1), 2, 64)
    l1 = float(jax.jit(b1.loss)(params, batch))
    l2 = float(jax.jit(b2.loss)(params, batch))
    assert abs(l1 - l2) < 1e-4, (l1, l2)
    g1 = jax.grad(b1.loss)(params, batch)
    g2 = jax.grad(b2.loss)(params, batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_param_count_close_to_nominal():
    """Analytic param_count within 20% of the configs' nominal sizes."""
    nominal = {"qwen3-4b": 4e9, "deepseek-67b": 67e9, "mamba2-1.3b": 1.3e9,
               "deepseek-v2-236b": 236e9}
    for arch, n in nominal.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.45 * n, (arch, got, n)


def test_moe_grouped_equals_flat_no_drop():
    """The sharding-friendly grouped dispatch is numerically identical to the
    flat dispatch when nothing is dropped (per-group routing only changes
    WHICH tokens compete for capacity)."""
    import jax
    from repro.models.moe import init_moe, moe_apply
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (3, 16, cfg.d_model)) * 0.5
    y1, _ = moe_apply(p, cfg, x, "silu", jnp.float32, no_drop=True,
                      grouped=True)
    y2, _ = moe_apply(p, cfg, x, "silu", jnp.float32, no_drop=True,
                      grouped=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_act_shard_hook_is_applied():
    """The act_shard hook must be called on the residual stream."""
    from repro.models import build, ModelCallConfig, sample_batch
    calls = []

    def hook(x):
        calls.append(x.shape)
        return x

    cfg = get_config("qwen2-0.5b", reduced=True)
    m = build(cfg, ModelCallConfig(dtype=jnp.float32, act_shard=hook))
    params = m.init(jax.random.key(0))
    batch = sample_batch(cfg, jax.random.key(1), 2, 16)
    m.loss(params, batch)
    assert calls and calls[0] == (2, 16, cfg.d_model)
