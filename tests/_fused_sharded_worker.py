"""Subprocess worker for the shard-mapped fused-step differential suite
(tests/test_fused_sharded.py; same pattern as tests/_sharding_worker.py —
jax locks the device count at first init, so the main pytest process keeps 1
device and this worker gets 8).

Modes (argv[1]):
  fast   representative slice: {savic, fedadam, local-adam} on the mixed
         client×model plan + the clip/wd/H_m composition + the shard_map
         flatten/unflatten-vs-reference pin.
  full   all six METHODS × {model, fsdp, mixed} plans (tier-2 @slow).
  hlo    collective-byte pins: the isolated per-step flat program carries
         ZERO collective bytes, the fused round program's collective bytes
         equal the tree path's, and the naive global flat view measurably
         blows up.  Prints one "RESULT {json}" line.

Every differential case asserts BITWISE (fp32) equality of the full state
trajectory: shard-mapped fused vs the live tree path vs the verbatim pre-PR
engine snapshot (tests/_reference_engine.py), all three jitted with the SAME
state/batch shardings on the same (2, 4) = ('data', 'model') mesh.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import _reference_engine as ref_engine
from repro.core import engine, savic
from repro.core.preconditioner import PrecondConfig
from repro.utils.flatten import FlatLayout, ShardedFlatPlan

M, H, B_MICRO = 4, 3, 2
MS_KW = dict(gamma=0.01, alpha=1e-2, eta_l=0.01, eta=0.05)

# toy MLP whose leaves exercise every layout case: dim-0 and dim-1 splits,
# divisible 1-D leaves, and an uneven leaf (5 % {4, 8} != 0 -> replicated
# fallback in every shard block)
LEAVES = ("w1", "b1", "w2", "b2", "u")


def init(key):
    ks = jax.random.split(key, 3)
    return {"w1": jax.random.normal(ks[0], (6, 16)) * 0.3,
            "b1": jnp.zeros((16,)),
            "w2": jax.random.normal(ks[1], (16, 8)) * 0.3,
            "b2": jnp.zeros((8,)),
            "u": jax.random.normal(ks[2], (5,))}


def loss(params, micro):
    h = jnp.tanh(micro["x"] @ params["w1"] + params["b1"])
    y = h @ params["w2"] + params["b2"]
    return jnp.mean((y - micro["y"]) ** 2) + 1e-3 * micro["z"] @ params["u"]


# plan name -> (client axes entry | None, shard axes, single-replica pspecs)
# NB: benchmarks/sharded_collectives.py carries the same plan table and step
# builders on bigger leaves (this copy asserts, that one measures); keep the
# two in sync when the fused_step signature or plan shapes change.
PLANS = {
    # pure tensor parallel: clients replicated over 'data'
    "model": (None, ("model",),
              {"w1": P(None, "model"), "b1": P("model"),
               "w2": P("model", None), "b2": P("model"), "u": P()}),
    # FSDP over both axes jointly (8 shards), clients replicated
    "fsdp": (None, ("data", "model"),
             {"w1": P(None, ("data", "model")), "b1": P(("data", "model")),
              "w2": P(("data", "model"), None), "b2": P(("data", "model")),
              "u": P()}),
    # mixed client×model: M over 'data', shards over 'model'
    "mixed": (("data",), ("model",),
              {"w1": P(None, "model"), "b1": P("model"),
               "w2": P("model", None), "b2": P("model"), "u": P()}),
}


def batch_for(key, b=B_MICRO):
    ks = jax.random.split(key, 3)
    return {"x": jax.random.normal(ks[0], (M, H, b, 6)),
            "y": jax.random.normal(ks[1], (M, H, b, 8)),
            "z": jax.random.normal(ks[2], (M, H, 5)) * 0.1}


def state_specs(state, pspecs, client):
    """Engine state pspec tree per DESIGN.md §2 for the toy tree."""
    cl = client
    pspec_m = {k: P(cl, *tuple(pspecs[k])) for k in LEAVES}
    spec = {"params": pspec_m, "mom": dict(pspec_m), "round": P()}
    pc = {"t": P(cl) if state["precond"]["t"].ndim else P()}
    if "d" in state["precond"]:
        local = jax.tree.leaves(state["precond"]["d"])[0].ndim \
            > jax.tree.leaves(state["params"])[0].ndim - 1
        pc["d"] = dict(pspec_m) if local else {k: pspecs[k] for k in LEAVES}
    spec["precond"] = pc
    if "server" in state:
        one = {k: pspecs[k] for k in LEAVES}
        spec["server"] = {"m": one, "v": dict(one)}
    return spec


def run_case(mesh, plan_name, spec, eng, shard_plan=None, rounds=3):
    client, _, pspecs = PLANS[plan_name]
    if shard_plan is not None:
        step = eng.build_round_step(loss, spec, shard_plan)
    else:
        step = eng.build_round_step(loss, spec)
    state = eng.init_state(jax.random.PRNGKey(0), init, spec, M)
    sspec = state_specs(state, pspecs, client)
    bspec = {"x": P(client, None, None, None), "y": P(client, None, None, None),
             "z": P(client, None, None)}
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    with mesh:
        jstep = jax.jit(step, in_shardings=(ns(sspec), ns(bspec), None),
                        out_shardings=(ns(sspec), None))
        key = jax.random.PRNGKey(1)
        for _ in range(rounds):
            key, k1, k2 = jax.random.split(key, 3)
            state, met = jstep(state, batch_for(k1), k2)
    return state, met


def assert_state_bitwise(st_a, st_b, tag):
    for k in LEAVES:
        np.testing.assert_array_equal(np.asarray(st_a["params"][k]),
                                      np.asarray(st_b["params"][k]),
                                      err_msg=f"{tag} params/{k}")
        np.testing.assert_array_equal(np.asarray(st_a["mom"][k]),
                                      np.asarray(st_b["mom"][k]),
                                      err_msg=f"{tag} mom/{k}")
        if "d" in st_b["precond"]:
            np.testing.assert_array_equal(
                np.asarray(st_a["precond"]["d"][k]),
                np.asarray(st_b["precond"]["d"][k]),
                err_msg=f"{tag} d/{k}")
    np.testing.assert_array_equal(np.asarray(st_a["precond"]["t"]),
                                  np.asarray(st_b["precond"]["t"]), err_msg=tag)
    if "server" in st_b:
        for k in LEAVES:
            np.testing.assert_array_equal(np.asarray(st_a["server"]["m"][k]),
                                          np.asarray(st_b["server"]["m"][k]),
                                          err_msg=f"{tag} server.m/{k}")
            np.testing.assert_array_equal(np.asarray(st_a["server"]["v"][k]),
                                          np.asarray(st_b["server"]["v"][k]),
                                          err_msg=f"{tag} server.v/{k}")


def build_plan(mesh, plan_name):
    client, axes, pspecs = PLANS[plan_name]
    params_one = jax.eval_shape(init, jax.random.PRNGKey(0))
    return ShardedFlatPlan.build(mesh, params_one, pspecs, axes, client=client)


def diff_one(mesh, plan_name, method):
    plan = build_plan(mesh, plan_name)
    spec_f = engine.method_spec(method, **MS_KW, use_fused_kernel=True)
    spec_u = engine.method_spec(method, **MS_KW)
    spec_r = ref_engine.method_spec(method, **MS_KW)
    st_f, met_f = run_case(mesh, plan_name, spec_f, engine, shard_plan=plan)
    st_u, met_u = run_case(mesh, plan_name, spec_u, engine)
    st_r, met_r = run_case(mesh, plan_name, spec_r, ref_engine)
    tag = f"{plan_name}/{method}"
    assert_state_bitwise(st_f, st_u, tag + " fused-vs-tree")
    assert_state_bitwise(st_f, st_r, tag + " fused-vs-ref")
    assert float(met_f["loss"]) == float(met_u["loss"]) == float(met_r["loss"])
    print(f"OK diff {tag}", flush=True)


def diff_composition(mesh, plan_name):
    """Heterogeneous H_m composes with the shard-mapped path BITWISE: the
    mask is a pure ``where``-select on the flat buffers (no new multiply-add,
    nothing reduces across shards), and frozen clients freeze their per-shard
    flat state at exactly step H_m."""
    plan = build_plan(mesh, plan_name)
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling="local", use_fused_kernel=fused,
        local_steps=(2, 1, 3, 3)))
    st_f, _ = run_case(mesh, plan_name, mk(True), engine, shard_plan=plan)
    st_u, _ = run_case(mesh, plan_name, mk(False), engine)
    assert_state_bitwise(st_f, st_u, f"{plan_name}/hm")
    np.testing.assert_array_equal(np.asarray(st_f["precond"]["t"]),
                                  3 * np.asarray([2, 1, 3, 3]))
    print(f"OK diff {plan_name}/hm", flush=True)


def diff_clip_wd_composition(mesh, plan_name):
    """grad-clip + weight-decay composition: 1-ulp tolerance, NOT bitwise.

    Both knobs introduce ops whose lowering XLA:CPU may contract differently
    into the two differently-shaped programs: the clip's global grad-norm is
    the one cross-shard REDUCTION in the local step (per-device partial-sum
    order unpinned), and ``g + wd·p`` is a fresh multiply-add that may or may
    not become an FMA inside the shard_map body.  Same effect class as the
    jit-vs-jit FMA note in tests/test_fused_step.py — the elementwise
    flat-path contract itself stays bitwise (every other case in this
    worker, all six METHODS included)."""
    plan = build_plan(mesh, plan_name)
    pc = PrecondConfig(kind="adam", alpha=1e-2)
    mk = lambda fused: savic.engine_spec(pc, savic.SavicConfig(
        gamma=0.01, beta1=0.9, scaling="local", use_fused_kernel=fused,
        grad_clip=0.3, weight_decay=0.05, local_steps=(2, 1, 3, 3)))
    st_f, _ = run_case(mesh, plan_name, mk(True), engine, shard_plan=plan)
    st_u, _ = run_case(mesh, plan_name, mk(False), engine)
    for k in LEAVES:
        np.testing.assert_allclose(np.asarray(st_f["params"][k]),
                                   np.asarray(st_u["params"][k]),
                                   rtol=2e-5, atol=1e-7,
                                   err_msg=f"{plan_name}/clip-wd params/{k}")
        np.testing.assert_allclose(np.asarray(st_f["precond"]["d"][k]),
                                   np.asarray(st_u["precond"]["d"][k]),
                                   rtol=2e-5, atol=1e-7,
                                   err_msg=f"{plan_name}/clip-wd d/{k}")
    print(f"OK diff {plan_name}/clip-wd-hm (1-ulp)", flush=True)


def flatten_oracle(mesh):
    """shard_map flatten/unflatten == the mesh-free reference, bitwise, on
    every plan — incl. the uneven/replicated leaf."""
    tree = {k: jax.random.normal(jax.random.fold_in(jax.random.key(3), i),
                                 (M,) + s)
            for i, (k, s) in enumerate(
                {"w1": (6, 16), "b1": (16,), "w2": (16, 8), "b2": (8,),
                 "u": (5,)}.items())}
    for plan_name, (client, axes, pspecs) in PLANS.items():
        lay = build_plan(mesh, plan_name).layout
        lead = (client,)
        tree_s = jax.device_put(tree, jax.tree.map(
            lambda s: NamedSharding(mesh, P(client, *tuple(s))), pspecs,
            is_leaf=lambda x: isinstance(x, P)))
        with mesh:
            buf = jax.jit(lambda t: lay.flatten(t, mesh, lead=lead))(tree_s)
            back = jax.jit(lambda b: lay.unflatten(b, mesh, lead=lead))(buf)
        ref_buf = lay.flatten_ref(tree, batch_dims=1)
        assert buf.shape == (M, lay.n_flat)
        np.testing.assert_array_equal(np.asarray(buf), np.asarray(ref_buf),
                                      err_msg=f"{plan_name} flatten")
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]),
                                          err_msg=f"{plan_name} unflatten/{k}")
        print(f"OK flatten-oracle {plan_name}", flush=True)


def hlo_pins(mesh):
    """Collective-byte pins for the sharded fast path (DESIGN.md §7):

      * the isolated per-step flat program (flatten -> fused kernel ->
        unflatten) carries ZERO collective bytes;
      * the full fused round program's trip-corrected collective bytes EQUAL
        the tree path's (sync traffic only — nothing touches the flat
        buffers);
      * the naive global flat view (pre-PR reason for the gate) measurably
        reshards: its one-step program carries collective bytes.
    """
    from repro.kernels import ref as kref
    from repro.utils.hlo import collective_bytes
    from repro.utils.hlo_cost import analyze as hlo_analyze

    plan_name = "mixed"
    client, axes, pspecs = PLANS[plan_name]
    plan = build_plan(mesh, plan_name)
    lay = plan.layout
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    params = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (M,) + l.shape),
        init(jax.random.PRNGKey(0)))
    leaf_specs = {k: P(client, *tuple(pspecs[k])) for k in LEAVES}
    params = jax.device_put(params, ns(leaf_specs))
    kw = dict(gamma=0.01, beta1=0.9, weight_decay=0.0, alpha=1e-2,
              beta2=0.999, kind="adam", clip="max", schedule="const",
              update_d=True)
    rec = {}

    # -- isolated per-step flat program: must carry ZERO collectives ---------
    def flat_step(tree):
        p = lay.flatten(tree, mesh, lead=(client,))
        from repro.core.engine import _shard_flat_ops
        _, _, _, _, fused_step = _shard_flat_ops(plan, local=True)
        po, mo, do = fused_step(p, p * 0.9, p * 0.1, p * 0.5 + 1.0, None,
                                jnp.zeros((M,), jnp.int32), None, **kw)
        return lay.unflatten(po, mesh, lead=(client,))

    with mesh:
        c = jax.jit(flat_step, in_shardings=(ns(leaf_specs),),
                    out_shardings=ns(leaf_specs)).lower(params).compile()
    total, by_kind, _ = collective_bytes(c.as_text())
    rec["step_collective_bytes_sharded"] = int(total)
    rec["step_collective_by_kind_sharded"] = {k: int(v)
                                              for k, v in by_kind.items()}

    # -- naive global flat view: the resharding blowup the gate guarded -----
    glay = FlatLayout.for_tree(params, batch_dims=1)

    def naive_step(tree):
        p = glay.flatten(tree, batch_dims=1)
        po, mo, _ = kref.fused_step_ref(p, p * 0.9, p * 0.1, p * 0.5 + 1.0,
                                        None, None, None, **dict(kw,
                                        update_d=False, schedule="const"))
        return glay.unflatten(po, batch_dims=1)

    with mesh:
        c = jax.jit(naive_step, in_shardings=(ns(leaf_specs),),
                    out_shardings=ns(leaf_specs)).lower(params).compile()
    total_naive, _, _ = collective_bytes(c.as_text())
    rec["step_collective_bytes_naive"] = int(total_naive)

    # -- full round program: fused collective bytes == tree path's ----------
    def coll_of(spec, shard_plan=None):
        step = engine.build_round_step(loss, spec, shard_plan)
        state = engine.init_state(jax.random.PRNGKey(0), init, spec, M)
        sspec = state_specs(state, pspecs, client)
        bspec = {"x": P(client, None, None, None),
                 "y": P(client, None, None, None), "z": P(client, None, None)}
        with mesh:
            c = jax.jit(step, in_shardings=(ns(sspec), ns(bspec), None),
                        out_shardings=(ns(sspec), None)).lower(
                state, batch_for(jax.random.PRNGKey(1)),
                jax.random.PRNGKey(2)).compile()
        return hlo_analyze(c.as_text())["collective_bytes"]

    spec_f = engine.method_spec("local-adam", **MS_KW, use_fused_kernel=True)
    spec_u = engine.method_spec("local-adam", **MS_KW)
    rec["round_collective_bytes_fused"] = coll_of(spec_f, plan)
    rec["round_collective_bytes_tree"] = coll_of(spec_u)
    print("RESULT " + json.dumps(rec), flush=True)


def main(mode: str):
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         devices=jax.devices()[:8])
    if mode == "fast":
        flatten_oracle(mesh)
        for method in ("savic", "fedadam", "local-adam"):
            diff_one(mesh, "mixed", method)
        diff_composition(mesh, "mixed")
    elif mode == "full":
        for plan_name in PLANS:
            for method in engine.METHODS:
                diff_one(mesh, plan_name, method)
            diff_composition(mesh, plan_name)
            diff_clip_wd_composition(mesh, plan_name)
    elif mode == "hlo":
        hlo_pins(mesh)
    else:
        raise SystemExit(f"unknown mode {mode!r}")
    print(f"ALL-OK {mode}")


if __name__ == "__main__":
    main(sys.argv[1])
