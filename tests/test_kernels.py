"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [17, 4096, 8 * 128 * 16, 8 * 128 * 16 + 3,
                               300_001])
@pytest.mark.parametrize("squared", [True, False])
def test_scaled_update_shapes(n, squared):
    k = jax.random.key(n)
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (n,))
               for i in range(3))
    d = jax.random.uniform(jax.random.fold_in(k, 3), (n,), minval=0.0,
                           maxval=4.0)
    kw = dict(gamma=0.1, beta1=0.9, alpha=1e-3, squared=squared)
    po, mo = ops.scaled_update(p, m, g, d, **kw)
    pr, mr = ref.scaled_update_ref(p, m, g, d, **kw)
    # near the α-clip 1/D̂ amplifies magnitudes — relative tolerance
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scaled_update_dtypes(dtype):
    n = 5000
    k = jax.random.key(0)
    p, m, g = (jax.random.normal(jax.random.fold_in(k, i), (n,), dtype)
               for i in range(3))
    d = jax.random.uniform(jax.random.fold_in(k, 3), (n,), minval=0.1,
                           maxval=2.0).astype(dtype)
    po, _ = ops.scaled_update(p, m, g, d, gamma=0.1, beta1=0.9, alpha=1e-3)
    pr, _ = ref.scaled_update_ref(p.astype(jnp.float32),
                                  m.astype(jnp.float32),
                                  g.astype(jnp.float32),
                                  d.astype(jnp.float32),
                                  gamma=0.1, beta1=0.9, alpha=1e-3)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(po, np.float32), np.asarray(pr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,Hk,D,bq,bk", [
    (1, 128, 2, 2, 64, 64, 64),
    (2, 256, 4, 2, 64, 128, 64),
    (2, 256, 8, 1, 32, 64, 128),    # MQA
    (1, 512, 2, 2, 128, 128, 128),
])
def test_flash_kernel_sweep(B, S, H, Hk, D, bq, bk):
    k0 = jax.random.key(S + H)
    q = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hk, D))
    v = jax.random.normal(jax.random.fold_in(k0, 3), (B, S, Hk, D))
    o = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    orf = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("window", [16, 100])
def test_flash_kernel_window(window):
    B, S, H, D = 2, 256, 2, 32
    k0 = jax.random.key(window)
    q, k, v = (jax.random.normal(jax.random.fold_in(k0, i), (B, S, H, D))
               for i in range(3))
    o = ops.flash_attention(q, k, v, window=window, bq=64, bk=64)
    orf = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5,
                               atol=2e-5)


def test_flash_kernel_bf16():
    B, S, H, D = 1, 256, 2, 64
    k0 = jax.random.key(9)
    q, k, v = (jax.random.normal(jax.random.fold_in(k0, i), (B, S, H, D),
                                 jnp.bfloat16) for i in range(3))
    o = ops.flash_attention(q, k, v, bq=128, bk=128)
    orf = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), rtol=0.05,
                               atol=0.05)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    k = jax.random.key(S)
    xh = jax.random.normal(jax.random.fold_in(k, 0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, H, N))
    Cm = jax.random.normal(jax.random.fold_in(k, 4), (B, S, H, N))
    y, h = ops.ssd(xh, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ref.ssd_ref(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)
