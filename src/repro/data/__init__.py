from repro.data.federated import (dirichlet_partition, heterogeneity_score,  # noqa
                                  iid_partition, labeled_mask,
                                  main_class_partition,
                                  realized_main_fraction)
from repro.data.loader import FederatedLoader, LMRoundLoader, QuadraticLoader  # noqa
from repro.data.synthetic import ClassificationData, QuadraticProblem, TokenStream  # noqa
