"""Heterogeneous client partitioning — the paper's §6.1 protocol.

"To realize the heterogeneity of the data for each of the clients we select a
'main' class ... choose 30%, 50%, or 70% of the 'main' class for the
corresponding client and add the rest data evenly from the remaining samples."

Implements that exactly (main-class fraction partitioner) plus the standard
Dirichlet(α) partitioner as an extra heterogeneity model, and an iid
partitioner for the identical-data regime of Theorem 1.
"""
from __future__ import annotations

import numpy as np


def main_class_partition(labels: np.ndarray, n_clients: int, main_frac: float,
                         seed: int = 0):
    """Paper protocol. Client m's "main" class = m % n_classes; main_frac of
    its samples come from that class, the rest drawn evenly from the others.

    Returns list of index arrays (one per client, equal sizes).
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_classes = len(classes)
    per_client = len(labels) // n_clients
    n_main = int(round(per_client * main_frac))
    n_rest = per_client - n_main

    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    for m in range(n_clients):
        main_c = classes[m % n_classes]
        take = []
        pool = by_class[main_c]
        k = min(n_main, len(pool))
        take += pool[:k]
        by_class[main_c] = pool[k:]
        # fill the remainder evenly from other classes
        others = [c for c in classes if c != main_c]
        need = per_client - len(take)
        for i, c in enumerate(others):
            share = need // len(others) + (1 if i < need % len(others) else 0)
            pool = by_class[c]
            k = min(share, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        # top up from whatever is left if classes ran dry
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            take += extra
            used = set(extra)
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0):
    """Classic label-Dirichlet federated split (equal client sizes)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    per_client = len(labels) // n_clients
    props = rng.dirichlet([alpha] * len(classes), size=n_clients)
    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    for m in range(n_clients):
        take = []
        quota = (props[m] * per_client).astype(int)
        for c, q in zip(classes, quota):
            pool = by_class[c]
            k = min(q, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            used = set(extra)
            take += extra
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    return out


def iid_partition(n: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    per = n // n_clients
    return [idx[m * per:(m + 1) * per] for m in range(n_clients)]


def heterogeneity_score(labels: np.ndarray, parts) -> float:
    """Mean total-variation distance between client label dists and global."""
    classes = np.unique(labels)
    glob = np.array([(labels == c).mean() for c in classes])
    tv = []
    for idx in parts:
        loc = np.array([(labels[idx] == c).mean() for c in classes])
        tv.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tv))
