"""Heterogeneous client partitioning — the paper's §6.1 protocol — plus
systems-heterogeneity models for the round engine (DESIGN.md §5).

Statistical heterogeneity ("To realize the heterogeneity of the data for each
of the clients we select a 'main' class ... choose 30%, 50%, or 70% of the
'main' class for the corresponding client and add the rest data evenly from
the remaining samples."): implemented exactly (main-class fraction
partitioner) plus the standard Dirichlet(α) partitioner as an extra
heterogeneity model, and an iid partitioner for the identical-data regime of
Theorem 1.

Systems heterogeneity (cf. the local-update regimes of arXiv:2409.13155 and
the adaptive-workload line of arXiv:2406.13936, Lau et al.): per-client relative
step times drawn from one of three models —

  uniform     every client identical (step time 1.0; H_m = H)
  lognormal   step time ~ LogNormal(0, sigma), normalized so the FASTEST
              client is 1.0 — the classic long-tailed straggler draw
  tiers       device classes (e.g. 1×/2×/4× step time) with given occupation
              probabilities — fleet-of-device-generations heterogeneity

plus the derived per-client local-step vector H_m (fixed wall-clock budget:
the slow clients do fewer local steps) and the simulated round-time model
used by `benchmarks/run.py --only async` (sync barrier = slowest client;
a B-round staleness budget divides the effective barrier by B).
"""
from __future__ import annotations

import numpy as np

SYSTEMS_MODELS = ("uniform", "lognormal", "tiers")


def main_class_partition(labels: np.ndarray, n_clients: int, main_frac: float,
                         seed: int = 0):
    """Paper protocol. Client m's "main" class = m % n_classes; main_frac of
    its samples come from that class, the rest drawn evenly from the others.

    Returns list of index arrays (one per client, equal sizes).
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_classes = len(classes)
    per_client = len(labels) // n_clients
    n_main = int(round(per_client * main_frac))
    n_rest = per_client - n_main

    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    for m in range(n_clients):
        main_c = classes[m % n_classes]
        take = []
        pool = by_class[main_c]
        k = min(n_main, len(pool))
        take += pool[:k]
        by_class[main_c] = pool[k:]
        # fill the remainder evenly from other classes
        others = [c for c in classes if c != main_c]
        need = per_client - len(take)
        for i, c in enumerate(others):
            share = need // len(others) + (1 if i < need % len(others) else 0)
            pool = by_class[c]
            k = min(share, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        # top up from whatever is left if classes ran dry
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            take += extra
            used = set(extra)
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0):
    """Classic label-Dirichlet federated split (equal client sizes)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    per_client = len(labels) // n_clients
    props = rng.dirichlet([alpha] * len(classes), size=n_clients)
    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    for m in range(n_clients):
        take = []
        quota = (props[m] * per_client).astype(int)
        for c, q in zip(classes, quota):
            pool = by_class[c]
            k = min(q, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            used = set(extra)
            take += extra
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    return out


def iid_partition(n: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    per = n // n_clients
    return [idx[m * per:(m + 1) * per] for m in range(n_clients)]


# --------------------------------------------------------------------------- #
# systems heterogeneity: step times, per-client H_m, simulated wall clock
# --------------------------------------------------------------------------- #


def sample_step_times(model: str, n_clients: int, seed: int = 0, *,
                      sigma: float = 0.6,
                      tiers=(1.0, 2.0, 4.0), tier_probs=None) -> np.ndarray:
    """Per-client RELATIVE step times (fastest client = 1.0) under a
    systems-heterogeneity model from SYSTEMS_MODELS."""
    rng = np.random.default_rng(seed)
    if model == "uniform":
        return np.ones(n_clients)
    if model == "lognormal":
        t = rng.lognormal(mean=0.0, sigma=sigma, size=n_clients)
        return t / t.min()
    if model == "tiers":
        tiers = np.asarray(tiers, dtype=np.float64)
        if tier_probs is None:
            tier_probs = np.full(len(tiers), 1.0 / len(tiers))
        t = rng.choice(tiers, size=n_clients, p=np.asarray(tier_probs))
        return t / t.min()
    raise ValueError(f"systems model {model!r}; expected one of "
                     f"{SYSTEMS_MODELS}")


def local_steps_from_times(step_times: np.ndarray, h_max: int, *,
                           time_budget: float = None) -> np.ndarray:
    """Per-client local-step vector H_m under a fixed wall-clock budget.

    The budget defaults to ``h_max`` × the fastest client's step time: the
    fastest client runs all H local steps, a client 2× slower runs ~H/2,
    everyone runs at least 1. This is the workload-adaptation regime of
    Lau et al. (2024): slow clients send fewer local steps rather than
    stretching the barrier.
    """
    step_times = np.asarray(step_times, dtype=np.float64)
    if time_budget is None:
        time_budget = h_max * float(step_times.min())
    h = np.floor(time_budget / step_times + 1e-9).astype(np.int64)
    return np.clip(h, 1, h_max)


def sample_local_steps(model: str, n_clients: int, h_max: int, seed: int = 0,
                       **kw) -> np.ndarray:
    """H_m sampled from a systems model: step times -> budgeted local steps."""
    return local_steps_from_times(
        sample_step_times(model, n_clients, seed=seed, **kw), h_max)


def simulated_round_time(step_times: np.ndarray, local_steps, *,
                         barrier: str = "sync",
                         buffer_rounds: int = 0) -> float:
    """Simulated wall-clock seconds per round (relative units).

    sync   the server waits for every client: max_m(t_m · H_m).
    async  a client whose delta may land up to B rounds late can spread its
           work over B server periods, so the server pace only needs
           max_m(t_m · H_m) / B — the staleness budget buys wall-clock.
    """
    step_times = np.asarray(step_times, dtype=np.float64)
    h_m = np.asarray(local_steps, dtype=np.float64)
    slowest = float((step_times * h_m).max())
    if barrier == "sync":
        return slowest
    if barrier == "async":
        return slowest / max(int(buffer_rounds), 1)
    raise ValueError(f"barrier {barrier!r}; expected 'sync' or 'async'")


def heterogeneity_score(labels: np.ndarray, parts) -> float:
    """Mean total-variation distance between client label dists and global."""
    classes = np.unique(labels)
    glob = np.array([(labels == c).mean() for c in classes])
    tv = []
    for idx in parts:
        loc = np.array([(labels[idx] == c).mean() for c in classes])
        tv.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tv))
