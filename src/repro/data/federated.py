"""Heterogeneous client partitioning — the paper's §6.1 protocol — plus
systems-heterogeneity models for the round engine (DESIGN.md §5).

Statistical heterogeneity ("To realize the heterogeneity of the data for each
of the clients we select a 'main' class ... choose 30%, 50%, or 70% of the
'main' class for the corresponding client and add the rest data evenly from
the remaining samples."): implemented exactly (main-class fraction
partitioner) plus the standard Dirichlet(α) partitioner as an extra
heterogeneity model, and an iid partitioner for the identical-data regime of
Theorem 1.

Systems heterogeneity (cf. the local-update regimes of arXiv:2409.13155 and
the adaptive-workload line of arXiv:2406.13936, Lau et al.): per-client relative
step times drawn from one of three models —

  uniform     every client identical (step time 1.0; H_m = H)
  lognormal   step time ~ LogNormal(0, sigma), normalized so the FASTEST
              client is 1.0 — the classic long-tailed straggler draw
  tiers       device classes (e.g. 1×/2×/4× step time) with given occupation
              probabilities — fleet-of-device-generations heterogeneity

plus the derived per-client local-step vector H_m (fixed wall-clock budget:
the slow clients do fewer local steps) and the simulated round-time model
used by `benchmarks/run.py --only async` (sync barrier = slowest client;
a B-round staleness budget divides the effective barrier by B).
"""
from __future__ import annotations

import warnings

import numpy as np

SYSTEMS_MODELS = ("uniform", "lognormal", "tiers")


def main_class_partition(labels: np.ndarray, n_clients: int, main_frac: float,
                         seed: int = 0):
    """Paper protocol. Client m's "main" class = m % n_classes; main_frac of
    its samples come from that class, the rest drawn evenly from the others.

    Returns list of index arrays (one per client, equal sizes).

    The realized main fraction can fall below ``main_frac`` when a main-class
    pool runs dry (more clients per class than ``1 / main_frac`` can support,
    i.e. roughly ``n_clients * main_frac > n_classes``): later clients of the
    same class get topped up from other classes. That shortfall is detected
    and reported with a ``UserWarning``; check ``realized_main_fraction`` when
    the exact heterogeneity level matters.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_classes = len(classes)
    per_client = len(labels) // n_clients
    n_main = int(round(per_client * main_frac))

    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    dry = []
    for m in range(n_clients):
        main_c = classes[m % n_classes]
        take = []
        pool = by_class[main_c]
        k = min(n_main, len(pool))
        if k < n_main:
            dry.append((m, int(main_c), n_main - k))
        take += pool[:k]
        by_class[main_c] = pool[k:]
        # fill the remainder evenly from other classes
        others = [c for c in classes if c != main_c]
        need = per_client - len(take)
        for i, c in enumerate(others):
            share = need // len(others) + (1 if i < need % len(others) else 0)
            pool = by_class[c]
            k = min(share, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        # top up from whatever is left if classes ran dry
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            take += extra
            used = set(extra)
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    if dry:
        worst = min(1.0 - s / n_main for _, _, s in dry) if n_main else 1.0
        warnings.warn(
            f"main_class_partition: main-class pool ran dry for "
            f"{len(dry)}/{n_clients} clients (first: client {dry[0][0]}, "
            f"class {dry[0][1]}, short {dry[0][2]} samples); realized main "
            f"fraction drops to {worst * main_frac:.3f} < {main_frac} for "
            f"the worst client. See realized_main_fraction().",
            UserWarning, stacklevel=2)
    return out


def realized_main_fraction(labels: np.ndarray, parts) -> np.ndarray:
    """Per-client fraction of samples actually in the client's main class
    (main class of client m = classes[m % n_classes], as assigned by
    ``main_class_partition``)."""
    classes = np.unique(labels)
    fr = []
    for m, idx in enumerate(parts):
        main_c = classes[m % len(classes)]
        fr.append((labels[idx] == main_c).mean() if len(idx) else 0.0)
    return np.asarray(fr, dtype=np.float64)


def _largest_remainder(raw: np.ndarray, total: int) -> np.ndarray:
    """Integer quotas summing to ``total`` that minimize |quota - raw|:
    floor everything, then hand the shortfall to the largest fractional
    remainders (deterministic stable order)."""
    quota = np.floor(raw).astype(np.int64)
    short = int(total - quota.sum())
    if short > 0:
        order = np.argsort(-(raw - quota), kind="stable")
        quota[order[:short]] += 1
    return quota


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0):
    """Classic label-Dirichlet federated split (equal client sizes).

    Per-client class quotas use largest-remainder rounding of the Dirichlet
    proportions (NOT truncation): truncating and backfilling from a uniform
    leftover shuffle systematically dilutes the drawn Dirichlet(α)
    heterogeneity — every truncated sample is replaced by a ~uniform one.
    The uniform backfill now only covers genuinely dry class pools.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    per_client = len(labels) // n_clients
    props = rng.dirichlet([alpha] * len(classes), size=n_clients)
    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    out = []
    for m in range(n_clients):
        take = []
        quota = _largest_remainder(props[m] * per_client, per_client)
        for c, q in zip(classes, quota):
            pool = by_class[c]
            k = min(q, len(pool))
            take += pool[:k]
            by_class[c] = pool[k:]
        if len(take) < per_client:
            leftovers = [i for c in classes for i in by_class[c]]
            rng.shuffle(leftovers)
            extra = leftovers[: per_client - len(take)]
            used = set(extra)
            take += extra
            for c in classes:
                by_class[c] = [i for i in by_class[c] if i not in used]
        out.append(np.array(take[:per_client]))
    return out


def iid_partition(n: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    per = n // n_clients
    return [idx[m * per:(m + 1) * per] for m in range(n_clients)]


# --------------------------------------------------------------------------- #
# systems heterogeneity: step times, per-client H_m, simulated wall clock
# --------------------------------------------------------------------------- #


def sample_step_times(model: str, n_clients: int, seed: int = 0, *,
                      sigma: float = 0.6,
                      tiers=(1.0, 2.0, 4.0), tier_probs=None) -> np.ndarray:
    """Per-client RELATIVE step times under a systems-heterogeneity model
    from SYSTEMS_MODELS. uniform/lognormal normalize so the fastest DRAWN
    client is 1.0 (the model defines times only up to scale); tiers
    normalizes by the declared fastest tier, so tier identities are stable
    across seeds and n_clients."""
    rng = np.random.default_rng(seed)
    if model == "uniform":
        return np.ones(n_clients)
    if model == "lognormal":
        t = rng.lognormal(mean=0.0, sigma=sigma, size=n_clients)
        return t / t.min()
    if model == "tiers":
        tiers = np.asarray(tiers, dtype=np.float64)
        if tier_probs is None:
            tier_probs = np.full(len(tiers), 1.0 / len(tiers))
        t = rng.choice(tiers, size=n_clients, p=np.asarray(tier_probs))
        # Normalize by the DECLARED fastest tier, not the drawn minimum: a
        # (1x, 2x, 4x) fleet must stay (2x, 4x) when no client draws tier 1
        # in this sample — dividing by t.min() would silently relabel the 2x
        # tier as the 1x baseline, changing tier semantics across seeds.
        return t / tiers.min()
    raise ValueError(f"systems model {model!r}; expected one of "
                     f"{SYSTEMS_MODELS}")


def local_steps_from_times(step_times: np.ndarray, h_max: int, *,
                           time_budget: float = None) -> np.ndarray:
    """Per-client local-step vector H_m under a fixed wall-clock budget.

    The budget defaults to ``h_max`` × the fastest client's step time: the
    fastest client runs all H local steps, a client 2× slower runs ~H/2,
    everyone runs at least 1. This is the workload-adaptation regime of
    Lau et al. (2024): slow clients send fewer local steps rather than
    stretching the barrier.
    """
    step_times = np.asarray(step_times, dtype=np.float64)
    if time_budget is None:
        time_budget = h_max * float(step_times.min())
    h = np.floor(time_budget / step_times + 1e-9).astype(np.int64)
    return np.clip(h, 1, h_max)


def sample_local_steps(model: str, n_clients: int, h_max: int, seed: int = 0,
                       **kw) -> np.ndarray:
    """H_m sampled from a systems model: step times -> budgeted local steps."""
    return local_steps_from_times(
        sample_step_times(model, n_clients, seed=seed, **kw), h_max)


def simulated_round_time(step_times: np.ndarray, local_steps, *,
                         barrier: str = "sync",
                         buffer_rounds: int = 0) -> float:
    """Simulated wall-clock seconds per round (relative units).

    sync   the server waits for every client: max_m(t_m · H_m).
    async  a client whose delta may land up to B rounds late can spread its
           work over B server periods, so the server pace only needs
           max_m(t_m · H_m) / B — the staleness budget buys wall-clock.
    """
    step_times = np.asarray(step_times, dtype=np.float64)
    h_m = np.asarray(local_steps, dtype=np.float64)
    slowest = float((step_times * h_m).max())
    if barrier == "sync":
        return slowest
    if barrier == "async":
        return slowest / max(int(buffer_rounds), 1)
    raise ValueError(f"barrier {barrier!r}; expected 'sync' or 'async'")


def labeled_mask(labels: np.ndarray, labeled_frac: float,
                 seed: int = 0) -> np.ndarray:
    """Label-scarcity mask for semi-supervised clients (DESIGN.md §12).

    Returns a float32 0/1 array over ``labels`` marking which examples keep
    their label; the rest are treated as unlabeled by the semi-supervised
    client objectives. The draw is stratified per class with largest-remainder
    rounding, so every class keeps ~labeled_frac of its examples labeled
    (at least 1 per class whenever labeled_frac > 0) — the standard SSL
    protocol. labeled_frac >= 1 returns all-ones; <= 0 all-zeros.
    """
    n = len(labels)
    if labeled_frac >= 1.0:
        return np.ones(n, dtype=np.float32)
    if labeled_frac <= 0.0:
        return np.zeros(n, dtype=np.float32)
    rng = np.random.default_rng(seed)
    mask = np.zeros(n, dtype=np.float32)
    classes = np.unique(labels)
    counts = np.array([(labels == c).sum() for c in classes])
    quota = _largest_remainder(counts * labeled_frac, int(round(n * labeled_frac)))
    quota = np.maximum(quota, 1)
    for c, q in zip(classes, quota):
        idx = np.where(labels == c)[0]
        mask[rng.permutation(idx)[:min(int(q), len(idx))]] = 1.0
    return mask


def heterogeneity_score(labels: np.ndarray, parts) -> float:
    """Mean total-variation distance between client label dists and global."""
    classes = np.unique(labels)
    glob = np.array([(labels == c).mean() for c in classes])
    tv = []
    for idx in parts:
        loc = np.array([(labels[idx] == c).mean() for c in classes])
        tv.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tv))
