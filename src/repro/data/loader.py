"""Client-sharded batching for SAVIC rounds.

A SAVIC round consumes a batch whose leaves are (M, H, b, ...): H local
microbatches of size b for each of M clients. ``FederatedLoader`` wraps a
dataset + partition and yields such round-batches; ``LMRoundLoader`` does the
same for token streams.
"""
from __future__ import annotations

import numpy as np


class FederatedLoader:
    """``labeled`` is an optional per-example float32 0/1 array over the full
    dataset (see ``federated.labeled_mask``); when given, round batches carry
    a ``"labeled"`` (M,H,b) leaf consumed by the semi-supervised client
    objectives (DESIGN.md §12). When None (the default), the batch structure
    is exactly the pre-objectives two-leaf {"x", "y"} dict — supervised runs
    are bit-identical to before the knob existed."""

    def __init__(self, x, y, parts, batch_size: int, seed: int = 0,
                 labeled=None):
        self.x, self.y = x, y
        self.parts = parts
        self.b = batch_size
        self.labeled = labeled
        self.rng = np.random.default_rng(seed)

    @property
    def n_clients(self):
        return len(self.parts)

    def round_batch(self, H: int):
        """Returns {"x": (M,H,b,D), "y": (M,H,b)[, "labeled": (M,H,b)]}."""
        M, b = self.n_clients, self.b
        xs = np.empty((M, H, b) + self.x.shape[1:], dtype=self.x.dtype)
        ys = np.empty((M, H, b), dtype=self.y.dtype)
        lab = (np.empty((M, H, b), dtype=np.float32)
               if self.labeled is not None else None)
        for m, idx in enumerate(self.parts):
            pick = self.rng.choice(idx, size=(H, b), replace=True)
            xs[m] = self.x[pick]
            ys[m] = self.y[pick]
            if lab is not None:
                lab[m] = self.labeled[pick]
        out = {"x": xs, "y": ys}
        if lab is not None:
            out["labeled"] = lab
        return out


class QuadraticLoader:
    """Noise-only 'batches' for QuadraticProblem: each microbatch is a noise
    vector added to the gradient (Assumption 2 with variance σ²)."""

    def __init__(self, problem, seed: int = 0):
        self.p = problem
        self.rng = np.random.default_rng(seed)

    def round_batch(self, H: int):
        M, d = self.p.b.shape
        z = self.rng.normal(size=(M, H, d)) * (self.p.sigma / np.sqrt(d))
        cid = np.broadcast_to(np.arange(M, dtype=np.int32)[:, None], (M, H))
        return {"z": z.astype(np.float32), "cid": np.ascontiguousarray(cid)}


class LMRoundLoader:
    """Round-addressable LM round batches: ``round_batch(r, ...)`` is a pure
    function of (stream seed, r, M, H, b, S) — all M·H·b sequences come from
    ONE vectorized ``TokenStream.batch_at`` draw (the former Python M×H loop
    was a per-round bottleneck at LM shapes), and a restored run at round r
    draws round-r data (DESIGN.md §9)."""

    def __init__(self, stream, n_clients: int, batch_size: int,
                 labeled_frac: float = 1.0, seed: int = 0):
        self.stream = stream
        self.M = n_clients
        self.b = batch_size
        self.labeled_frac = labeled_frac
        self.seed = seed

    def round_batch(self, r: int, H: int, seq_len: int):
        toks, labs = self.stream.batch_at(r, self.M * H * self.b, seq_len)
        shape = (self.M, H, self.b, seq_len)
        out = {"tokens": toks.reshape(shape), "labels": labs.reshape(shape)}
        if self.labeled_frac < 1.0:
            # Per-SEQUENCE labeled mask, round-addressable like the tokens:
            # a pure function of (seed, r), so checkpoint resume at round r
            # redraws the identical mask (DESIGN.md §9/§12). labeled_frac
            # >= 1 emits no leaf at all — supervised batches are bit-exact
            # pre-objectives structures.
            rng = np.random.default_rng([self.seed, 24593, r])
            lab = rng.random((self.M, H, self.b)) < self.labeled_frac
            out["labeled"] = lab.astype(np.float32)
        return out
