"""Client-sharded batching for SAVIC rounds.

A SAVIC round consumes a batch whose leaves are (M, H, b, ...): H local
microbatches of size b for each of M clients. ``FederatedLoader`` wraps a
dataset + partition and yields such round-batches; ``LMRoundLoader`` does the
same for token streams.
"""
from __future__ import annotations

import numpy as np


class FederatedLoader:
    def __init__(self, x, y, parts, batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.parts = parts
        self.b = batch_size
        self.rng = np.random.default_rng(seed)

    @property
    def n_clients(self):
        return len(self.parts)

    def round_batch(self, H: int):
        """Returns {"x": (M,H,b,D), "y": (M,H,b)}."""
        M, b = self.n_clients, self.b
        xs = np.empty((M, H, b) + self.x.shape[1:], dtype=self.x.dtype)
        ys = np.empty((M, H, b), dtype=self.y.dtype)
        for m, idx in enumerate(self.parts):
            pick = self.rng.choice(idx, size=(H, b), replace=True)
            xs[m] = self.x[pick]
            ys[m] = self.y[pick]
        return {"x": xs, "y": ys}


class QuadraticLoader:
    """Noise-only 'batches' for QuadraticProblem: each microbatch is a noise
    vector added to the gradient (Assumption 2 with variance σ²)."""

    def __init__(self, problem, seed: int = 0):
        self.p = problem
        self.rng = np.random.default_rng(seed)

    def round_batch(self, H: int):
        M, d = self.p.b.shape
        z = self.rng.normal(size=(M, H, d)) * (self.p.sigma / np.sqrt(d))
        cid = np.broadcast_to(np.arange(M, dtype=np.int32)[:, None], (M, H))
        return {"z": z.astype(np.float32), "cid": np.ascontiguousarray(cid)}


class LMRoundLoader:
    def __init__(self, stream, n_clients: int, batch_size: int):
        self.stream = stream
        self.M = n_clients
        self.b = batch_size

    def round_batch(self, H: int, seq_len: int):
        toks = np.empty((self.M, H, self.b, seq_len), np.int32)
        labs = np.empty_like(toks)
        for m in range(self.M):
            for h in range(H):
                t, l = self.stream.batch(self.b, seq_len)
                toks[m, h], labs[m, h] = t, l
        return {"tokens": toks, "labels": labs}
