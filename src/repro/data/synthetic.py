"""Synthetic datasets (the container has no network access; CIFAR-10 is
replaced by a same-shape synthetic classification set, see DESIGN.md §7).

* ``ClassificationData`` — CIFAR-shaped images with class-dependent Gaussian
  prototypes + noise; learnable but not trivial. Used by the paper-experiment
  reproduction (benchmarks/fig1) with the main-class partitioner.
* ``QuadraticProblem`` — strongly-convex quadratics with controllable μ, L,
  gradient noise σ² and client heterogeneity; the only setting where the
  theorems are quantitatively falsifiable.
* ``TokenStream`` — deterministic pseudo-token LM stream (mixture of n-gram
  generators) for end-to-end LM training examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClassificationData:
    x: np.ndarray           # (N, D) float32
    y: np.ndarray           # (N,) int32
    n_classes: int

    @staticmethod
    def make(n=20_000, shape=(8, 8, 3), n_classes=10, noise=1.0, seed=0):
        rng = np.random.default_rng(seed)
        D = int(np.prod(shape))
        protos = rng.normal(size=(n_classes, D)).astype(np.float32)
        protos /= np.linalg.norm(protos, axis=1, keepdims=True)
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        x = 2.0 * protos[y] + noise * rng.normal(size=(n, D)).astype(np.float32)
        # second-order structure so adaptivity has something to exploit:
        scales = np.exp(rng.uniform(-2, 2, size=D)).astype(np.float32)
        x = x * scales[None, :]
        return ClassificationData(x=x.astype(np.float32), y=y,
                                  n_classes=n_classes)


@dataclasses.dataclass
class QuadraticProblem:
    """f_m(x) = 0.5 (x-b_m)ᵀ Q_m (x-b_m); stochastic grads add N(0, σ²/d I).

    heterogeneity h shifts each client's optimum b_m by h·unit vectors; h=0
    gives the identical-data regime (all f_m equal).
    """
    Q: np.ndarray            # (M, d, d)
    b: np.ndarray            # (M, d)
    sigma: float
    mu: float
    L: float

    @staticmethod
    def make(d=50, M=8, mu=0.1, L=10.0, sigma=1.0, heterogeneity=0.0, seed=0):
        rng = np.random.default_rng(seed)
        Qs, bs = [], []
        # shared eigenbasis, per-client spectra within [mu, L]
        A = rng.normal(size=(d, d))
        U, _ = np.linalg.qr(A)
        center = rng.normal(size=d)     # common optimum (x0=0 is NOT optimal)
        for m in range(M):
            eig = np.exp(rng.uniform(np.log(mu), np.log(L), size=d))
            eig[0], eig[-1] = mu, L     # pin extremes
            Qs.append((U * eig) @ U.T)
            shift = heterogeneity * rng.normal(size=d) / np.sqrt(d)
            bs.append(center + shift)
        return QuadraticProblem(Q=np.stack(Qs).astype(np.float64),
                                b=np.stack(bs).astype(np.float64),
                                sigma=sigma, mu=mu, L=L)

    def x_star(self):
        """argmin of the average objective: (ΣQ_m)^{-1} ΣQ_m b_m."""
        Qbar = self.Q.mean(0)
        rhs = np.einsum("mij,mj->i", self.Q, self.b) / self.Q.shape[0]
        return np.linalg.solve(Qbar, rhs)

    def sigma_dif2(self):
        """σ²_dif = (1/M) Σ E‖∇f_m(x*, z)‖² at the global optimum."""
        xs = self.x_star()
        g2 = [np.sum((self.Q[m] @ (xs - self.b[m])) ** 2)
              for m in range(self.Q.shape[0])]
        return float(np.mean(g2) + self.sigma ** 2)


class TokenStream:
    """Deterministic synthetic LM data: tokens from a mixture of order-2
    Markov chains (so a real model can reduce loss well below uniform).

    Two access modes share one vectorized walk (a single Python loop over the
    sequence dim; all batch dims advance in one fancy-indexed step):

    * ``batch``    — stateful stream, kept for single-shot consumers;
    * ``batch_at`` — stateless and round-addressable: batch ``index`` is a
      pure function of (stream seed, index, shapes), so a run restored at
      round r draws exactly round-r data (DESIGN.md §9).
    """

    def __init__(self, vocab_size: int, seed: int = 0, n_chains: int = 4):
        self.vocab = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # stacked sparse transition structure: (n_chains, vocab, 8)
        self.chains = rng.integers(0, vocab_size,
                                   size=(n_chains, vocab_size, 8),
                                   dtype=np.int32)

        self._rng = np.random.default_rng(seed + 1)

    def _walk(self, rng, batch_size: int, seq_len: int):
        """(B, S+1) chain walk: per-sequence chain id, vectorized over B."""
        cid = rng.integers(self.chains.shape[0], size=batch_size)
        start = rng.integers(self.vocab, size=batch_size)
        branch = rng.integers(8, size=(batch_size, seq_len))
        out = np.empty((batch_size, seq_len + 1), dtype=np.int32)
        out[:, 0] = start
        for s in range(seq_len):
            out[:, s + 1] = self.chains[cid, out[:, s], branch[:, s]]
        return out

    def batch(self, batch_size: int, seq_len: int):
        """Returns (tokens, labels) int32 of shape (B, S); labels = next token."""
        out = self._walk(self._rng, batch_size, seq_len)
        return out[:, :-1], out[:, 1:]

    def batch_at(self, index: int, batch_size: int, seq_len: int):
        """Stateless ``batch``: draw batch ``index`` of the stream. Same
        (seed, index, shapes) always yields the same arrays."""
        rng = np.random.default_rng((self.seed, int(index)))
        out = self._walk(rng, batch_size, seq_len)
        return out[:, :-1], out[:, 1:]
