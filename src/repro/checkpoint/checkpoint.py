"""Dependency-free pytree checkpointing (msgpack + raw numpy buffers).

Layout: ``<dir>/step_<n>/state.msgpack`` holding a manifest (paths, shapes,
dtypes, scalars) and a single concatenated buffer file. Restores into the
exact pytree structure given a template (or returns raw dict-of-arrays).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.utils.tree import tree_from_paths, tree_paths

_MAGIC = "repro-ckpt-v1"


def save(ckpt_dir: str, step: int, state, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(ckpt_dir, exist_ok=True)
    # a save that crashed mid-write leaves its step_*.tmp dir behind (only a
    # COMPLETE tmp is ever renamed into place); reclaim all orphans before
    # starting this write
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    os.makedirs(tmp)
    manifest = {"magic": _MAGIC, "step": step, "leaves": []}
    with open(os.path.join(tmp, "data.bin"), "wb") as fb:
        off = 0
        for p, leaf in tree_paths(state):
            arr = np.asarray(jax.device_get(leaf))
            buf = np.ascontiguousarray(arr).tobytes()
            manifest["leaves"].append({
                "path": p, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "offset": off, "nbytes": len(buf),
            })
            fb.write(buf)
            off += len(buf)
    with open(os.path.join(tmp, "state.msgpack"), "wb") as fm:
        fm.write(msgpack.packb(manifest))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: int = None):
    """Restore into the structure of ``template`` (shapes must match)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "state.msgpack"), "rb") as fm:
        manifest = msgpack.unpackb(fm.read())
    assert manifest["magic"] == _MAGIC
    by_path = {l["path"]: l for l in manifest["leaves"]}
    data = open(os.path.join(path, "data.bin"), "rb").read()

    def one(p, leaf):
        meta = by_path[p]
        arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]),
                            count=int(np.prod(meta["shape"]) or 1),
                            offset=meta["offset"]).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: ckpt {arr.shape} != template {leaf.shape}")
        return jnp.asarray(arr)

    return tree_from_paths(template, one), step


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
