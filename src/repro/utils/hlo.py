"""HLO post-processing: collective traffic + op census from compiled modules.

``collective_bytes(hlo_text)`` sums *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute in
the optimized, partitioned HLO — the §Roofline collective term's numerator.

Optimized HLO prints operands untyped (``all-gather(%fusion.1)``), so operand
bytes are derived from the typed *result* plus the replica-group size gs:

    all-reduce          operand = result
    all-to-all          operand = result
    collective-permute  operand = result
    all-gather          operand = result / gs   (result is the gathered buf)
    reduce-scatter      operand = result * gs   (result is one shard)

Sizes are per-device values (the SPMD module is per-partition); multiply by
device count for fleet-wide traffic.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = <types> <opcode>(` — result types may be a tuple for -start forms
_LINE_RE = re.compile(
    r"=\s*(?P<types>[^=]*?)\s(?P<op>" + "|".join(_COLLECTIVES)
    + r")(?P<async>-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# replica_groups=[32,8]<=... (32 groups of 8) or explicit {{0,1},{2,3},...}
_RG_COMPACT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _RG_COMPACT_RE.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str):
    """Returns (total_operand_bytes, per_kind dict, op_count dict)."""
    per_kind = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("async") == "-done":
            continue
        kind = m.group("op")
        shapes = _SHAPE_RE.findall(m.group("types"))
        res_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
        gs = _group_size(line)
        if kind == "all-gather":
            op_bytes = res_bytes // max(gs, 1)
        elif kind == "reduce-scatter":
            op_bytes = res_bytes * gs
        else:
            op_bytes = res_bytes
        per_kind[kind] += op_bytes
        counts[kind] += 1
    return sum(per_kind.values()), dict(per_kind), dict(counts)


def op_census(hlo_text: str, top=15):
    """Rough census of op kinds (fusion-aware enough for perf iteration)."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*[a-z0-9]+\[[0-9,]*\][^ ]*\s+([a-z][a-z0-9\-]{2,})\(",
                      line)
        if m:
            counts[m.group(1)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])


def collective_lines(hlo_text: str, limit=40):
    """The raw collective instructions (for perf-iteration eyeballing)."""
    out = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m and m.group("async") != "-done":
            out.append(line.strip()[:220])
            if len(out) >= limit:
                break
    return out
