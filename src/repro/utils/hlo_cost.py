"""Trip-count-aware cost model over optimized HLO text.

Why: ``compiled.cost_analysis()`` visits every while-loop body ONCE, but our
models scan over layers and SAVIC scans over H local steps — so FLOPs, bytes
and (critically) collectives inside scans are under-counted by the trip count
(e.g. 95× for deepseek-67b's layer scan). XLA annotates loops with
``backend_config={"known_trip_count":{"n":...}}``; this module parses the HLO
module text, builds the computation call graph, and multiplies per-computation
costs by the product of enclosing trip counts.

Cost model (documented approximations):
* FLOPs: matmuls only (``dot``: 2 · numel(result) · prod(lhs contracting
  dims)); elementwise flops ignored (<5% for transformer workloads).
* bytes: counted at fusion boundaries (operands + result of non-fused,
  non-structural instructions); instructions inside fused computations are
  VMEM-internal. dynamic-update-slice counts 2×update (in-place), gather /
  dynamic-slice count 2×result, scatter 2×updates.
* collectives: operand bytes per kind (all-gather result/gs, reduce-scatter
  result·gs, others = result), times the enclosing trip-count multiplier.

Validated against analytic counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict


def xla_cost_properties(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jaxlib versions.

    Newer jaxlib (the CI container's) returns a list with one dict per
    executable; older versions return the dict directly; either may be empty.
    Every consumer of the raw XLA numbers (dryrun.py, tests) should go
    through here instead of unwrapping ad hoc. Regression-pinned in
    tests/test_hlo_cost.py.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_STRUCTURAL = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "optimization-barrier", "copy-start", "copy-done", "domain"}

_SHAPE_TOKEN = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^(?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                     r"([a-z][\w\-]*)\((.*)$")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^{]*\))?\s*->.*\{")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_RG_COMPACT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CALLED = re.compile(r"(body|condition|calls|to_apply)=(%[\w.\-]+)")
_CALLED_MULTI = re.compile(r"(?:branch_computations|called_computations)="
                           r"\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[...] token in a type string."""
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_TOKEN.findall(text))


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    rest: str                 # text after the opening paren of the op
    type_text: str
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def _parse(hlo: str):
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        # tuple types embed /*index=N*/ comments whose '=' breaks opcode
        # detection — strip comments before parsing
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        h = _COMP_HEADER.match(line.strip())
        if h and line.strip().endswith("{"):
            cur = Computation(name=h.group(2), instrs=[],
                              is_entry=bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        body = m.group(3)
        om = _OPCODE.match(body)
        if not om:
            continue
        opcode, rest = om.group(1), om.group(2)
        # operand refs: %names before the first "), "
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arglist = rest[:max(i - 1, 0)]
        operands = re.findall(r"%[\w.\-]+", arglist)
        type_text = body[: body.find(opcode + "(")]
        cur.instrs.append(Instr(m.group(2), opcode, rest, type_text, operands))
    return comps


def _multipliers(comps):
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult = defaultdict(float)
    fusion_body = set()
    unknown_loops = []
    if entry is None:
        return mult, fusion_body, unknown_loops
    mult[entry.name] = 1.0
    stack = [entry.name]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            for kind, callee in _CALLED.findall(ins.rest):
                edge = (cname, ins.name, callee, kind)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                k = 1.0
                if kind == "body":
                    tm = _TRIP.search(ins.rest)
                    if tm:
                        k = float(tm.group(1))
                    else:
                        unknown_loops.append(ins.name)
                if kind == "calls" and ins.opcode == "fusion":
                    fusion_body.add(callee)
                mult[callee] += m * k
                stack.append(callee)
            mm = _CALLED_MULTI.search(ins.rest)
            if mm:
                for callee in re.findall(r"%[\w.\-]+", mm.group(1)):
                    edge = (cname, ins.name, callee, "multi")
                    if edge not in seen_edges:
                        seen_edges.add(edge)
                        mult[callee] += m
                        stack.append(callee)
    return mult, fusion_body, unknown_loops


def _group_size(rest: str) -> int:
    m = _RG_COMPACT.search(rest)
    if m:
        return int(m.group(2))
    m = _RG_LIST.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                      r"(?:T\(([0-9,]+)\))?")
_RG_FULL_LIST = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")


def _crosses_boundary(rest: str, boundary: int) -> bool:
    """True if any replica group contains device ids on both sides of
    ``boundary`` (e.g. 256 = pod size -> inter-pod traffic)."""
    import numpy as np
    m = _RG_IOTA.search(rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        return bool(((groups < boundary).any(axis=1)
                     & (groups >= boundary).any(axis=1)).any())
    m = _RG_FULL_LIST.search(rest)
    if m:
        for grp in re.findall(r"\{([0-9, ]+)\}", m.group(1)):
            ids = [int(x) for x in grp.split(",")]
            if min(ids) < boundary <= max(ids):
                return True
        return False
    return False


def analyze(hlo: str, pod_boundary: int = 0):
    """Returns dict with trip-count-corrected flops / bytes / collectives.

    ``pod_boundary`` > 0 additionally splits collective bytes into intra- vs
    inter-pod traffic (groups containing ids on both sides of the boundary)."""
    comps = _parse(hlo)
    mult, fusion_bodies, unknown = _multipliers(comps)

    # symbol table: %instr -> result bytes (across all comps; names are unique)
    sizes = {}
    shapes = {}
    for comp in comps.values():
        for ins in comp.instrs:
            sizes[ins.name] = _first_shape_bytes(ins.type_text)
            ts = _SHAPE_TOKEN.findall(ins.type_text)
            shapes[ins.name] = ts

    flops = 0.0
    bytes_hbm = 0.0
    coll = defaultdict(float)
    coll_n = defaultdict(float)
    coll_xpod = 0.0

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp.name in fusion_bodies
        for ins in comp.instrs:
            op = ins.opcode
            # ---- flops (matmuls) --------------------------------------------
            if op == "dot":
                cm = _CONTRACT.search(ins.rest)
                lhs = ins.operands[0] if ins.operands else None
                cdim = 1
                if cm and lhs and shapes.get(lhs):
                    dims = shapes[lhs][0][1]
                    dims = [int(d) for d in dims.split(",")] if dims else []
                    for ci in cm.group(1).split(","):
                        if ci != "" and int(ci) < len(dims):
                            cdim *= dims[int(ci)]
                out_elems = sum(_shape_elems(d) for _, d in shapes[ins.name])
                flops += m * 2.0 * out_elems * cdim
            # ---- collectives -------------------------------------------------
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                res = sizes[ins.name]
                gs = _group_size(ins.rest)
                if base == "all-gather":
                    ob = res / max(gs, 1)
                elif base == "reduce-scatter":
                    ob = res * gs
                else:
                    ob = res
                coll[base] += m * ob
                coll_n[base] += m
                if pod_boundary and _crosses_boundary(ins.rest, pod_boundary):
                    coll_xpod += m * ob
            # ---- bytes at fusion boundaries ----------------------------------
            if in_fusion or op in _STRUCTURAL:
                continue
            if op == "dynamic-update-slice":
                upd = sizes.get(ins.operands[1], 0) if len(ins.operands) > 1 \
                    else 0
                bytes_hbm += m * 2 * upd
            elif op in ("gather", "dynamic-slice"):
                bytes_hbm += m * 2 * sizes[ins.name]
            elif op == "scatter":
                upd = sizes.get(ins.operands[-1], 0)
                bytes_hbm += m * 2 * upd
            else:
                ob = sum(sizes.get(o, 0) for o in ins.operands)
                bytes_hbm += m * (sizes[ins.name] + ob)

    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "collective_bytes": sum(coll.values()),
        "collective_by_kind": dict(coll),
        "collective_counts": dict(coll_n),
        "collective_bytes_interpod": coll_xpod,
        "unknown_trip_loops": unknown,
    }
