"""Flat-buffer view of a client-state pytree (DESIGN.md §7).

The fused client loop runs H local steps per round on buffers shaped
``(M, n_total)`` — every params/momentum/D leaf reshaped and concatenated into
one contiguous fp32 buffer per client — so the whole optimizer update is ONE
Pallas pass per local step instead of one launch per leaf.  ``FlatLayout``
records the leaf order, shapes, sizes and offsets of that view so the tree can
be reconstructed bit-exactly at the sync barrier (flatten at round start,
unflatten only at sync).

Flatten/unflatten are pure reshape+concatenate / slice+reshape — values are
never touched, which is what makes the flat path bit-identical to the tree
path (pinned in tests/test_fused_step.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_paths


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Layout of a pytree flattened into one trailing ``(n_total,)`` axis.

    Built from a *single-replica* tree (arrays or ShapeDtypeStructs, no
    leading client dim); ``flatten``/``unflatten`` then accept trees whose
    leaves carry ``batch_dims`` extra leading axes (the client dim M in the
    engine) which are preserved as leading axes of the flat buffer.
    """
    treedef: jax.tree_util.PyTreeDef
    paths: tuple          # '/'-joined key path per leaf, flatten order
    shapes: tuple         # single-replica shape per leaf
    sizes: tuple          # element count per leaf
    offsets: tuple        # start offset of each leaf in the flat axis
    n_total: int

    @classmethod
    def for_tree(cls, tree, batch_dims: int = 0) -> "FlatLayout":
        """Derive the layout; ``batch_dims`` leading axes are ignored."""
        paths, shapes, sizes, offsets = [], [], [], []
        off = 0
        for path, leaf in tree_paths(tree):
            shape = tuple(leaf.shape[batch_dims:])
            size = int(np.prod(shape)) if shape else 1
            paths.append(path)
            shapes.append(shape)
            sizes.append(size)
            offsets.append(off)
            off += size
        return cls(treedef=jax.tree.structure(tree), paths=tuple(paths),
                   shapes=tuple(shapes), sizes=tuple(sizes),
                   offsets=tuple(offsets), n_total=off)

    def flatten(self, tree, batch_dims: int = 0):
        """Tree with ``batch_dims`` leading axes -> fp32 ``(*batch, n_total)``."""
        leaves = jax.tree.leaves(tree)
        flat = [l.reshape(l.shape[:batch_dims] + (-1,)).astype(jnp.float32)
                for l in leaves]
        return jnp.concatenate(flat, axis=-1)

    def unflatten(self, buf, batch_dims: int = 0):
        """``(*batch, n_total)`` -> the tree (leaves cast back per-layout fp32
        — the fast path only engages for fp32 state, so this is exact)."""
        batch = buf.shape[:batch_dims]
        leaves = [buf[..., o:o + s].reshape(batch + shp)
                  for o, s, shp in zip(self.offsets, self.sizes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)

    def describe(self) -> dict:
        """JSON-able summary for BuiltStep meta / dry-run artifacts."""
        return {
            "n_total": self.n_total,
            "leaves": [
                {"path": p, "shape": list(s), "size": sz, "offset": o}
                for p, s, sz, o in zip(self.paths, self.shapes, self.sizes,
                                       self.offsets)
            ],
        }


def all_float32(tree) -> bool:
    """True iff every leaf is fp32 — the fused fast path's dtype gate."""
    return all(l.dtype == jnp.float32 for l in jax.tree.leaves(tree))
