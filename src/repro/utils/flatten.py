"""Flat-buffer view of a client-state pytree (DESIGN.md §7).

The fused client loop runs H local steps per round on buffers shaped
``(M, n_total)`` — every params/momentum/D leaf reshaped and concatenated into
one contiguous fp32 buffer per client — so the whole optimizer update is ONE
Pallas pass per local step instead of one launch per leaf.  ``FlatLayout``
records the leaf order, shapes, sizes and offsets of that view so the tree can
be reconstructed bit-exactly at the sync barrier (flatten at round start,
unflatten only at sync).

``ShardFlatLayout`` is the model-/FSDP-sharded counterpart: the single global
flat axis cannot follow per-leaf shardings (GSPMD would reshard the whole
client state every local step), so on sharded plans each device flattens only
its LOCAL leaf shards into an fp32 ``(M, n_local)`` block and the global flat
buffer is the shard-major concatenation of those blocks, sharded over the
plan's model/FSDP axes.  Flatten/unflatten run inside ``shard_map`` so no
collective ever touches the flat buffers; ``ShardedFlatPlan`` bundles the
layout with the mesh/client axes for the engine's fused fast path.

Flatten/unflatten are pure reshape+concatenate / slice+reshape — values are
never touched, which is what makes the flat path bit-identical to the tree
path (pinned in tests/test_fused_step.py and tests/test_fused_sharded.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.utils.tree import tree_paths


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Layout of a pytree flattened into one trailing ``(n_total,)`` axis.

    Built from a *single-replica* tree (arrays or ShapeDtypeStructs, no
    leading client dim); ``flatten``/``unflatten`` then accept trees whose
    leaves carry ``batch_dims`` extra leading axes (the client dim M in the
    engine) which are preserved as leading axes of the flat buffer.
    """
    treedef: jax.tree_util.PyTreeDef
    paths: tuple          # '/'-joined key path per leaf, flatten order
    shapes: tuple         # single-replica shape per leaf
    sizes: tuple          # element count per leaf
    offsets: tuple        # start offset of each leaf in the flat axis
    n_total: int

    @classmethod
    def for_tree(cls, tree, batch_dims: int = 0) -> "FlatLayout":
        """Derive the layout; ``batch_dims`` leading axes are ignored."""
        paths, shapes, sizes, offsets = [], [], [], []
        off = 0
        for path, leaf in tree_paths(tree):
            shape = tuple(leaf.shape[batch_dims:])
            size = int(np.prod(shape)) if shape else 1
            paths.append(path)
            shapes.append(shape)
            sizes.append(size)
            offsets.append(off)
            off += size
        return cls(treedef=jax.tree.structure(tree), paths=tuple(paths),
                   shapes=tuple(shapes), sizes=tuple(sizes),
                   offsets=tuple(offsets), n_total=off)

    def flatten(self, tree, batch_dims: int = 0):
        """Tree with ``batch_dims`` leading axes -> fp32 ``(*batch, n_total)``."""
        leaves = jax.tree.leaves(tree)
        flat = [l.reshape(l.shape[:batch_dims] + (-1,)).astype(jnp.float32)
                for l in leaves]
        return jnp.concatenate(flat, axis=-1)

    def unflatten(self, buf, batch_dims: int = 0):
        """``(*batch, n_total)`` -> the tree (leaves cast back per-layout fp32
        — the fast path only engages for fp32 state, so this is exact)."""
        batch = buf.shape[:batch_dims]
        leaves = [buf[..., o:o + s].reshape(batch + shp)
                  for o, s, shp in zip(self.offsets, self.sizes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)

    def describe(self) -> dict:
        """JSON-able summary for BuiltStep meta / dry-run artifacts."""
        return {
            "n_total": self.n_total,
            "leaves": [
                {"path": p, "shape": list(s), "size": sz, "offset": o}
                for p, s, sz, o in zip(self.paths, self.shapes, self.sizes,
                                       self.offsets)
            ],
        }


def all_float32(tree) -> bool:
    """True iff every leaf is fp32 — the fused fast path's dtype gate."""
    return all(l.dtype == jnp.float32 for l in jax.tree.leaves(tree))


# --------------------------------------------------------------------------- #
# shard-local flat view (model-/FSDP-sharded plans; DESIGN.md §7)
# --------------------------------------------------------------------------- #


def _entry_axes(entry):
    """PartitionSpec entry -> tuple of mesh-axis names (major first)."""
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


@dataclasses.dataclass(frozen=True)
class ShardFlatLayout:
    """Per-shard flat view of a single-replica pytree sharded over ``axes``.

    Built at trace time from the plan's NamedShardings (their PartitionSpecs
    + the mesh axis sizes): for each leaf and each dim, the dim is *split*
    when its spec shards it over a subset of ``axes`` whose extent divides it;
    otherwise — uneven extents (dim % extent ∈ {1, …, extent−1}) and leaves
    smaller than one shard included — that dim falls back to *replicated in
    every shard block*, which is exactly what GSPMD does with such leaves on
    the tree path (each device holds and updates a full copy), so the fused
    step stays bit-identical with zero extra memory per device.

    The global flat buffer is the SHARD-MAJOR concatenation of the per-shard
    local blocks: shape ``(*batch, n_shards · n_local)``, flat axis sharded
    ``P(axes)``.  Each device's resident chunk is precisely the flat view of
    its local leaf shards, so flatten / the fused step / unflatten all run
    inside ``shard_map`` with in_specs == out_specs == the storage shardings:
    no resharding collective can appear (pinned in tests/test_fused_sharded.py).
    """
    local: FlatLayout                 # layout of ONE shard's local blocks
    axes: Tuple[str, ...]             # shard (model/FSDP) axes, major first
    axis_sizes: Tuple[int, ...]       # mesh extent per axis
    specs: tuple                      # per-leaf effective inner PartitionSpec
    global_shapes: tuple              # per-leaf single-replica global shape
    split: tuple                      # per-leaf: any dim actually sharded
    uneven: tuple                     # per-leaf: replicated by uneven fallback

    @property
    def n_shards(self) -> int:
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n

    @property
    def n_local(self) -> int:
        return self.local.n_total

    @property
    def n_flat(self) -> int:
        return self.n_shards * self.local.n_total

    @classmethod
    def for_tree(cls, tree, pspecs, mesh_shape, axes) -> "ShardFlatLayout":
        """Derive the layout from a SINGLE-REPLICA (shape-)tree.

        ``pspecs`` is the matching PartitionSpec tree (single-replica: no
        client dim), ``mesh_shape`` a mapping axis name -> size (``Mesh.shape``
        or a plain dict), ``axes`` the shard axes in flat-axis order.
        """
        axes = tuple(axes)
        sizes = tuple(int(mesh_shape[a]) for a in axes)
        spec_leaves = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
        paths_leaves = tree_paths(tree)
        if len(spec_leaves) != len(paths_leaves):
            raise ValueError(f"pspec tree has {len(spec_leaves)} leaves for "
                             f"{len(paths_leaves)} tree leaves")
        eff_specs, local_shapes, gshapes, split, uneven = [], [], [], [], []
        for (path, leaf), spec in zip(paths_leaves, spec_leaves):
            shape = tuple(leaf.shape)
            entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
            eff, loc, any_split, any_uneven = [], [], False, False
            for dim, entry in zip(shape, entries):
                shard_ax = _entry_axes(entry)
                alien = [a for a in shard_ax if a not in axes]
                if alien:
                    raise ValueError(
                        f"leaf {path!r}: spec {spec} uses axis {alien[0]!r} "
                        f"outside the shard axes {axes}")
                ext = 1
                for a in shard_ax:
                    ext *= int(mesh_shape[a])
                if ext > 1 and dim % ext == 0:
                    eff.append(entry)
                    loc.append(dim // ext)
                    any_split = True
                else:
                    # uneven extent (or size-1 axes): replicate this dim in
                    # every shard block — the GSPMD-equivalent fallback
                    if ext > 1:
                        any_uneven = True
                    eff.append(None)
                    loc.append(dim)
            eff_specs.append(P(*eff))
            local_shapes.append(tuple(loc))
            gshapes.append(shape)
            split.append(any_split)
            uneven.append(any_uneven)
        treedef = jax.tree.structure(tree)
        local_tree = jax.tree.unflatten(treedef, [
            jax.ShapeDtypeStruct(s, jnp.float32) for s in local_shapes])
        return cls(local=FlatLayout.for_tree(local_tree), axes=axes,
                   axis_sizes=sizes, specs=tuple(eff_specs),
                   global_shapes=tuple(gshapes), split=tuple(split),
                   uneven=tuple(uneven))

    # ---- shard_map specs ------------------------------------------------- #

    def flat_spec(self, lead=()) -> P:
        """Spec of the flat buffer: ``lead`` entries then the shard axes."""
        return P(*lead, self.axes)

    def leaf_specs(self, lead=()):
        """PartitionSpec tree for the (possibly batched) leaf tree."""
        return jax.tree.unflatten(
            self.local.treedef, [P(*lead, *tuple(s)) for s in self.specs])

    # ---- shard_map flatten / unflatten ----------------------------------- #

    def flatten(self, tree, mesh, lead=()):
        """Leaf tree (``len(lead)`` leading batch dims) -> sharded flat
        buffer ``(*batch, n_flat)`` — each device flattens only its local
        shards; no cross-device traffic."""
        bd = len(lead)
        f = shard_map(lambda t: self.local.flatten(t, batch_dims=bd),
                      mesh=mesh, in_specs=(self.leaf_specs(lead),),
                      out_specs=self.flat_spec(lead), check_rep=False)
        return f(tree)

    def unflatten(self, buf, mesh, lead=()):
        """Sharded flat buffer -> the leaf tree, each device reconstructing
        its local shards (replicated-in-block leaves agree bit-for-bit across
        shards by construction: same elementwise math on identical inputs)."""
        bd = len(lead)
        f = shard_map(lambda b: self.local.unflatten(b, batch_dims=bd),
                      mesh=mesh, in_specs=(self.flat_spec(lead),),
                      out_specs=self.leaf_specs(lead), check_rep=False)
        return f(buf)

    # ---- mesh-free reference (tests + differential oracle) ---------------- #

    def _shard_slices(self, s: int):
        """Per-leaf index tuples selecting shard ``s``'s local block."""
        coords = np.unravel_index(s, self.axis_sizes) if self.axes else ()
        by_axis = dict(zip(self.axes, (int(c) for c in coords)))
        size_of = dict(zip(self.axes, self.axis_sizes))
        out = []
        for spec, gshape, lshape in zip(self.specs, self.global_shapes,
                                        self.local.shapes):
            idx = []
            for dim, loc, entry in zip(
                    gshape, lshape,
                    tuple(spec) + (None,) * (len(gshape) - len(tuple(spec)))):
                ax = _entry_axes(entry)
                if not ax:
                    idx.append(slice(None))
                    continue
                k = 0
                for a in ax:           # major-first ravel over the entry axes
                    k = k * size_of[a] + by_axis[a]
                idx.append(slice(k * loc, (k + 1) * loc))
            out.append(tuple(idx))
        return out

    def flatten_ref(self, tree, batch_dims: int = 0):
        """Global-array reference of ``flatten`` (no mesh): shard-major
        concatenation of each shard's local flat block.  The shard_map path is
        pinned bitwise against this in tests/test_fused_sharded.py."""
        leaves = jax.tree.leaves(tree)
        pre = (slice(None),) * batch_dims
        blocks = []
        for s in range(self.n_shards):
            parts = [l[pre + sl].reshape(l.shape[:batch_dims] + (-1,))
                     .astype(jnp.float32)
                     for l, sl in zip(leaves, self._shard_slices(s))]
            blocks.append(jnp.concatenate(parts, axis=-1))
        return jnp.concatenate(blocks, axis=-1)

    def unflatten_ref(self, buf, batch_dims: int = 0):
        """Inverse of ``flatten_ref``: reassemble every leaf from its shard
        blocks (replicated-in-block leaves take any block's copy — they agree
        by contract)."""
        batch = buf.shape[:batch_dims]
        nl = self.n_local
        leaves = [jnp.zeros(batch + s, jnp.float32)
                  for s in self.global_shapes]
        pre = (slice(None),) * batch_dims
        for s in range(self.n_shards):
            block = buf[..., s * nl:(s + 1) * nl]
            for i, (sl, off, sz, lshape) in enumerate(zip(
                    self._shard_slices(s), self.local.offsets,
                    self.local.sizes, self.local.shapes)):
                part = block[..., off:off + sz].reshape(batch + lshape)
                leaves[i] = leaves[i].at[pre + sl].set(part)
        return jax.tree.unflatten(self.local.treedef, leaves)

    def describe(self) -> dict:
        """JSON-able summary for BuiltStep meta / dry-run artifacts."""
        return {
            "n_shards": self.n_shards,
            "axes": list(self.axes),
            "axis_sizes": list(self.axis_sizes),
            "n_local": self.n_local,
            "n_flat": self.n_flat,
            "leaves": [
                {"path": p, "global_shape": list(g), "local_shape": list(s),
                 "size": sz, "offset": o, "split": bool(sp),
                 "uneven_fallback": bool(un)}
                for p, g, s, sz, o, sp, un in zip(
                    self.local.paths, self.global_shapes, self.local.shapes,
                    self.local.sizes, self.local.offsets, self.split,
                    self.uneven)
            ],
        }


@dataclasses.dataclass(frozen=True)
class ShardedFlatPlan:
    """Everything the engine's fused fast path needs to run per model shard:
    the mesh, the shard-local layout, and the client-axes entry for the
    leading M dim (``None`` = client-replicated plans)."""
    mesh: Any
    layout: ShardFlatLayout
    client: Any = None

    @classmethod
    def build(cls, mesh, params_one, pspecs_one, axes,
              client=None) -> "ShardedFlatPlan":
        """``params_one``/``pspecs_one`` are single-replica (no client dim)."""
        layout = ShardFlatLayout.for_tree(params_one, pspecs_one,
                                          dict(mesh.shape), tuple(axes))
        return cls(mesh=mesh, layout=layout, client=client)
