"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_map(f, *trees):
    return jax.tree.map(f, *trees)


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def tree_ones_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.ones_like(x, dtype=dtype or x.dtype), tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_sqnorm(a):
    return tree_dot(a, a)


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.leaves(
        jax.tree.map(lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b)
    )
    return all(oks)


def tree_paths(tree):
    """Flattened ('/'-joined key path, leaf) pairs."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


def tree_from_paths(tree, fn):
    """Map ``fn(path, leaf) -> new leaf`` over a tree, preserving structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        new.append(fn("/".join(keys), leaf))
    return jax.tree_util.tree_unflatten(treedef, new)
