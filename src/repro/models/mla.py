"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a per-token latent c_kv (kv_lora_rank) plus a single
shared RoPE key head (qk_rope_head_dim). The decode cache stores only
(c_kv, k_pe) — 512+64 floats/token for the full config — which is MLA's
memory win over GQA.

Two decode paths:
* ``naive``  — reconstruct K/V from the latent each step (faithful baseline).
* ``absorbed`` — fold W_uk into the query and W_uv into the output projection
  so attention runs directly in latent space (DeepSeek-V2's inference
  optimization; our beyond-paper §Perf lever for decode shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import (_dense_init, _sdpa_chunked, _sdpa_dense,
                                 apply_rope, init_rmsnorm, linear, rmsnorm,
                                 rope_cos_sin)


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)

    def hproj(k, r, nd):
        # head-major 3D (r, H, nd): the head dim is sharded explicitly; flat
        # (r, H*nd) weights lose the head sharding through the reshape and
        # the score einsum degenerates to contraction-sharding + all-reduce
        # of the full logits (measured 260 TB/device/round on deepseek-v2
        # train_4k before this layout — EXPERIMENTS §Perf).
        return {"w": jax.random.normal(k, (r, H, nd), dtype) * r ** -0.5}

    return {
        "wq_a": _dense_init(ks[0], d, m.q_lora_rank, dtype=dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "wq_b": hproj(ks[1], m.q_lora_rank, qk),
        "wkv_a": _dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                             dtype=dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "wk_b": hproj(ks[3], m.kv_lora_rank, m.qk_nope_head_dim),
        "wv_b": hproj(ks[4], m.kv_lora_rank, m.v_head_dim),
        "wo": {"w": jax.random.normal(ks[5], (H, m.v_head_dim, d), dtype)
               * (H * m.v_head_dim) ** -0.5},
    }


def _hproj(p, x, dtype):
    """x (B,S,r) @ (r,H,nd) -> (B,S,H,nd)."""
    return jnp.einsum("bsr,rhn->bshn", x.astype(dtype), p["w"].astype(dtype))


def _project_q(p, cfg, x, positions, dtype):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = _hproj(p["wq_b"], rmsnorm(p["q_norm"], linear(p["wq_a"], x, dtype),
                                  cfg.norm_eps), dtype)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_pe = q[..., m.qk_nope_head_dim:]
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin).astype(dtype)
    return q_nope, q_pe, (cos, sin)


def _latent_kv(p, cfg, x, positions, dtype):
    m = cfg.mla
    kv = linear(p["wkv_a"], x, dtype)
    c_kv = rmsnorm(p["kv_norm"], kv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_pe = kv[..., m.kv_lora_rank:][..., None, :]            # (B,S,1,rope)
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_pe = apply_rope(k_pe, cos, sin).astype(dtype)[..., 0, :]
    return c_kv, k_pe                                        # (B,S,r), (B,S,rope)


def mla_attention(p, cfg: ModelConfig, x, positions, dtype, chunk=0):
    """Full-sequence MLA (train / prefill). Returns y and the latent cache."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q_nope, q_pe, _ = _project_q(p, cfg, x, positions, dtype)
    c_kv, k_pe = _latent_kv(p, cfg, x, positions, dtype)
    k_nope = _hproj(p["wk_b"], c_kv, dtype)
    v = _hproj(p["wv_b"], c_kv, dtype)
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate([k_nope, k_pe_b], -1)
    if chunk and S > chunk:
        # pad V's head dim up to QK's so one kernel handles both
        from repro.models.flash import flash_attention_bshd
        out = flash_attention_bshd(
            q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                              (0, q.shape[-1] - v.shape[-1]))),
            positions, positions, bq=chunk, bk=chunk)
        out = out[..., : m.v_head_dim]
    else:
        out = _sdpa_dense(q, k, v, positions, positions, 0, 0.0)
    y = jnp.einsum("bshv,hvd->bsd", out.astype(dtype),
                   p["wo"]["w"].astype(dtype))
    return y, (c_kv, k_pe)


def mla_decode(p, cfg: ModelConfig, x, pos, ckv_cache, kpe_cache, dtype,
               absorbed=True):
    """Decode one token against the latent cache.

    ckv_cache (B,C,r), kpe_cache (B,C,rope); slot = pos (no ring buffer —
    MLA archs are full-attention, long_500k is skipped for them).

    ``pos`` is a scalar int32, or a (B,) int32 vector of per-slot positions
    (continuous batching).
    """
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    C = ckv_cache.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)
    if jnp.ndim(pos) == 0:
        posv = jnp.full((1,), pos, jnp.int32)
        q_nope, q_pe, _ = _project_q(p, cfg, x, posv, dtype)  # (B,1,H,*)
        c_kv, k_pe = _latent_kv(p, cfg, x, posv, dtype)
        ckv_cache = jax.lax.dynamic_update_slice(
            ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
        kpe_cache = jax.lax.dynamic_update_slice(
            kpe_cache, k_pe.astype(kpe_cache.dtype), (0, pos, 0))
        valid = idx <= pos                               # (C,)
        vmask = valid[None, None, None]
        q_pos = posv
    else:
        posb = pos.astype(jnp.int32)                     # (B,)
        posv = posb[:, None]                             # (B,1)
        q_nope, q_pe, _ = _project_q(p, cfg, x, posv, dtype)
        c_kv, k_pe = _latent_kv(p, cfg, x, posv, dtype)
        barange = jnp.arange(B)
        ckv_cache = ckv_cache.at[barange, posb].set(
            c_kv[:, 0].astype(ckv_cache.dtype))
        kpe_cache = kpe_cache.at[barange, posb].set(
            k_pe[:, 0].astype(kpe_cache.dtype))
        valid = idx[None, :] <= posb[:, None]            # (B,C)
        vmask = valid[:, None, None, :]
        q_pos = posv
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if absorbed:
        # q_lat[h] = q_nope[h] @ W_uk[h]^T : attention in latent space
        wk = p["wk_b"]["w"].astype(jnp.float32)      # (r, H, nope)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), wk)
        logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                             ckv_cache.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", q_pe.astype(jnp.float32),
                               kpe_cache.astype(jnp.float32))) * scale
        logits = jnp.where(vmask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", w, ckv_cache.astype(jnp.float32))
        wv = p["wv_b"]["w"].astype(jnp.float32)      # (r, H, v)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv)
    else:
        k_nope = _hproj(p["wk_b"], ckv_cache.astype(dtype), dtype)
        v = _hproj(p["wv_b"], ckv_cache.astype(dtype), dtype)
        kpe_b = jnp.broadcast_to(kpe_cache[:, :, None, :].astype(dtype),
                                 (B, C, H, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_pe], -1)
        k = jnp.concatenate([k_nope, kpe_b], -1)
        k_pos = jnp.where(valid, jnp.broadcast_to(idx, valid.shape),
                          jnp.iinfo(jnp.int32).max)
        out = _sdpa_dense(q, k, v, q_pos, k_pos, 0, 0.0, k_valid=valid)

    y = jnp.einsum("bshv,hvd->bsd", out.astype(dtype),
                   p["wo"]["w"].astype(dtype))
    return y, ckv_cache, kpe_cache
