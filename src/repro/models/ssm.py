"""Mamba2 — State Space Duality (SSD) blocks (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like, MXU-friendly) term + inter-chunk linear state recurrence via
``lax.scan`` over chunks. Decode carries a constant-size recurrent state
(B, H, P, N) plus depthwise-conv tails — O(1) per token regardless of context
length, which is why the ssm/hybrid archs run ``long_500k``.

TPU adaptation notes (vs. the CUDA kernels of the paper): chunked einsums are
shaped (chunk × head_dim/state) so the MXU sees >=128-sized contractions; the
inter-chunk recurrence stays a scan (sequential over S/chunk steps, trivially
cheap). A Pallas kernel for the fused intra-chunk term lives in
kernels/ssd_scan.py; this module is the pure-JAX reference path used by
default (identical math).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import _dense_init, init_rmsnorm, linear, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    return s, d_in, nheads


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32):
    s, d_in, nh = _dims(cfg)
    gn = s.ngroups * s.d_state
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    return {
        "wx": _dense_init(ks[0], d, d_in, dtype=dtype),
        "wz": _dense_init(ks[1], d, d_in, dtype=dtype),
        "wB": _dense_init(ks[2], d, gn, dtype=dtype),
        "wC": _dense_init(ks[3], d, gn, dtype=dtype),
        "wdt": _dense_init(ks[4], d, nh, dtype=dtype),
        "conv_x": jax.random.normal(ks[5], (d_in, s.d_conv), dtype) * 0.1,
        "conv_B": jax.random.normal(ks[6], (gn, s.d_conv), dtype) * 0.1,
        "conv_C": jax.random.normal(ks[7], (gn, s.d_conv), dtype) * 0.1,
        "dt_bias": jnp.zeros((nh,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=dtype)),
        "Dskip": jnp.ones((nh,), dtype),
        "gate_norm": init_rmsnorm(d_in, dtype),
        "wo": _dense_init(ks[8], d_in, d, dtype=dtype),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (C,K) -> (B,S,C)."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum_k x[t-K+1+k] * w[:,k]
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + xp[:, k: k + x.shape[1], :] * w[None, None, :, k]
    return out


def _segsum_exp(cum):
    """cum (..., Q) cumulative dA -> L (..., Q, Q); L[i,j]=exp(cum_i-cum_j), i>=j.

    Mask BEFORE exp: upper-triangle diffs are positive and can overflow to
    inf, which poisons the backward of where (0·inf = NaN in the exp VJP).
    """
    Q = cum.shape[-1]
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.exp(jnp.where(mask, diff, -1e30)) * mask


def ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0=None):
    """Chunked SSD scan.

    xh (B,S,H,P) input heads; dt (B,S,H) >0; A (H,) <0;
    Bm/Cm (B,S,H,N) per-head (groups pre-broadcast). Returns (y (B,S,H,P),
    h_final (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    f32 = jnp.float32
    dA = (dt.astype(f32) * A.astype(f32)[None, None, :])          # (B,S,H)
    xdt = xh.astype(f32) * dt.astype(f32)[..., None]              # (B,S,H,P)

    def r(t, last=None):
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    dA_c, xdt_c = r(dA), r(xdt)
    B_c, C_c = r(Bm.astype(f32)), r(Cm.astype(f32))
    cum = jnp.cumsum(dA_c, axis=2)                                # (B,nc,Q,H)

    # intra-chunk (quadratic, MXU): Y[i] = sum_{j<=i} C_i·B_j L_ij x_j dt_j
    L = _segsum_exp(cum.transpose(0, 1, 3, 2))                    # (B,nc,H,Q,Q)
    G = jnp.einsum("bcihn,bcjhn->bchij", C_c, B_c)                # (B,nc,H,Q,Q)
    Y_diag = jnp.einsum("bchij,bchij,bcjhp->bcihp", G, L, xdt_c)

    # end-of-chunk states
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,Q,H)
    S_c = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", B_c, decay_out, xdt_c)
    total = jnp.exp(cum[:, :, -1, :])                             # (B,nc,H)

    def step(h, xs):
        s_c, tot = xs
        h_next = tot[..., None, None] * h + s_c
        return h_next, h                                          # emit pre-update

    h0 = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_fin, h_prevs = jax.lax.scan(step, h0, (S_c.swapaxes(0, 1),
                                             total.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                              # (B,nc,H,P,N)

    decay_in = jnp.exp(cum)                                       # (B,nc,Q,H)
    Y_off = jnp.einsum("bcihn,bcih,bchpn->bcihp", C_c, decay_in, h_prevs)
    y = (Y_diag + Y_off).reshape(Bsz, S, H, P)
    return y, h_fin


def _conv_tail(x, K):
    """Last K-1 causal-conv inputs (left zero-padded when S < K-1): the conv
    state a decode step starting at pos = S expects."""
    S = x.shape[1]
    if S >= K - 1:
        return x[:, S - (K - 1):, :]
    return jnp.pad(x, ((0, 0), (K - 1 - S, 0), (0, 0)))


def mamba2_forward(p, cfg: ModelConfig, u, dtype, h0=None, return_state=False,
                   return_cache=False):
    """u (B,S,d) -> (B,S,d). Full-sequence (train / prefill).

    ``return_cache=True`` additionally returns a decode cache (same pytree as
    ``mamba2_init_cache``) positioned after the last token: the final SSD
    state plus the depthwise-conv input tails — what serving needs to continue
    decoding at pos = S without replaying the prompt.
    """
    s, d_in, nh = _dims(cfg)
    Bsz, S, _ = u.shape
    x_pre = linear(p["wx"], u, dtype)
    B_pre = linear(p["wB"], u, dtype)
    C_pre = linear(p["wC"], u, dtype)
    x = _causal_conv(x_pre, p["conv_x"].astype(dtype))
    Bm = _causal_conv(B_pre, p["conv_B"].astype(dtype))
    Cm = _causal_conv(C_pre, p["conv_C"].astype(dtype))
    x, Bm, Cm = jax.nn.silu(x), jax.nn.silu(Bm), jax.nn.silu(Cm)
    z = linear(p["wz"], u, dtype)
    dt = jax.nn.softplus(linear(p["wdt"], u, jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = x.reshape(Bsz, S, nh, s.head_dim)
    rep = nh // s.ngroups
    Bh = jnp.repeat(Bm.reshape(Bsz, S, s.ngroups, s.d_state), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(Bsz, S, s.ngroups, s.d_state), rep, axis=2)

    y, h_fin = ssd_chunked(xh, dt, A, Bh, Ch, min(s.chunk, S), h0=h0)
    y = y + p["Dskip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["wo"], y, dtype)
    if return_cache:
        K = s.d_conv
        cache = {"h": h_fin, "conv_x": _conv_tail(x_pre, K),
                 "conv_B": _conv_tail(B_pre, K), "conv_C": _conv_tail(C_pre, K)}
        return out, cache
    if return_state:
        return out, h_fin
    return out


def mamba2_init_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    s, d_in, nh = _dims(cfg)
    gn = s.ngroups * s.d_state
    # conv tails stay fp32 like h: _conv_step promotes the rolled window to
    # fp32 anyway, and the cache dtype must be a fixed point of the decode
    # step (the continuous-batching slot insert requires leaf dtypes to
    # round-trip). K-1 rows per layer — negligible memory.
    return {
        "h": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), jnp.float32),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, gn), jnp.float32),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, gn), jnp.float32),
    }


def _conv_step(state, xt, w):
    """state (B,K-1,C), xt (B,C), w (C,K) -> (out (B,C), new_state)."""
    window = jnp.concatenate([state, xt[:, None, :]], axis=1)     # (B,K,C)
    out = jnp.einsum("bkc,ck->bc", window, w)
    return out, window[:, 1:, :]


def mamba2_decode(p, cfg: ModelConfig, u, cache, dtype):
    """u (B,1,d) -> (B,1,d); O(1) state update."""
    s, d_in, nh = _dims(cfg)
    Bsz = u.shape[0]
    ut = u[:, 0, :]
    x_t = linear(p["wx"], ut, dtype)
    B_t = linear(p["wB"], ut, dtype)
    C_t = linear(p["wC"], ut, dtype)
    x_t, cx = _conv_step(cache["conv_x"], x_t, p["conv_x"].astype(dtype))
    B_t, cb = _conv_step(cache["conv_B"], B_t, p["conv_B"].astype(dtype))
    C_t, cc = _conv_step(cache["conv_C"], C_t, p["conv_C"].astype(dtype))
    x_t, B_t, C_t = jax.nn.silu(x_t), jax.nn.silu(B_t), jax.nn.silu(C_t)
    z = linear(p["wz"], ut, dtype)
    dt = jax.nn.softplus(linear(p["wdt"], ut, jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = x_t.reshape(Bsz, nh, s.head_dim).astype(jnp.float32)
    rep = nh // s.ngroups
    Bh = jnp.repeat(B_t.reshape(Bsz, s.ngroups, s.d_state), rep, 1).astype(jnp.float32)
    Ch = jnp.repeat(C_t.reshape(Bsz, s.ngroups, s.d_state), rep, 1).astype(jnp.float32)

    dA = jnp.exp(dt * A[None, :])                                 # (B,H)
    h = cache["h"] * dA[..., None, None] \
        + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) \
        + p["Dskip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, d_in).astype(dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["wo"], y, dtype)[:, None, :]
    new_cache = {"h": h, "conv_x": cx, "conv_B": cb, "conv_C": cc}
    return out, new_cache


def ssd_reference(xh, dt, A, Bm, Cm):
    """Naive sequential SSD (oracle for tests): O(S) python-free scan."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, xs):
        x_t, dt_t, B_t, C_t = xs
        dA = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])   # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt_t.astype(f32), x_t.astype(f32),
            B_t.astype(f32))
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t.astype(f32))
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), f32)
    xs = (xh.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1),
          Cm.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_fin
