"""Decoder stacks for all assigned architecture families.

All stacks scan over layers (stacked parameters with a leading L dim) so HLO
size — and dry-run compile time — is independent of depth. Per-layer
structural variation (gemma3 local/global windows, zamba2's shared attention
block every k layers) flows through the scan as per-layer scalars, not
separate code paths.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import layers as Lyr
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import AttnCall, init_rmsnorm, mlp, rmsnorm

HUGE_WINDOW = jnp.int32(2**30)


# --------------------------------------------------------------------------- #
# per-layer init
# --------------------------------------------------------------------------- #


def _init_block(key, cfg: ModelConfig, dtype):
    """One decoder block (uniform structure within a stack)."""
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"norm1": init_rmsnorm(d, dtype), "norm2": init_rmsnorm(d, dtype)}
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        p["mamba"] = SSM.init_mamba2(ks[0], cfg, dtype)
        del p["norm2"]  # mamba block: single pre-norm
        return p
    if cfg.mla:
        p["attn"] = MLA.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = Lyr.init_attention(ks[0], cfg, dtype)
    if cfg.family == "moe":
        p["ffn"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = Lyr.init_mlp(ks[1], d, cfg.d_ff, dtype)
    return p


def _init_dense_block(key, cfg: ModelConfig, dtype, d_ff):
    """Dense-FFN block used for a MoE model's dense prefix layers."""
    ks = jax.random.split(key, 2)
    p = {"norm1": init_rmsnorm(cfg.d_model, dtype),
         "norm2": init_rmsnorm(cfg.d_model, dtype)}
    p["attn"] = MLA.init_mla(ks[0], cfg, dtype) if cfg.mla \
        else Lyr.init_attention(ks[0], cfg, dtype)
    p["ffn"] = Lyr.init_mlp(ks[1], cfg.d_model, d_ff, dtype)
    return p


def _init_shared_block(key, cfg: ModelConfig, dtype):
    """zamba2: the single weight-tied attention+MLP block."""
    ks = jax.random.split(key, 2)
    return {
        "norm1": init_rmsnorm(cfg.d_model, dtype),
        "attn": Lyr.init_attention(ks[0], cfg, dtype),
        "norm2": init_rmsnorm(cfg.d_model, dtype),
        "ffn": Lyr.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def init_stack(key, cfg: ModelConfig, dtype=jnp.float32):
    """All transformer-stack params: scanned stack + unscanned extras."""
    L = cfg.n_layers
    n_prefix = cfg.moe.moe_layer_start if (cfg.moe and cfg.moe.moe_layer_start) else 0
    ks = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: _init_block(k, cfg, dtype))(
        jax.random.split(ks[0], L - n_prefix))
    p = {"stack": stacked}
    if n_prefix:
        d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        p["prefix"] = [
            _init_dense_block(jax.random.fold_in(ks[1], i), cfg, dtype, d_ff)
            for i in range(n_prefix)
        ]
    if cfg.hybrid_attn_every:
        p["shared"] = _init_shared_block(ks[2], cfg, dtype)
    return p


# --------------------------------------------------------------------------- #
# per-layer window schedule (gemma3 5:1)
# --------------------------------------------------------------------------- #


def layer_windows(cfg: ModelConfig, n_layers: int, force_window: int = 0):
    """int32 (L,) per-layer window; HUGE_WINDOW means global."""
    if force_window:
        return jnp.full((n_layers,), force_window, jnp.int32)
    if not cfg.sliding_window:
        return jnp.full((n_layers,), HUGE_WINDOW, jnp.int32)
    if not cfg.local_global_ratio:
        return jnp.full((n_layers,), cfg.sliding_window, jnp.int32)
    r = cfg.local_global_ratio
    i = jnp.arange(n_layers)
    is_global = (i % (r + 1)) == r
    return jnp.where(is_global, HUGE_WINDOW, cfg.sliding_window).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------- #


def _block_fwd(bp, cfg, x, positions, window, call: AttnCall, dtype,
               want_cache):
    """One uniform block. Returns (x, cache_leaf, aux)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm" or cfg.family == "hybrid":
        h_in = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if want_cache:
            h, mc = SSM.mamba2_forward(bp["mamba"], cfg, h_in, dtype,
                                       return_cache=True)
            return x + h, mc, aux
        h = SSM.mamba2_forward(bp["mamba"], cfg, h_in, dtype)
        return x + h, None, aux
    h_in = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if cfg.mla:
        h, kv = MLA.mla_attention(bp["attn"], cfg, h_in, positions, dtype,
                                  chunk=call.chunk)
    else:
        c = AttnCall(window=window, softcap=call.softcap, chunk=call.chunk,
                     use_flash_kernel=call.use_flash_kernel)
        h, kv = Lyr.attention(bp["attn"], cfg, h_in, positions, c, dtype)
    x = x + h
    f_in = rmsnorm(bp["norm2"], x, cfg.norm_eps)
    if cfg.family == "moe" and "router" in bp["ffn"]:
        f, aux = MOE.moe_apply(bp["ffn"], cfg, f_in, cfg.act, dtype,
                               no_drop=getattr(call, "exact_moe", False),
                               shard=getattr(call, "moe_shard", None))
    else:
        f = mlp(bp["ffn"], f_in, cfg.act, dtype)
    x = x + f
    cache = kv if want_cache else None
    return x, cache, aux


def forward(params, cfg: ModelConfig, x, positions, call: AttnCall, dtype,
            want_cache=False, remat=True):
    """x (B,S,d) residual stream -> (y, caches, aux_loss_sum).

    caches: dict with stacked per-layer KV (attention archs), per-layer mamba
    states (ssm/hybrid) and shared-block KV (hybrid), as applicable.
    """
    L = cfg.n_layers
    n_prefix = cfg.moe.moe_layer_start if (cfg.moe and cfg.moe.moe_layer_start) else 0
    caches = {}
    aux_total = jnp.float32(0.0)

    for i, bp in enumerate(params.get("prefix", [])):
        x, kv, aux = _block_fwd(bp, cfg, x, positions,
                                HUGE_WINDOW, call, dtype, want_cache)
        aux_total += aux
        if want_cache:
            caches[f"prefix{i}"] = kv

    wins = layer_windows(cfg, L - n_prefix, force_window=call.force_window
                         if hasattr(call, "force_window") else 0)
    every = cfg.hybrid_attn_every
    shared = params.get("shared")

    def layer(carry, xs):
        x, aux_t = carry
        bp, win, idx = xs
        x, kv, aux = _block_fwd(bp, cfg, x, positions, win, call, dtype,
                                want_cache)
        if every:
            def with_attn(x):
                h = rmsnorm(shared["norm1"], x, cfg.norm_eps)
                c = AttnCall(window=win, softcap=call.softcap, chunk=call.chunk)
                h, skv = Lyr.attention(shared["attn"], cfg, h, positions, c, dtype)
                x = x + h
                f = mlp(shared["ffn"], rmsnorm(shared["norm2"], x, cfg.norm_eps),
                        cfg.act, dtype)
                return x + f, skv

            def no_attn(x):
                hk, hd = cfg.n_kv_heads, cfg.head_dim
                z = jnp.zeros(x.shape[:2] + (hk, hd), dtype)
                return x, (z, z)

            x, skv = jax.lax.cond((idx % every) == (every - 1), with_attn,
                                  no_attn, x)
            # hybrid caches both the mamba states and the shared-block KV
            kv = {"mamba": kv, "skv": skv} if want_cache else None
        ys = kv if want_cache else None
        return (x, aux_t + aux), ys

    layer_fn = jax.checkpoint(layer) if remat else layer
    xs = (params["stack"], wins, jnp.arange(L - n_prefix))
    (x, aux_total), stack_kv = jax.lax.scan(layer_fn, (x, aux_total), xs)
    if want_cache:
        caches["stack"] = stack_kv
    return x, caches, aux_total


# --------------------------------------------------------------------------- #
# decode (one token, cache carried)
# --------------------------------------------------------------------------- #


def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16):
    """Abstract-safe cache construction for serve_step."""
    L = cfg.n_layers
    n_prefix = cfg.moe.moe_layer_start if (cfg.moe and cfg.moe.moe_layer_start) else 0
    Ls = L - n_prefix
    c = {}
    if cfg.family in ("ssm", "hybrid"):
        c["mamba"] = jax.vmap(lambda _: SSM.mamba2_init_cache(cfg, batch, dtype)
                              )(jnp.arange(Ls))
        if cfg.hybrid_attn_every:
            napp = Ls // cfg.hybrid_attn_every
            hk, hd = cfg.n_kv_heads, cfg.head_dim
            c["shared_k"] = jnp.zeros((napp, batch, cache_len, hk, hd), dtype)
            c["shared_v"] = jnp.zeros((napp, batch, cache_len, hk, hd), dtype)
        return c
    if cfg.mla:
        m = cfg.mla
        c["ckv"] = jnp.zeros((Ls, batch, cache_len, m.kv_lora_rank), dtype)
        c["kpe"] = jnp.zeros((Ls, batch, cache_len, m.qk_rope_head_dim), dtype)
        if n_prefix:
            c["p_ckv"] = jnp.zeros((n_prefix, batch, cache_len, m.kv_lora_rank), dtype)
            c["p_kpe"] = jnp.zeros((n_prefix, batch, cache_len,
                                    m.qk_rope_head_dim), dtype)
        return c
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    c["k"] = jnp.zeros((Ls, batch, cache_len, hk, hd), dtype)
    c["v"] = jnp.zeros((Ls, batch, cache_len, hk, hd), dtype)
    if n_prefix:
        c["pk"] = jnp.zeros((n_prefix, batch, cache_len, hk, hd), dtype)
        c["pv"] = jnp.zeros((n_prefix, batch, cache_len, hk, hd), dtype)
    return c


def _ring_place(src, C, S, axis):
    """Place a length-S sequence axis into a C-slot ring at slot = pos % C.

    Keeps the last min(S, C) positions (the only ones a windowed decode can
    ever attend to) so decode at pos = S reconstructs k_pos exactly like a
    cache that was filled token-by-token. S and C are static Python ints.
    """
    if S <= C:
        pad = [(0, 0)] * src.ndim
        pad[axis] = (0, C - S)
        return jnp.pad(src, pad)
    # slot c holds the unique position p in [S-C, S) with p % C == c
    c = np.arange(C)
    p = (S - C) + ((c - (S - C)) % C)
    return jnp.take(src, p, axis=axis)


def prefill_to_decode_cache(cfg: ModelConfig, caches, prompt_len: int, cache):
    """Convert ``forward(want_cache=True)`` caches into the decode layout.

    ``cache`` is a fresh ``init_decode_cache`` pytree whose leaves fix the
    target shapes/dtypes (including the ring size C when decode_window is
    on); the populated copy is returned, ready for decode at pos = prompt_len.
    """
    S = prompt_len
    new = dict(cache)

    if cfg.family in ("ssm", "hybrid"):
        st = caches["stack"]
        mc = st["mamba"] if cfg.hybrid_attn_every else st
        new["mamba"] = jax.tree.map(lambda t, s: s.astype(t.dtype),
                                    cache["mamba"], mc)
        if cfg.hybrid_attn_every:
            every = cfg.hybrid_attn_every
            Ls = st["skv"][0].shape[0]
            sel = np.arange(every - 1, Ls, every)    # layers that run shared attn
            C = cache["shared_k"].shape[2]
            new["shared_k"] = _ring_place(st["skv"][0][sel], C, S, axis=2) \
                .astype(cache["shared_k"].dtype)
            new["shared_v"] = _ring_place(st["skv"][1][sel], C, S, axis=2) \
                .astype(cache["shared_v"].dtype)
        return new

    if cfg.mla:
        C = cache["ckv"].shape[2]
        assert S <= C, "MLA decode cache is not a ring buffer"
        ck, kp = caches["stack"]                     # (Ls,B,S,r) / (Ls,B,S,rope)
        new["ckv"] = _ring_place(ck, C, S, axis=2).astype(cache["ckv"].dtype)
        new["kpe"] = _ring_place(kp, C, S, axis=2).astype(cache["kpe"].dtype)
        if "p_ckv" in cache:
            n_prefix = cache["p_ckv"].shape[0]
            pc = jnp.stack([caches[f"prefix{i}"][0] for i in range(n_prefix)])
            pk = jnp.stack([caches[f"prefix{i}"][1] for i in range(n_prefix)])
            new["p_ckv"] = _ring_place(pc, C, S, axis=2).astype(cache["p_ckv"].dtype)
            new["p_kpe"] = _ring_place(pk, C, S, axis=2).astype(cache["p_kpe"].dtype)
        return new

    C = cache["k"].shape[2]
    k, v = caches["stack"]                           # (Ls,B,S,hk,hd)
    new["k"] = _ring_place(k, C, S, axis=2).astype(cache["k"].dtype)
    new["v"] = _ring_place(v, C, S, axis=2).astype(cache["v"].dtype)
    if "pk" in cache:
        n_prefix = cache["pk"].shape[0]
        pk = jnp.stack([caches[f"prefix{i}"][0] for i in range(n_prefix)])
        pv = jnp.stack([caches[f"prefix{i}"][1] for i in range(n_prefix)])
        new["pk"] = _ring_place(pk, C, S, axis=2).astype(cache["pk"].dtype)
        new["pv"] = _ring_place(pv, C, S, axis=2).astype(cache["pv"].dtype)
    return new


def decode(params, cfg: ModelConfig, x, pos, cache, call: AttnCall, dtype,
           mla_absorbed=True):
    """x (B,1,d), pos scalar int32 or (B,) per-slot vector
    -> (y (B,1,d), new cache)."""
    L = cfg.n_layers
    n_prefix = cfg.moe.moe_layer_start if (cfg.moe and cfg.moe.moe_layer_start) else 0
    Ls = L - n_prefix
    new_cache = dict(cache)

    # ---- dense prefix layers (unscanned) -------------------------------------
    for i, bp in enumerate(params.get("prefix", [])):
        h_in = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if cfg.mla:
            h, ck, kp = MLA.mla_decode(bp["attn"], cfg, h_in, pos,
                                       cache["p_ckv"][i], cache["p_kpe"][i],
                                       dtype, absorbed=mla_absorbed)
            new_cache["p_ckv"] = new_cache["p_ckv"].at[i].set(ck)
            new_cache["p_kpe"] = new_cache["p_kpe"].at[i].set(kp)
        else:
            c = AttnCall(window=call.window, softcap=call.softcap)
            h, kc, vc = Lyr.attention_decode(bp["attn"], cfg, h_in, pos,
                                             cache["pk"][i], cache["pv"][i],
                                             c, dtype)
            new_cache["pk"] = new_cache["pk"].at[i].set(kc)
            new_cache["pv"] = new_cache["pv"].at[i].set(vc)
        x = x + h
        x = x + mlp(bp["ffn"], rmsnorm(bp["norm2"], x, cfg.norm_eps), cfg.act,
                    dtype)

    wins = layer_windows(cfg, Ls, force_window=getattr(call, "force_window", 0))
    every = cfg.hybrid_attn_every
    shared = params.get("shared")

    def layer(carry, xs):
        x, lcache = carry
        if cfg.family in ("ssm", "hybrid"):
            bp, win, idx, mcache = xs
            h_in = rmsnorm(bp["norm1"], x, cfg.norm_eps)
            h, mnew = SSM.mamba2_decode(bp["mamba"], cfg, h_in, mcache, dtype)
            x = x + h
            if every:
                def with_attn(args):
                    x, sk, sv = args
                    app = idx // every
                    kc = jax.lax.dynamic_index_in_dim(sk, app, 0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(sv, app, 0, keepdims=False)
                    h_in = rmsnorm(shared["norm1"], x, cfg.norm_eps)
                    c = AttnCall(window=win, softcap=call.softcap)
                    h, kc, vc = Lyr.attention_decode(shared["attn"], cfg, h_in,
                                                     pos, kc, vc, c, dtype)
                    x = x + h
                    x = x + mlp(shared["ffn"],
                                rmsnorm(shared["norm2"], x, cfg.norm_eps),
                                cfg.act, dtype)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, kc, app, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, vc, app, 0)
                    return x, sk, sv

                sk, sv = lcache
                x, sk, sv = jax.lax.cond((idx % every) == (every - 1),
                                         with_attn, lambda a: a, (x, sk, sv))
                lcache = (sk, sv)
            return (x, lcache), mnew
        # attention families
        bp, win, idx, kv = xs
        h_in = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if cfg.mla:
            ck, kp = kv
            h, ck, kp = MLA.mla_decode(bp["attn"], cfg, h_in, pos, ck, kp,
                                       dtype, absorbed=mla_absorbed)
            newkv = (ck, kp)
        else:
            kc, vc = kv
            c = AttnCall(window=win, softcap=call.softcap)
            h, kc, vc = Lyr.attention_decode(bp["attn"], cfg, h_in, pos, kc, vc,
                                             c, dtype)
            newkv = (kc, vc)
        x = x + h
        f_in = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if cfg.family == "moe" and "router" in bp["ffn"]:
            f, _ = MOE.moe_apply(bp["ffn"], cfg, f_in, cfg.act, dtype,
                                 no_drop=getattr(call, "exact_moe", False),
                                 shard=getattr(call, "moe_shard", None))
        else:
            f = mlp(bp["ffn"], f_in, cfg.act, dtype)
        return (x + f, lcache), newkv

    idxs = jnp.arange(Ls)
    if cfg.family in ("ssm", "hybrid"):
        lcache = ((cache["shared_k"], cache["shared_v"])
                  if every else (jnp.zeros((), dtype), jnp.zeros((), dtype)))
        xs = (params["stack"], wins, idxs, cache["mamba"])
        (x, lcache), mnew = jax.lax.scan(layer, (x, lcache), xs)
        new_cache["mamba"] = mnew
        if every:
            new_cache["shared_k"], new_cache["shared_v"] = lcache
    elif cfg.mla:
        xs = (params["stack"], wins, idxs, (cache["ckv"], cache["kpe"]))
        (x, _), (ck, kp) = jax.lax.scan(layer, (x, None), xs)
        new_cache["ckv"], new_cache["kpe"] = ck, kp
    else:
        xs = (params["stack"], wins, idxs, (cache["k"], cache["v"]))
        (x, _), (kc, vc) = jax.lax.scan(layer, (x, None), xs)
        new_cache["k"], new_cache["v"] = kc, vc
    return x, new_cache
