"""Core transformer layers — functional, params as plain dict pytrees.

Conventions
-----------
* ``init_*`` functions return a params dict; ``*_apply`` functions consume it.
* Parameters are stored in ``param_dtype`` (default fp32); compute happens in
  ``dtype`` (default bf16) — weights are cast at use.
* Attention supports GQA, qk-norm, QKV bias, sliding windows, logit softcap,
  dense or KV-chunked (online-softmax) evaluation, and single-token decode
  against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig

# --------------------------------------------------------------------------- #
# initializers / basics
# --------------------------------------------------------------------------- #


def _dense_init(key, d_in, d_out, bias=False, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, dtype):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #


def rope_cos_sin(positions, head_dim, theta):
    """positions (...,) int32 -> cos/sin of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    """QKV/O projections stored head-major 3D — (d, H, hd) / (H, hd, d).

    Head-major weights let the partitioner shard the *head* dim explicitly;
    flat (d, H·hd) weights force GSPMD to propagate sharding through a reshape
    whose split does not align with head boundaries when H or Hk is not a
    multiple of the model axis, which degenerates into contraction-dim
    sharding + an all-reduce of the full S×S attention logits (measured: 7.5
    GB/layer/step on qwen2-0.5b before this layout).
    """
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)

    def proj(k, nheads):
        w = jax.random.normal(k, (d, nheads, hd), dtype) * d ** -0.5
        p = {"w": w}
        if cfg.qkv_bias:
            p["b"] = jnp.zeros((nheads, hd), dtype)
        return p

    p = {
        "wq": proj(ks[0], h),
        "wk": proj(ks[1], hk),
        "wv": proj(ks[2], hk),
        "wo": {"w": jax.random.normal(ks[3], (h, hd, d), dtype)
               * (h * hd) ** -0.5},
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _proj_heads(p, x, dtype):
    """x (B,S,d) @ (d,H,hd) -> (B,S,H,hd)."""
    y = jnp.einsum("bsd,dhk->bshk", x.astype(dtype), p["w"].astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)[None, None]
    return y


def _proj_out(p, x, dtype):
    """x (B,S,H,hd) @ (H,hd,d) -> (B,S,d)."""
    return jnp.einsum("bshk,hkd->bsd", x.astype(dtype), p["w"].astype(dtype))


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def _mask_bias(q_pos, k_pos, window, k_valid=None):
    """Additive fp32 mask bias: causal + optional sliding window + validity.

    ``window`` may be: None / 0 (full attention, static), a positive Python int
    (static sliding window), or a traced int32 scalar (per-layer window inside a
    layer scan — gemma3's 5:1 local:global pattern; global layers pass a huge
    value).

    ``q_pos``/``k_pos`` are (Sq,)/(Sk,) for a shared position grid, or carry
    leading batch dims — (B,Sq)/(B,Sk) for per-slot decode positions in the
    continuous-batching ring — giving a (B,Sq,Sk) bias.
    """
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if _window_on(window):
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _window_on(window) -> bool:
    if window is None:
        return False
    if isinstance(window, int):
        return window > 0
    return True  # traced scalar: always apply (global layers use a huge value)


def _sdpa_dense(q, k, v, q_pos, k_pos, window, softcap, k_valid=None):
    """q (B,Sq,H,D), k/v (B,Sk,Hk,D) -> (B,Sq,H,D).  fp32 softmax.

    Positions are (Sq,)/(Sk,) shared across the batch, or (B,Sq)/(B,Sk) for
    per-slot decode positions (continuous batching).
    """
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, Hk, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kf)
    logits = _softcap(logits, softcap)
    bias = _mask_bias(q_pos, k_pos, window, k_valid)
    # (Sq,Sk) -> (1,1,Sq,Sk) broadcast; (B,Sq,Sk) -> (B,1,1,Sq,Sk)
    logits = logits + bias[..., None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, vf)
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim may differ (MLA)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, softcap, chunk):
    """Online-softmax attention, scanning over KV chunks (bounded memory).

    Differentiable (pure lax.scan); fp32 running (m, l, acc) accumulators.
    """
    B, Sq, H, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    assert Sk % chunk == 0, (Sk, chunk)
    nC = Sk // chunk
    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, Sq, Hk, rep, D)
    kc = k.reshape(B, nC, chunk, Hk, D).swapaxes(0, 1)
    vc = v.reshape(B, nC, chunk, Hk, D).swapaxes(0, 1)
    kp = k_pos.reshape(nC, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, kpb = xs
        logits = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kb.astype(jnp.float32))
        logits = _softcap(logits, softcap)
        logits = logits + _mask_bias(q_pos, kpb, window)[None, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hk, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hk, rep, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kp))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


@dataclasses.dataclass
class AttnCall:
    """Runtime knobs for an attention call (not parameters).

    ``window`` may be a Python int (0 = full attention) or a traced int32
    scalar (per-layer windows inside a layer scan). ``force_window`` overrides
    every layer's window (long_500k decode on hybrid/windowed archs).
    """
    window: object = 0
    softcap: float = 0.0
    chunk: int = 0            # 0 = dense; else KV-chunked online softmax
    use_flash_kernel: bool = False  # route through the Pallas kernel (TPU)
    use_decode_kernel: bool = False  # fused single-query decode (kernels/)
    force_window: int = 0
    exact_moe: bool = False   # capacity = N*K (no token drops); tests only
    moe_shard: object = None  # sharding-constraint hook for MoE buffers


def attention(p, cfg: ModelConfig, x, positions, call: AttnCall, dtype):
    """Full self-attention over x (B,S,d) at integer positions (S,).

    KV is repeated to the full head count before the score einsums so every
    attention tensor is sharded on the (explicit, divisible) head dim — the
    Megatron TP pattern: the only model-axis collective is the psum after the
    output projection. The decode cache still stores the compact Hk heads.
    """
    B, S, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj_heads(p["wq"], x, dtype)
    k = _proj_heads(p["wk"], x, dtype)
    v = _proj_heads(p["wv"], x, dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin).astype(dtype)
    k = apply_rope(k, cos, sin).astype(dtype)
    cache_kv = (k, v)
    rep = h // hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if call.use_flash_kernel and not _window_on(call.window):
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, softcap=call.softcap)
    elif call.chunk and S > call.chunk:
        from repro.models.flash import flash_attention_bshd
        win = None if not _window_on(call.window) else call.window
        out = flash_attention_bshd(q, k, v, positions, positions, window=win,
                                   softcap=call.softcap, bq=call.chunk,
                                   bk=call.chunk)
    else:
        out = _sdpa_dense(q, k, v, positions, positions, call.window, call.softcap)
    return _proj_out(p["wo"], out.astype(dtype), dtype), cache_kv


def attention_decode(p, cfg: ModelConfig, x, pos, kcache, vcache, call: AttnCall,
                     dtype):
    """Decode one token: x (B,1,d); cache (B,C,Hk,D).

    ``pos`` is a scalar int32 (one shared position — the classic batched-serve
    path) or a (B,) int32 vector of per-slot positions (continuous batching:
    every slot of the ring is at its own depth in its own sequence).

    The cache may be a ring buffer (C == window) — slot = pos % C; key positions
    are reconstructed so causal/window masking stays correct.
    """
    B = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    C = kcache.shape[1]
    q = _proj_heads(p["wq"], x, dtype)
    k = _proj_heads(p["wk"], x, dtype)
    v = _proj_heads(p["wv"], x, dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    idx = jnp.arange(C, dtype=jnp.int32)
    if jnp.ndim(pos) == 0:
        posv = jnp.full((1,), pos, jnp.int32)
        cos, sin = rope_cos_sin(posv, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin).astype(dtype)
        k = apply_rope(k, cos, sin).astype(dtype)
        slot = jnp.mod(pos, C)
        kcache = jax.lax.dynamic_update_slice(kcache, k.astype(kcache.dtype),
                                              (0, slot, 0, 0))
        vcache = jax.lax.dynamic_update_slice(vcache, v.astype(vcache.dtype),
                                              (0, slot, 0, 0))
        # reconstruct absolute positions of cache slots for a ring buffer
        wrap = (pos // C) * C
        k_pos = jnp.where(idx <= slot, wrap + idx, wrap - C + idx)
        q_pos = posv
    else:
        posb = pos.astype(jnp.int32)                     # (B,)
        cos, sin = rope_cos_sin(posb[:, None], hd, cfg.rope_theta)  # (B,1,·)
        q = apply_rope(q, cos, sin).astype(dtype)
        k = apply_rope(k, cos, sin).astype(dtype)
        slot = jnp.mod(posb, C)                          # (B,)
        barange = jnp.arange(B)
        kcache = kcache.at[barange, slot].set(k[:, 0].astype(kcache.dtype))
        vcache = vcache.at[barange, slot].set(v[:, 0].astype(vcache.dtype))
        wrap = (posb // C) * C                           # (B,)
        k_pos = jnp.where(idx[None, :] <= slot[:, None],
                          wrap[:, None] + idx[None, :],
                          wrap[:, None] - C + idx[None, :])  # (B,C)
        q_pos = posb[:, None]                            # (B,1)
    k_valid = k_pos >= 0
    if call.use_decode_kernel:
        from repro.kernels import ops as kops
        bias = _mask_bias(q_pos, k_pos, call.window, k_valid)  # (·,1?,C)
        bias = jnp.broadcast_to(bias.reshape(-1, C), (B, C))
        out = kops.decode_attention(q[:, 0], kcache, vcache, bias,
                                    softcap=call.softcap)[:, None]
    else:
        out = _sdpa_dense(q, kcache, vcache, q_pos, k_pos, call.window,
                          call.softcap, k_valid=k_valid)
    return _proj_out(p["wo"], out.astype(dtype), dtype), kcache, vcache


# --------------------------------------------------------------------------- #
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------- #


def init_mlp(key, d, f, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], d, f, dtype=dtype),
        "wu": _dense_init(ks[1], d, f, dtype=dtype),
        "wd": _dense_init(ks[2], f, d, dtype=dtype),
    }


def mlp(p, x, act, dtype):
    g = linear(p["wg"], x, dtype)
    u = linear(p["wu"], x, dtype)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return linear(p["wd"], a * u, dtype)


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #


def padded_vocab(v, multiple=2048):
    return ((v + multiple - 1) // multiple) * multiple


def init_embed(key, cfg: ModelConfig, dtype=jnp.float32):
    V = padded_vocab(cfg.vocab_size)
    p = {"table": jax.random.normal(key, (V, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"] = jax.random.normal(k2, (cfg.d_model, V), dtype) \
            * cfg.d_model ** -0.5
    return p


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x, cfg: ModelConfig, dtype):
    if cfg.tie_embeddings:
        logits = x.astype(dtype) @ p["table"].astype(dtype).T
        logits = logits * (cfg.d_model ** -0.5)  # gemma-style tied-head scaling
    else:
        logits = x.astype(dtype) @ p["head"].astype(dtype)
    return logits


def cross_entropy(logits, labels, vocab_size):
    """Mean CE over positions; labels < 0 are masked out; padded vocab masked."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if V > vocab_size:
        pad_mask = jnp.arange(V) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
