"""Top-k routed Mixture-of-Experts with shared experts (Qwen-MoE / DeepSeek-V2 style).

Dispatch is sort-based with a static per-expert capacity (Megablocks-style,
adapted to TPU): tokens are ranked within their chosen expert via an argsort
over expert ids, scattered into a capacity buffer, processed with a stacked-
expert einsum (MXU friendly), and combined back with router weights. Tokens
overflowing capacity are dropped (standard GShard semantics).

Distribution story (hard-won; see EXPERIMENTS §Perf):
* grouped=True (default): each batch row is routed independently with a
  per-group capacity, so the scatter destination carries the batch dim and
  stays LOCAL to each data shard. The flat variant scatters data-sharded
  tokens into one global (E·C, d) buffer, which GSPMD can only realize by
  all-reducing the whole buffer every layer (measured 401 TB/device/round on
  deepseek-v2-236b train_4k).
* The expert FFN runs OUTSIDE the per-group vmap on the (B, E, C, d) buffer,
  with optional sharding constraints (``shard`` hook) pinning (batch, expert)
  dims — scatters have weak GSPMD propagation, and without the pin the
  buffer silently replicates (measured 9× total-flops blowup).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import _dense_init, init_mlp, linear, mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)

    def one_expert(k):
        kk = jax.random.split(k, 3)
        s = d ** -0.5
        return {
            "wg": jax.random.normal(kk[0], (d, m.d_ff_expert), dtype) * s,
            "wu": jax.random.normal(kk[1], (d, m.d_ff_expert), dtype) * s,
            "wd": jax.random.normal(kk[2], (m.d_ff_expert, d), dtype)
            * m.d_ff_expert ** -0.5,
        }

    p = {
        "router": _dense_init(ks[0], d, m.n_experts, dtype=dtype),
        "experts": jax.vmap(one_expert)(jax.random.split(ks[1], m.n_experts)),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[2], d, m.d_ff_shared, dtype=dtype)
    return p


def _capacity(n_tokens, m):
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, (c + 7) // 8 * 8)  # multiple of 8 (TPU sublane)


def _dispatch_one(p, cfg: ModelConfig, xt, dtype, C):
    """Route flat tokens xt (N, d) into an (E, C, d) capacity buffer.

    Returns (hidden (E,C,d), slot (N·K,), keep, w, token_of, aux)."""
    m = cfg.moe
    N, d = xt.shape
    E, K = m.n_experts, m.top_k

    logits = linear(p["router"], xt, jnp.float32)            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                     # (N, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style)
    density = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (N * K)
    mean_prob = probs.mean(axis=0)
    aux = m.router_aux_weight * E * jnp.sum(density * mean_prob)

    # rank within expert via stable argsort
    flat_e = eidx.reshape(-1)                                # (N*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(N * K, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((N * K,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)          # E*C = drop bin
    token_of = jnp.arange(N * K, dtype=jnp.int32) // K
    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(xt[token_of].astype(dtype), mode="drop")
    hidden = buf[: E * C].reshape(E, C, d)
    w = (gate.reshape(-1) * keep).astype(dtype)
    return hidden, slot, keep, w, token_of, aux


def _combine_one(out, slot, keep, w, token_of, N, dtype):
    """out (E,C,d) expert outputs -> per-token sums (N, d)."""
    EC, d = out.shape[0] * out.shape[1], out.shape[2]
    flat = out.reshape(EC, d)
    picked = jnp.where(keep[:, None], flat[jnp.minimum(slot, EC - 1)], 0.0)
    return jnp.zeros((N, d), dtype).at[token_of].add(picked * w[:, None])


def _expert_ffn(p, hidden, act, dtype):
    """hidden (..., E, C, d) -> (..., E, C, d) via stacked-expert einsums."""
    we = p["experts"]
    g = jnp.einsum("...ecd,edf->...ecf", hidden, we["wg"].astype(dtype))
    u = jnp.einsum("...ecd,edf->...ecf", hidden, we["wu"].astype(dtype))
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("...ecf,efd->...ecd", a * u, we["wd"].astype(dtype))


def moe_apply(p, cfg: ModelConfig, x, act, dtype, capacity=None,
              no_drop=False, grouped=True, shard=None):
    """x (B, S, d) -> (y (B, S, d), aux fp32).

    ``shard(arr)``: optional constraint hook applied to the (B, E, C, ·)
    buffers (launch/steps.py supplies it with the mesh's batch/expert axes).
    ``no_drop`` sets capacity = tokens·K (exactness tests only).
    """
    m = cfg.moe
    B, S, d = x.shape

    if grouped:
        C = S * m.top_k if no_drop else (capacity or _capacity(S, m))
        hidden, slot, keep, w, tok, aux = jax.vmap(
            lambda xg: _dispatch_one(p, cfg, xg, dtype, C))(x)
        if shard is not None:
            hidden = shard(hidden, "dispatch")   # (batch, E:model) for the FFN
        out = _expert_ffn(p, hidden, act, dtype)              # (B,E,C,d)
        if shard is not None:
            # one explicit model-axis gather of each group's buffer; without
            # it the per-token combine gather drags the FULL buffer through
            # an all-reduce every layer (measured 427 TB/device/round)
            out = shard(out, "combine")          # (batch, None)
        y = jax.vmap(lambda o, s, k, ww, t: _combine_one(o, s, k, ww, t, S,
                                                         dtype))(
            out, slot, keep, w, tok)
        if shard is not None:
            y = shard(y, "combine")              # pin (batch, None, None)
        aux = aux.mean()
    else:
        N = B * S
        C = N * m.top_k if no_drop else (capacity or _capacity(N, m))
        hidden, slot, keep, w, tok, aux = _dispatch_one(
            p, cfg, x.reshape(N, d), dtype, C)
        out = _expert_ffn(p, hidden, act, dtype)
        y = _combine_one(out, slot, keep, w, tok, N, dtype)
    y = y.reshape(B, S, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x, act, dtype)
    return y, aux
