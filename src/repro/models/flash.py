"""Memory-efficient (flash-style) attention in pure JAX with a custom VJP.

Forward: online-softmax over KV blocks per Q block — O(S·D) residuals
(out, rowmax, rowsum), never the S×S score matrix.
Backward: standard FlashAttention-2 recompute — scores rebuilt per block pair
from saved (q, k, v, out, m, l); dq accumulated over KV blocks, dk/dv over Q
blocks. Peak memory O(block²) instead of O(S²) (a plain lax.scan
implementation saves every block's probabilities for the backward — measured
45 GB/device on qwen2-0.5b train_4k before this).

This is the lowering-friendly counterpart of kernels/flash_attention.py (the
Pallas TPU kernel); both match kernels/ref.attention_ref in tests.

Layout: q (B,H,Sq,D), k/v (B,H,Sk,D) — KV already repeated to full heads
(GQA repeat happens in layers.attention, where the head dim is sharded).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG = -1e30


def _blk_mask(q_pos, k_pos, window):
    """window: int32 scalar/array; pass HUGE (2**30) for full attention."""
    ok = k_pos[None, :] <= q_pos[:, None]
    ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return ok


def _fwd_qblock(qb, k, v, qp, k_pos, window, softcap, bk, scale):
    """One q block vs all kv blocks. qb (B,H,bq,D) -> (out, m, l)."""
    B, H, bq, D = qb.shape
    Sk = k.shape[2]
    nk = Sk // bk
    kb = k.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)
    kpb = k_pos.reshape(nk, bk)

    def step(carry, xs):
        m, l, acc = carry
        kk, vv, kp = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32) * scale,
                       kk.astype(jnp.float32))
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(_blk_mask(qp, kp, window)[None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, bq), NEG, jnp.float32)
    l0 = jnp.zeros((B, H, bq), jnp.float32)
    a0 = jnp.zeros((B, H, bq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, m, l


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def flash_mha(q, k, v, q_pos, k_pos, window, softcap=0.0, bq=1024,
              bk=1024):
    """window is an int32 array (possibly traced per-layer); 2**30 = off."""
    out, _, _ = _flash_fwd_all(q, k, v, q_pos, k_pos, window, softcap, bq, bk)
    return out


def _flash_fwd_all(q, k, v, q_pos, k_pos, window, softcap, bq, bk):
    B, H, Sq, D = q.shape
    scale = D ** -0.5
    nq = Sq // bq
    qb = q.reshape(B, H, nq, bq, D).transpose(2, 0, 1, 3, 4)
    qpb = q_pos.reshape(nq, bq)

    def one(xs):
        qq, qp = xs
        return _fwd_qblock(qq, k, v, qp, k_pos, window, softcap, bk, scale)

    outs, ms, ls = jax.lax.map(one, (qb, qpb))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)
    m = ms.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    l = ls.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return out.astype(q.dtype), m, l


def _fwd_rule(q, k, v, q_pos, k_pos, window, softcap, bq, bk):
    out, m, l = _flash_fwd_all(q, k, v, q_pos, k_pos, window, softcap, bq, bk)
    return out, (q, k, v, out, m, l, q_pos, k_pos, window)


def _bwd_rule(softcap, bq, bk, res, dout):
    q, k, v, out, m, l, q_pos, k_pos, window = res
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = D ** -0.5
    nq, nk = Sq // bq, Sk // bk
    f32 = jnp.float32

    delta = jnp.sum(dout.astype(f32) * out.astype(f32), axis=-1)  # (B,H,Sq)

    qb = q.reshape(B, H, nq, bq, D).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)
    dob = dout.reshape(B, H, nq, bq, D).transpose(2, 0, 1, 3, 4)
    mb = m.reshape(B, H, nq, bq).transpose(2, 0, 1, 3)
    lb = l.reshape(B, H, nq, bq).transpose(2, 0, 1, 3)
    db = delta.reshape(B, H, nq, bq).transpose(2, 0, 1, 3)
    qpb = q_pos.reshape(nq, bq)
    kpb = k_pos.reshape(nk, bk)

    def p_block(qq, kk, qp, kp, mm, ll):
        s = jnp.einsum("bhqd,bhkd->bhqk", qq.astype(f32) * scale,
                       kk.astype(f32))
        raw = s
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(_blk_mask(qp, kp, window)[None, None], s, NEG)
        p = jnp.exp(s - mm[..., None]) / jnp.maximum(ll, 1e-30)[..., None]
        return p, raw

    def ds_block(p, dp, dd, raw):
        ds = p * (dp - dd[..., None])
        if softcap:
            ds = ds * (1.0 - jnp.tanh(raw / softcap) ** 2)
        return ds

    # ---- dq: for each q block, loop kv blocks ---------------------------------
    def dq_one(xs):
        qq, do, mm, ll, dd, qp = xs

        def step(acc, ys):
            kk, vv, kp = ys
            p, raw = p_block(qq, kk, qp, kp, mm, ll)
            dp = jnp.einsum("bhqd,bhkd->bhqk", do.astype(f32), vv.astype(f32))
            ds = ds_block(p, dp, dd, raw)
            return acc + jnp.einsum("bhqk,bhkd->bhqd", ds,
                                    kk.astype(f32)) * scale, None

        acc0 = jnp.zeros((B, H, bq, D), f32)
        acc, _ = jax.lax.scan(step, acc0, (kb, vb, kpb))
        return acc

    dq = jax.lax.map(dq_one, (qb, dob, mb, lb, db, qpb))
    dq = dq.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D).astype(q.dtype)

    # ---- dk, dv: for each kv block, loop q blocks -----------------------------
    def dkv_one(xs):
        kk, vv, kp = xs

        def step(carry, ys):
            dk_acc, dv_acc = carry
            qq, do, mm, ll, dd, qp = ys
            p, raw = p_block(qq, kk, qp, kp, mm, ll)
            dv_acc = dv_acc + jnp.einsum("bhqk,bhqd->bhkd", p, do.astype(f32))
            dp = jnp.einsum("bhqd,bhkd->bhqk", do.astype(f32), vv.astype(f32))
            ds = ds_block(p, dp, dd, raw)
            dk_acc = dk_acc + jnp.einsum("bhqk,bhqd->bhkd", ds,
                                         qq.astype(f32)) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, H, bk, D), f32)
        (dk_acc, dv_acc), _ = jax.lax.scan(step, (z, z),
                                           (qb, dob, mb, lb, db, qpb))
        return dk_acc, dv_acc

    dk, dv = jax.lax.map(dkv_one, (kb, vb, kpb))
    dk = dk.transpose(1, 2, 0, 3, 4).reshape(B, H, Sk, D).astype(k.dtype)
    dv = dv.transpose(1, 2, 0, 3, 4).reshape(B, H, Sk, D).astype(v.dtype)
    return dq, dk, dv, None, None, None


flash_mha.defvjp(_fwd_rule, _bwd_rule)


def flash_attention_bshd(q, k, v, q_pos, k_pos, *, window=None, softcap=0.0,
                         bq=1024, bk=1024):
    """(B,S,H,D) layout wrapper; kv already repeated to H heads.
    window: None/0 -> full attention; int or traced int32 -> sliding."""
    B, Sq = q.shape[0], q.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, k.shape[1])
    if window is None or (isinstance(window, int) and window == 0):
        window = jnp.int32(2**30)
    o = flash_mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                  v.transpose(0, 2, 1, 3), q_pos, k_pos,
                  jnp.asarray(window, jnp.int32), softcap, bq, bk)
    return o.transpose(0, 2, 1, 3)
