"""Top-level model API: build(cfg) -> Model with init / loss / prefill / decode.

Batch formats (what the data pipeline and input_specs produce):

* token families (dense/moe/ssm/hybrid):
    ``{"tokens": (B,S) i32, "labels": (B,S) i32}``
* audio (musicgen): the EnCodec frontend is a stub — precomputed frame
    embeddings replace token embeddings 1:1:
    ``{"embeds": (B,S,d) f32, "labels": (B,S) i32}``
* vlm (internvl2): ViT/projector stubbed — patch embeddings prepended:
    ``{"patches": (B,P,d) f32, "tokens": (B,S) i32, "labels": (B,S) i32}``
    (labels cover text positions only).

``loss`` returns mean next-token CE (+ MoE aux). ``prefill`` returns last-pos
logits and the decode cache. ``decode`` consumes one token id per sequence.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import transformer as T
from repro.models.layers import (AttnCall, cross_entropy, embed, init_embed,
                                 init_rmsnorm, padded_vocab, rmsnorm, unembed)


@dataclasses.dataclass
class ModelCallConfig:
    """Runtime (non-parameter) knobs; a §Perf surface."""
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024          # KV-chunk for online-softmax long prefill
    dense_attn_max: int = 2048      # use dense attention for S <= this
    remat: bool = True
    use_flash_kernel: bool = False
    mla_absorbed: bool = True       # MLA decode in latent space
    decode_window: int = 0          # ring-buffer decode cache (long_500k)
    use_decode_kernel: bool = False  # fused Pallas decode attention + sampling
    softcap: float = 0.0
    exact_moe: bool = False         # no MoE capacity drops (tests)
    # optional residual-stream sharding hook: fn((B,S,d)) -> constrained array.
    # Used by launch/steps.py to pin batch-parallel activations when parameter
    # sharding would otherwise win GSPMD propagation (paper_fsdp mode).
    act_shard: Any = None
    # optional (B,E,C,·) MoE-buffer constraint (scatters propagate weakly)
    moe_shard: Any = None


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    call: ModelCallConfig
    init: Callable            # (key) -> params
    loss: Callable            # (params, batch) -> scalar fp32
    prefill: Callable         # (params, batch) -> (logits_last, cache)
    decode: Callable          # (params, cache, token (B,), pos) -> (logits, cache)
    init_cache: Callable      # (batch, cache_len) -> cache pytree
    # (params, batch, cache_len) -> (logits_last, decode cache at pos=S):
    # prefill whose cache feeds decode directly — no prompt replay.
    prefill_cache: Callable = None
    # (params, cache, token, pos, noise (B,V)) -> (next token (B,), cache):
    # one decode step fused with gumbel-argmax sampling (greedy = zero noise).
    decode_sample: Callable = None
    # (params, batch) -> full-sequence fp32 logits (B, S, V): the pre-CE
    # view of ``loss`` — what the semi-supervised client objectives
    # (core/objectives.py) consume for pseudo-labels / consistency targets.
    logits: Callable = None


def build(cfg: ModelConfig, call: Optional[ModelCallConfig] = None) -> Model:
    call = call or ModelCallConfig()
    dtype = call.dtype

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": init_embed(k1, cfg),
            "blocks": T.init_stack(k2, cfg),
            "final_norm": init_rmsnorm(cfg.d_model),
        }

    def _attncall(S):
        chunk = call.attn_chunk if S > call.dense_attn_max else 0
        return AttnCall(window=0, softcap=call.softcap, chunk=chunk,
                        use_flash_kernel=call.use_flash_kernel,
                        force_window=call.decode_window,
                        exact_moe=call.exact_moe, moe_shard=call.moe_shard)

    def _residual_input(params, batch):
        """family-specific residual-stream input + label positions."""
        if cfg.family == "audio":
            x = batch["embeds"].astype(dtype)
            labels = batch["labels"]
            return x, labels, 0
        if cfg.family == "vlm":
            tx = embed(params["embed"], batch["tokens"], dtype)
            x = jnp.concatenate([batch["patches"].astype(dtype), tx], axis=1)
            P = batch["patches"].shape[1]
            pad = jnp.full((batch["labels"].shape[0], P), -1, jnp.int32)
            labels = jnp.concatenate([pad, batch["labels"]], axis=1)
            return x, labels, 0
        x = embed(params["embed"], batch["tokens"], dtype)
        return x, batch["labels"], 0

    def _constrain(x):
        return call.act_shard(x) if call.act_shard is not None else x

    def _forward_logits(params, batch):
        x, labels, _ = _residual_input(params, batch)
        x = _constrain(x)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        y, _, aux = T.forward(params["blocks"], cfg, x, positions,
                              _attncall(S), dtype, want_cache=False,
                              remat=call.remat)
        y = rmsnorm(params["final_norm"], y, cfg.norm_eps)
        return unembed(params["embed"], y, cfg, dtype), labels, aux

    def loss(params, batch):
        logits_, labels, aux = _forward_logits(params, batch)
        return cross_entropy(logits_, labels, cfg.vocab_size) + aux

    def logits(params, batch):
        return _forward_logits(params, batch)[0].astype(jnp.float32)

    def prefill(params, batch):
        x, _, _ = _residual_input(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        y, caches, _ = T.forward(params["blocks"], cfg, x, positions,
                                 _attncall(S), dtype, want_cache=True,
                                 remat=False)
        y = rmsnorm(params["final_norm"], y[:, -1:, :], cfg.norm_eps)
        logits = unembed(params["embed"], y, cfg, dtype)
        return logits[:, 0, :], caches

    def init_cache(batch_size, cache_len):
        clen = min(cache_len, call.decode_window) if call.decode_window \
            else cache_len
        return T.init_decode_cache(cfg, batch_size, clen, dtype=jnp.bfloat16)

    def prefill_cache(params, batch, cache_len):
        """Prefill returning (last-pos logits, decode-ready cache).

        Unlike ``prefill`` (whose cache is the raw stacked per-layer output),
        the cache here is in ``init_cache`` layout, populated so decode
        continues at pos = prompt_len — no prompt replay.
        """
        x, _, _ = _residual_input(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        y, caches, _ = T.forward(params["blocks"], cfg, x, positions,
                                 _attncall(S), dtype, want_cache=True,
                                 remat=False)
        cache = T.prefill_to_decode_cache(cfg, caches, S,
                                          init_cache(B, cache_len))
        y = rmsnorm(params["final_norm"], y[:, -1:, :], cfg.norm_eps)
        logits = unembed(params["embed"], y, cfg, dtype)
        return logits[:, 0, :], cache

    def _decode_call():
        return AttnCall(window=call.decode_window or 0, softcap=call.softcap,
                        force_window=call.decode_window,
                        use_decode_kernel=call.use_decode_kernel,
                        exact_moe=call.exact_moe, moe_shard=call.moe_shard)

    def decode(params, cache, token, pos):
        """token (B,) int32 ids; pos scalar int32 or (B,) per-slot positions.
        Returns (logits (B,V), cache)."""
        x = embed(params["embed"], token[:, None], dtype)
        y, cache = T.decode(params["blocks"], cfg, x, pos, cache,
                            _decode_call(), dtype,
                            mla_absorbed=call.mla_absorbed)
        y = rmsnorm(params["final_norm"], y, cfg.norm_eps)
        logits = unembed(params["embed"], y, cfg, dtype)
        return logits[:, 0, :], cache

    def decode_sample(params, cache, token, pos, noise):
        """One decode step fused with sampling: next token = argmax over the
        real vocab of logits + ``noise`` ((B,V) fp32; zeros = greedy, gumbel
        draws = categorical). With ``use_decode_kernel`` the unembed matmul
        and the argmax run in one Pallas pass without materialising logits."""
        x = embed(params["embed"], token[:, None], dtype)
        y, cache = T.decode(params["blocks"], cfg, x, pos, cache,
                            _decode_call(), dtype,
                            mla_absorbed=call.mla_absorbed)
        y = rmsnorm(params["final_norm"], y, cfg.norm_eps)[:, 0, :]
        if call.use_decode_kernel:
            from repro.kernels import ops as kops
            if cfg.tie_embeddings:
                table, scale = params["embed"]["table"], cfg.d_model ** -0.5
            else:
                # (V, d) layout for the kernel; a production server would
                # pre-transpose once instead of per step
                table, scale = params["embed"]["head"].T, 1.0
            tok = kops.decode_sample(y, table, noise, scale=scale,
                                     v_real=cfg.vocab_size)
        else:
            logits = unembed(params["embed"], y[:, None, :], cfg, dtype)[:, 0]
            logits = logits.astype(jnp.float32) + noise.astype(jnp.float32)
            V = logits.shape[-1]
            if V > cfg.vocab_size:
                logits = jnp.where(jnp.arange(V) >= cfg.vocab_size, -jnp.inf,
                                   logits)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, cache

    return Model(cfg=cfg, call=call, init=init, loss=loss, logits=logits,
                 prefill=prefill, decode=decode, init_cache=init_cache,
                 prefill_cache=prefill_cache, decode_sample=decode_sample)


# --------------------------------------------------------------------------- #
# input specs (abstract stand-ins for every model input; no allocation)
# --------------------------------------------------------------------------- #


def batch_struct(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStructs of a *training/prefill* batch for this family."""
    i32 = jnp.int32
    f32 = jnp.float32
    if cfg.family == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        return {
            "patches": jax.ShapeDtypeStruct((batch, P, cfg.d_model), f32),
            "tokens": jax.ShapeDtypeStruct((batch, seq - P), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq - P), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }


def sample_batch(cfg: ModelConfig, key, batch: int, seq: int):
    """Concrete random batch matching batch_struct (for smoke tests/examples)."""
    structs = batch_struct(cfg, batch, seq)
    out = {}
    for name, s in structs.items():
        key = jax.random.fold_in(key, hash(name) % (2**31))
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(key, s.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(key, s.shape, jnp.float32)
    return out
