from repro.models.model import Model, ModelCallConfig, batch_struct, build, sample_batch  # noqa
