"""Plain inner optimizers shared by examples and the FedOpt client loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_step(params, mom, grads, lr, beta1=0.9, weight_decay=0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    mom = jax.tree.map(lambda m, g: beta1 * m + g, mom, grads)
    params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
    return params, mom


def adamw_step(params, m, v, grads, lr, t, beta1=0.9, beta2=0.999, eps=1e-8,
               weight_decay=0.0):
    m = jax.tree.map(lambda a, g: beta1 * a + (1 - beta1) * g, m, grads)
    v = jax.tree.map(lambda a, g: beta2 * a + (1 - beta2) * g * g, v, grads)
    tt = t.astype(jnp.float32) + 1.0
    c1 = 1.0 - beta1 ** tt
    c2 = 1.0 - beta2 ** tt
    def upd(p, mi, vi):
        return p - lr * (mi / c1) / (jnp.sqrt(vi / c2) + eps) \
            - lr * weight_decay * p
    params = jax.tree.map(upd, params, m, v)
    return params, m, v
