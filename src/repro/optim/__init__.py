from repro.optim.inner import adamw_step, sgd_step  # noqa
