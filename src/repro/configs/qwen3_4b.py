"""qwen3-4b — dense, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab_size=151936, d_head=128, qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

# beyond-assignment variant: sliding-window attention so long_500k decode is
# legal for a dense arch (selectable: --arch qwen3-4b-swa)
CONFIG_SWA = CONFIG.replace(name="qwen3-4b-swa", sliding_window=8192)

REDUCED = CONFIG.replace(
    name="qwen3-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, d_head=32,
)
