"""Config system: architecture configs, input shapes, and the registry.

Every assigned architecture has a module ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact assigned dims) and ``REDUCED`` (a tiny same-family variant for CPU
smoke tests: <=2 layers, d_model<=512, <=4 experts).

Select with ``--arch <id>`` (dashed ids, e.g. ``zamba2-2.7b``).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# --------------------------------------------------------------------------- #
# Model configuration
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    n_shared: int = 0               # always-on shared experts
    top_k: int = 1
    d_ff_expert: int = 0            # per-expert FFN hidden dim
    d_ff_shared: int = 0            # total shared-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_layer_start: int = 0        # first layer index that is MoE (earlier = dense)
    d_ff_dense: int = 0             # FFN dim for the dense (non-MoE) layers


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                # SSD chunk length
    ngroups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    # attention features
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    local_global_ratio: int = 0     # gemma3: N local layers per 1 global (0 = all global)
    logit_softcap: float = 0.0
    # norm / activation
    norm_eps: float = 1e-6
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): run a shared (weight-tied) attention block every k ssm layers
    hybrid_attn_every: int = 0
    # modality frontend stub: extra embedding inputs of shape (B, n_frontend, d_model)
    frontend_tokens: int = 0        # vlm: #patch embeddings; audio: embeddings per frame
    frontend_kind: str = ""         # "" | "vision" | "audio"
    # source citation
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        n = V * d  # embeddings
        if not self.tie_embeddings:
            n += V * d  # lm head
        per_layer = 0
        hd = self.head_dim
        if self.family == "ssm" or (self.family == "hybrid" and self.ssm):
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            # in_proj(z,x,B,C,dt) + conv + out_proj + A,D,dt_bias + norm
            conv_dim = d_in + 2 * s.ngroups * s.d_state
            per_layer += d * (2 * d_in + 2 * s.ngroups * s.d_state + nheads)
            per_layer += conv_dim * s.d_conv + d_in * d + 3 * nheads + 2 * d
        if self.family in ("dense", "moe", "audio", "vlm") or self.hybrid_attn_every:
            attn = d * self.n_heads * hd  # q
            if self.mla:
                m = self.mla
                attn = (d * m.q_lora_rank
                        + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn += 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            n_attn_layers = (L if not self.hybrid_attn_every
                             else L // self.hybrid_attn_every)
            if self.hybrid_attn_every:  # weight-tied shared block counted once
                n += attn + 3 * d * d  # incl. shared MLP-ish projections
                n_attn_layers = 0
            per_layer += attn if not self.hybrid_attn_every else 0
        if self.family in ("dense", "audio", "vlm"):
            per_layer += 3 * d * self.d_ff + 2 * d
        elif self.family == "moe":
            m = self.moe
            moe_layers = L - m.moe_layer_start
            dense_layers = m.moe_layer_start
            n += moe_layers * (m.n_experts * 3 * d * m.d_ff_expert
                               + m.n_shared * 3 * d * (m.d_ff_shared // max(m.n_shared, 1))
                               + d * m.n_experts)  # router
            n += dense_layers * 3 * d * (m.d_ff_dense or self.d_ff)
            per_layer += 2 * d  # norms
        n += per_layer * L + d  # final norm
        return n

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        moe_layers = self.n_layers - m.moe_layer_start
        unused = moe_layers * (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return full - unused


# --------------------------------------------------------------------------- #
# Input shapes (assigned)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic decode path); see DESIGN.md
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "zamba2-2.7b", "gemma3-4b", "qwen3-4b")


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

ARCH_IDS = (
    "zamba2-2.7b",
    "qwen3-4b",
    "qwen2-moe-a2.7b",
    "gemma3-4b",
    "qwen2-0.5b",
    "deepseek-67b",
    "mamba2-1.3b",
    "musicgen-large",
    "deepseek-v2-236b",
    "internvl2-1b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "p") for a in ARCH_IDS}
# beyond-assignment variants (selectable but not part of the assigned matrix)
_VARIANTS = {"qwen3-4b-swa": ("qwen3_4b", "CONFIG_SWA")}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    """Look up an architecture config by its dashed id (or any extra registered id)."""
    if arch in _VARIANTS:
        modname, attr = _VARIANTS[arch]
        mod = importlib.import_module(f"repro.configs.{modname}")
        return mod.REDUCED if reduced else getattr(mod, attr)
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: "
                       f"{sorted(_MODULE_FOR) + sorted(_VARIANTS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def register(arch_id: str, module_name: str) -> None:
    _MODULE_FOR[arch_id] = module_name


def list_archs():
    return list(_MODULE_FOR)


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def pairs_to_run():
    """All (arch, shape) pairs of the assignment, with long_500k skips applied."""
    out = []
    for a in ARCH_IDS:
        for s in INPUT_SHAPES.values():
            if s.name == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue
            out.append((a, s.name))
    return out
