"""internvl2-1b — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

Backbone only: the ViT/projector is a stub; input_specs() provides 256 patch
embeddings (B, 256, d_model) prepended to the text tokens.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151655, qkv_bias=True, tie_embeddings=True,
    frontend_tokens=256, frontend_kind="vision",
    source="arXiv:2404.16821",
)

REDUCED = CONFIG.replace(
    name="internvl2-reduced", n_layers=2, d_model=112, n_heads=4, n_kv_heads=2,
    d_ff=224, vocab_size=512, frontend_tokens=16,
)
