"""qwen2-0.5b — dense, GQA kv=2, QKV bias [arXiv:2407.10671]."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    source="arXiv:2407.10671",
)

REDUCED = CONFIG.replace(
    name="qwen2-0.5b-reduced", n_layers=2, d_model=112, n_heads=4, n_kv_heads=2,
    d_ff=224, vocab_size=512,
)
