"""gemma3-4b — dense, 5:1 local(sliding-1024):global attention, 128k ctx
[hf:google/gemma-3-1b-pt]."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262144, d_head=256, qk_norm=True, act="gelu",
    sliding_window=1024, local_global_ratio=5, rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)

REDUCED = CONFIG.replace(
    name="gemma3-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, d_head=32, sliding_window=64, local_global_ratio=1,
)
