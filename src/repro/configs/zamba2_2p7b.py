"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560; a single weight-tied attention(+MLP) block runs
every 6 layers (Zamba2's shared transformer block), 32 heads (kv=32), d_ff=10240,
vocab=32000, ssm_state=64.
"""
from repro.configs import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, d_head=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid_attn_every=6, sliding_window=0,
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.replace(
    name="zamba2-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512, d_head=32,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
    hybrid_attn_every=2,
)
