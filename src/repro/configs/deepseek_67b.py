"""deepseek-67b — dense llama-arch, 95L, GQA kv=8 [arXiv:2401.02954]."""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=102400,
    source="arXiv:2401.02954",
)

REDUCED = CONFIG.replace(
    name="deepseek-67b-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
)
