"""mamba2-1.3b — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060",
)

REDUCED = CONFIG.replace(
    name="mamba2-reduced", n_layers=2, d_model=128, vocab_size=512,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
)
