"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec/conv frontend is a stub; input_specs() provides
precomputed frame embeddings (B, S, d_model). The decoder predicts codebook
tokens, vocab=2048.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, act="gelu",
    frontend_tokens=-1, frontend_kind="audio",   # -1: embeddings replace tokens 1:1
    source="arXiv:2306.05284",
)

REDUCED = CONFIG.replace(
    name="musicgen-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=256,
)
