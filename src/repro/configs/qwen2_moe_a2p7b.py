"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 experts [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936, qkv_bias=True,
    moe=MoEConfig(n_experts=60, n_shared=4, top_k=4, d_ff_expert=1408,
                  d_ff_shared=5632, capacity_factor=1.25),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

REDUCED = CONFIG.replace(
    name="qwen2-moe-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab_size=512,
    moe=MoEConfig(n_experts=4, n_shared=1, top_k=2, d_ff_expert=64,
                  d_ff_shared=128, capacity_factor=1.5),
)
