"""deepseek-v2-236b — MoE 160e top-6 (+2 shared), MLA kv_lora=512 [arXiv:2405.04434]."""
from repro.configs import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab_size=102400,
    moe=MoEConfig(n_experts=160, n_shared=2, top_k=6, d_ff_expert=1536,
                  d_ff_shared=3072, capacity_factor=1.25,
                  moe_layer_start=1, d_ff_dense=12288),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434",
)

REDUCED = CONFIG.replace(
    name="deepseek-v2-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=4, n_shared=1, top_k=2, d_ff_expert=64,
                  d_ff_shared=64, capacity_factor=1.5,
                  moe_layer_start=1, d_ff_dense=256),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
)
