import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 placeholder host devices back the production meshes
(16,16) and (2,16,16).

Per pair this records into results/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis()  — per-device argument/output/temp/code bytes
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerators)
  * collective operand bytes by kind (parsed from optimized HLO)
  * compile wall time, mode (paper/plain), clients M, analytic param count

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all                 # single-pod sweep
  python -m repro.launch.dryrun --all --multi-pod     # 2-pod sweep
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, get_shape, pairs_to_run
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.utils.hlo import collective_bytes, op_census
from repro.utils.hlo_cost import analyze as hlo_analyze
from repro.utils.hlo_cost import xla_cost_properties


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            mode: str = "auto", method: str = "savic", compression=None,
            het_model=None, het_seed: int = 0, het_sigma: float = 0.6,
            asynchrony=None, controller=None, use_fused_kernel: bool = False,
            objective=None, labeled_frac: float = 1.0, personal=None,
            out_dir: str = "results/dryrun",
            save: bool = True, call=None, tag: str = "", verbose=True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = get_shape(shape_name)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size, "tag": tag,
    }
    t0 = time.time()
    built = build_step(arch, shape_name, mesh, mode=mode, method=method,
                       compression=compression, het_model=het_model,
                       het_seed=het_seed, het_sigma=het_sigma,
                       asynchrony=asynchrony, controller=controller,
                       objective=objective, labeled_frac=labeled_frac,
                       personal=personal,
                       use_fused_kernel=use_fused_kernel, call=call) \
        if shape.kind == "train" else build_step(arch, shape_name, mesh,
                                                 call=call)
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate)
        lowered = jitted.lower(*built.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = xla_cost_properties(compiled)  # list/dict normalized per jaxlib
    hlo = compiled.as_text()
    coll_total, coll_kind, coll_count = collective_bytes(hlo)
    tc = hlo_analyze(hlo)   # trip-count-corrected (scans execute L·H times)

    cfg = get_config(arch)
    rec.update({
        "kind": shape.kind,
        "mode": built.meta.get("mode", "serve"),
        "method": built.meta.get("method", ""),
        "clients": built.meta.get("clients", 0),
        "h_local": built.meta.get("h_local", 0),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        # raw cost_analysis (while bodies counted ONCE — kept for reference)
        "flops_raw": cost.get("flops", 0.0),
        "bytes_raw": cost.get("bytes accessed", 0.0),
        # trip-count-corrected HLO analysis (the roofline numerators)
        "flops": tc["flops"],
        "bytes_accessed": tc["bytes"],
        "collective_bytes": tc["collective_bytes"],
        "collective_by_kind": tc["collective_by_kind"],
        "collective_counts": tc["collective_counts"],
        "unknown_trip_loops": tc["unknown_trip_loops"],
        "collective_bytes_static": coll_total,
        "collective_by_kind_static": coll_kind,
        "memory": {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "op_census": op_census(hlo),
        "ok": True,
    })
    spec = built.meta.get("engine_spec")
    if spec is not None:
        # sync compression (engine SyncStrategy layer) + analytic wire volume
        import dataclasses as _dc

        from repro.core import engine as _engine
        params_one = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            built.args[0]["params"])
        rec["compression"] = _dc.asdict(spec.sync.compression)
        rec["sync_payload_per_client"] = _engine.bytes_on_wire(spec, params_one)
        # heterogeneity & staleness (DESIGN.md §5): the H_m vector is a spec
        # constant (baked into the program); the buffer is server state
        rec["asynchrony"] = _dc.asdict(spec.sync.asynchrony)
        if "flat_layout" in built.meta:
            # fused flat-buffer client loop (DESIGN.md §7): the in-round
            # flat-view layout the scan runs over
            rec["flat_layout"] = built.meta["flat_layout"]
        if "flat_layout_sharded" in built.meta:
            # shard-mapped fused path (model-/FSDP-sharded plans): the
            # per-shard flat layout — each device's (M, n_local) block
            rec["flat_layout_sharded"] = built.meta["flat_layout_sharded"]
        if "fused_kernel_fallback" in built.meta:
            # only genuinely ineligible builds fall back now (non-fp32
            # client state); sharded plans take the shard_map fast path
            rec["fused_kernel_fallback"] = built.meta["fused_kernel_fallback"]
        if "objective" in built.meta:
            # client objective & personalization (DESIGN.md §12): the kind,
            # labeled fraction and client-resident leaf mask the program was
            # lowered with — wire volume above already excludes personal
            # leaves (bytes_on_wire strips them)
            rec["objective"] = built.meta["objective"]
        hs = spec.client.local_steps
        rec["heterogeneity"] = {
            "local_steps": list(hs) if hs is not None else None,
            **{k: built.meta[k] for k in
               ("het_model", "step_times", "sim_round_time_sync",
                "sim_round_time_budgeted", "sim_round_time_async")
               if k in built.meta},
        }
        if spec.controller.enabled:
            # controller contract (DESIGN.md §10): the compiled program is
            # knob-agnostic — H_m/k/b_eff are read from state["ctrl"] each
            # round. The artifact records the spec and the INITIAL knobs;
            # the realized trajectory lands in launch/train.py's log.
            from repro.core import controller as _ctrl
            c0 = _ctrl.init_ctrl_state(spec.controller,
                                       built.meta.get("clients", 0))
            rec["controller"] = {
                "spec": _dc.asdict(spec.controller),
                "init_knobs": {
                    "h_m": [int(h) for h in c0["h_m"]],
                    "k": float(c0["k"]),
                    "b_eff": int(c0["b_eff"]),
                },
                "state_leaves": {k2: list(v.shape)
                                 for k2, v in c0.items()},
            }
    if verbose:
        print(f"[dryrun] {arch:18s} {shape_name:12s} mesh={rec['mesh']:8s} "
              f"mode={rec['mode']:6s} flops={rec['flops']:.3e} "
              f"coll={coll_total/1e9:.2f}GB compile={rec['compile_s']:.1f}s",
              flush=True)
    if save:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{rec['mesh']}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="auto")
    ap.add_argument("--method", default="savic",
                    help="round-engine method for train shapes "
                         "(savic|fedadagrad|fedadam|fedyogi|local-adam)")
    ap.add_argument("--compression", default="none",
                    help="sync delta compression for train shapes "
                         "(none|topk|randk|int8-stochastic)")
    ap.add_argument("--compression-k", type=float, default=0.1)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--het-model", default="",
                    help="systems-heterogeneity model for train shapes "
                         "(uniform|lognormal|tiers); H_m is baked into the "
                         "lowered program as scan masking")
    ap.add_argument("--het-seed", type=int, default=0)
    ap.add_argument("--het-sigma", type=float, default=0.6,
                    help="lognormal straggler sigma for --het-model lognormal")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="server staleness buffer depth B (adds the sharded "
                         "delta FIFO to the compiled state)")
    ap.add_argument("--staleness-weight", default="constant")
    ap.add_argument("--controller", action="store_true",
                    help="enable the adaptive communication-budget controller "
                         "(round-addressable H_m/k/b_eff knobs; artifact "
                         "records the spec + initial knob values)")
    ap.add_argument("--use-fused-kernel", action="store_true",
                    help="flat-buffer fused client loop (one Pallas pass per "
                         "local step; artifact records the flat-view layout)")
    ap.add_argument("--objective", default="supervised",
                    help="client objective for train shapes "
                         "(supervised|consistency|pseudo-label)")
    ap.add_argument("--labeled-frac", type=float, default=1.0,
                    help="labeled fraction (<1 adds the 'labeled' batch leaf)")
    ap.add_argument("--personalize", default="",
                    help="comma-separated client-resident param-path "
                         "substrings (never synced; DESIGN.md §12)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    from repro.core.engine import AsyncSpec, CompressionSpec
    comp = None if args.compression == "none" else CompressionSpec(
        op=args.compression, k=args.compression_k,
        error_feedback=args.error_feedback)
    asy = None if not args.async_buffer else AsyncSpec(
        buffer_rounds=args.async_buffer, weighting=args.staleness_weight)
    het = args.het_model or None
    ctrl = None
    if args.controller:
        from repro.core.controller import ControllerSpec
        ctrl = ControllerSpec(enabled=True, buffer_max=args.async_buffer)
        het = het or "lognormal"  # controller requires a heterogeneity trace
    obj = None
    if args.objective != "supervised":
        from repro.core.objectives import ObjectiveSpec
        obj = ObjectiveSpec(kind=args.objective)
    personal = tuple(p for p in args.personalize.split(",") if p) or None

    if args.all:
        failures = []
        for arch, shape in pairs_to_run():
            try:
                run_one(arch, shape, multi_pod=args.multi_pod, mode=args.mode,
                        method=args.method, compression=comp, het_model=het,
                        het_seed=args.het_seed, het_sigma=args.het_sigma,
                        asynchrony=asy, controller=ctrl,
                        objective=obj, labeled_frac=args.labeled_frac,
                        personal=personal,
                        use_fused_kernel=args.use_fused_kernel,
                        out_dir=args.out, tag=args.tag)
            except Exception as e:  # noqa
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] FAIL {arch} {shape}: {e}", flush=True)
                traceback.print_exc()
        print(f"[dryrun] done; {len(failures)} failures")
        for f in failures:
            print("  FAIL:", *f)
        raise SystemExit(1 if failures else 0)

    run_one(args.arch, args.shape, multi_pod=args.multi_pod, mode=args.mode,
            method=args.method, compression=comp, het_model=het,
            het_seed=args.het_seed, het_sigma=args.het_sigma, asynchrony=asy,
            controller=ctrl, objective=obj, labeled_frac=args.labeled_frac,
            personal=personal, use_fused_kernel=args.use_fused_kernel,
            out_dir=args.out, tag=args.tag)


if __name__ == "__main__":
    main()
