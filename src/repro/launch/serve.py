"""Batched serving driver: prefill once, reuse the cache, decode (DESIGN.md §8).

On TPU this serves the assigned configs on the production mesh (see
launch/steps.build_serve_step / build_prefill_step for the sharded serve
path); on CPU it runs reduced configs end-to-end, which is what the serving
example, benchmarks and tests use.

Four entry points:

* ``serve`` — the production path: ``model.prefill_cache`` returns the decode
  cache already populated at pos = prompt_len, so decode starts immediately
  (TTFT = one batched prefill). The cache conversion is fused into the
  prefill program, so ``cache_setup_s`` is 0 here by construction.
* ``serve_replay`` — the old per-token prompt-replay path, kept ONLY as a
  differential baseline (tests pin reuse == replay greedy tokens; the
  benchmark shows reuse dominating replay on TTFT). Timing is attributed
  honestly: the replay loop is ``cache_setup_s``, not prefill.
* ``serve_continuous`` — continuous batching over a fixed ring of ``slots``
  decode slots: requests from a synthetic Poisson arrival trace are admitted
  into free slots (single-request prefill + ``dynamic_update_slice`` into the
  slot-major cache at a *traced* slot index) and evicted on completion, while
  ONE jitted decode step with per-slot (B,) positions serves the whole ring —
  zero recompilation across request churn (asserted via jit cache size).
* ``serve_static`` — static batching baseline on the SAME trace: groups of
  ``slots`` requests, a group starts only when every member has arrived and
  the previous group drained, and runs to the longest member's length.

Scheduling comparison is in decode-step clock units (1 step = one batched
decode; prefill = 0 steps; idle waiting advances the clock), which isolates
the batching policy from CPU-vs-TPU step cost; wall-clock compute seconds are
reported alongside, honestly.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import ModelCallConfig, build, sample_batch


# --------------------------------------------------------------------------- #
# shared plumbing
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray      # (B, gen_len) generated ids (first from prefill)
    timings: dict           # prefill_s / cache_setup_s / decode_s / ttft_s / tok_per_s
    per_token_s: np.ndarray  # decode-loop wall seconds per step


@dataclasses.dataclass
class TraceResult:
    tokens: dict            # rid -> (gen_len_r,) np.int32
    requests: dict          # rid -> {arrival, start, finish} in step-clock units
    metrics: dict           # makespan_steps, tok_per_step, wall tok/s, p50/p99, ...


def _build(arch, *, reduced, dtype, decode_window, use_decode_kernel,
           exact_moe):
    cfg = get_config(arch, reduced=reduced)
    call = ModelCallConfig(dtype=dtype, decode_window=decode_window,
                           use_decode_kernel=use_decode_kernel,
                           exact_moe=exact_moe)
    return cfg, build(cfg, call)


def _noise(key, shape, greedy):
    """Additive sampling noise: zeros = greedy; Gumbel = categorical."""
    if greedy:
        return jnp.zeros(shape, jnp.float32), key
    key, k = jax.random.split(key)
    return jax.random.gumbel(k, shape, jnp.float32), key


def _first_token(logits, noise, vocab_size):
    lg = logits.astype(jnp.float32) + noise
    V = lg.shape[-1]
    if V > vocab_size:
        lg = jnp.where(jnp.arange(V) >= vocab_size, -jnp.inf, lg)
    return jnp.argmax(lg, -1).astype(jnp.int32)


def _jit_cache_size(fn):
    try:
        return fn._cache_size()
    except AttributeError:       # older jax
        return -1


def poisson_trace(n_requests, arrival_rate, seed, gen_len):
    """Synthetic Poisson arrival trace in decode-step clock units.

    Returns (arrivals, gens): arrival step of each request (cumulative
    exponential inter-arrival times at ``arrival_rate`` requests/step) and its
    generation length, drawn in [max(1, gen_len//2), gen_len].
    """
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / arrival_rate, size=n_requests)
    arrivals = np.floor(np.cumsum(inter)).astype(np.int64)
    gens = rng.integers(max(1, gen_len // 2), gen_len + 1, size=n_requests)
    return arrivals, gens


def request_prompt(cfg, seed, rid, prompt_len):
    """Per-request B=1 prompt, deterministic in (seed, rid)."""
    return sample_batch(cfg, jax.random.fold_in(jax.random.PRNGKey(seed + 1),
                                                rid), 1, prompt_len)


# --------------------------------------------------------------------------- #
# single-batch serving: cache reuse (production) vs prompt replay (baseline)
# --------------------------------------------------------------------------- #


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen_len=32,
          decode_window=0, dtype=jnp.float32, greedy=True, seed=0,
          use_decode_kernel=False, exact_moe=False, cache_len=None,
          prompt=None, warmup=False, verbose=True) -> ServeResult:
    """Prefill once, decode from the returned cache — no prompt replay.

    ``warmup=True`` compiles the prefill and decode programs on a throwaway
    pass before timing, so the reported phases are steady-state (benchmarks);
    the default includes compile, matching a cold server start.
    """
    cfg, model = _build(arch, reduced=reduced, dtype=dtype,
                        decode_window=decode_window,
                        use_decode_kernel=use_decode_kernel,
                        exact_moe=exact_moe)
    params = model.init(jax.random.PRNGKey(seed))
    if prompt is None:
        prompt = sample_batch(cfg, jax.random.PRNGKey(seed + 1), batch,
                              prompt_len)
    cache_len = cache_len or (prompt_len + gen_len)

    prefill = jax.jit(model.prefill_cache, static_argnums=2)
    step = jax.jit(model.decode_sample)
    if warmup:
        lg, cw = prefill(params, prompt, cache_len)
        tw, cw = step(params, cw, jnp.zeros((batch,), jnp.int32),
                      jnp.int32(prompt_len),
                      jnp.zeros(lg.shape, jnp.float32))
        jax.block_until_ready(tw)

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt, cache_len)
    jax.block_until_ready((logits, cache))
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 2)
    noise, key = _noise(key, logits.shape, greedy)
    tok = _first_token(logits, noise, cfg.vocab_size)

    out, per_tok = [np.asarray(tok)], []
    pos = prompt_len
    for _ in range(gen_len - 1):
        noise, key = _noise(key, logits.shape, greedy)
        ts = time.perf_counter()
        tok, cache = step(params, cache, tok, jnp.int32(pos), noise)
        tok.block_until_ready()
        per_tok.append(time.perf_counter() - ts)
        pos += 1
        out.append(np.asarray(tok))
    t_decode = float(sum(per_tok))

    timings = {"prefill_s": t_prefill, "cache_setup_s": 0.0,
               "decode_s": t_decode, "ttft_s": t_prefill,
               "tok_per_s": batch * max(gen_len - 1, 1) / max(t_decode, 1e-9)}
    if verbose:
        print(f"[serve] {arch}: prefill {t_prefill:.3f}s (TTFT), "
              f"decode {gen_len - 1} steps x{batch} = "
              f"{timings['tok_per_s']:.1f} tok/s")
    return ServeResult(np.stack(out, axis=1), timings,
                       np.asarray(per_tok, np.float64))


def serve_replay(arch: str, *, reduced=True, batch=4, prompt_len=32,
                 gen_len=32, decode_window=0, dtype=jnp.float32, greedy=True,
                 seed=0, exact_moe=False, cache_len=None, prompt=None,
                 warmup=False, verbose=True) -> ServeResult:
    """Differential baseline: build the decode cache by replaying the prompt
    token-by-token through ``model.decode``. Token-id families only (the
    replay feeds ids, not embeddings). The replay loop is reported as
    ``cache_setup_s`` — the misattribution the old driver had (it called it
    prefill) is fixed here."""
    cfg, model = _build(arch, reduced=reduced, dtype=dtype,
                        decode_window=decode_window, use_decode_kernel=False,
                        exact_moe=exact_moe)
    params = model.init(jax.random.PRNGKey(seed))
    if prompt is None:
        prompt = sample_batch(cfg, jax.random.PRNGKey(seed + 1), batch,
                              prompt_len)
    cache_len = cache_len or (prompt_len + gen_len)
    toks = prompt.get("tokens")
    if toks is None:
        toks = jnp.zeros((batch, prompt_len), jnp.int32)

    decode = jax.jit(model.decode)
    step = jax.jit(model.decode_sample)
    if warmup:
        cw = model.init_cache(batch, cache_len)
        lw, cw = decode(params, cw, toks[:, 0], jnp.int32(0))
        tw, cw = step(params, cw, toks[:, 0], jnp.int32(1),
                      jnp.zeros(lw.shape, jnp.float32))
        jax.block_until_ready(tw)

    t0 = time.perf_counter()
    cache = model.init_cache(batch, cache_len)
    logits = None
    for t in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, t], jnp.int32(t))
    jax.block_until_ready(logits)
    t_setup = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 2)
    noise, key = _noise(key, logits.shape, greedy)
    tok = _first_token(logits, noise, cfg.vocab_size)

    out, per_tok = [np.asarray(tok)], []
    pos = prompt_len
    for _ in range(gen_len - 1):
        noise, key = _noise(key, logits.shape, greedy)
        ts = time.perf_counter()
        tok, cache = step(params, cache, tok, jnp.int32(pos), noise)
        tok.block_until_ready()
        per_tok.append(time.perf_counter() - ts)
        pos += 1
        out.append(np.asarray(tok))
    t_decode = float(sum(per_tok))

    timings = {"prefill_s": 0.0, "cache_setup_s": t_setup,
               "decode_s": t_decode, "ttft_s": t_setup,
               "tok_per_s": batch * max(gen_len - 1, 1) / max(t_decode, 1e-9)}
    if verbose:
        print(f"[serve-replay] {arch}: replay {t_setup:.3f}s (TTFT), "
              f"decode {gen_len - 1} steps x{batch} = "
              f"{timings['tok_per_s']:.1f} tok/s")
    return ServeResult(np.stack(out, axis=1), timings,
                       np.asarray(per_tok, np.float64))


# --------------------------------------------------------------------------- #
# continuous vs static batching over a Poisson arrival trace
# --------------------------------------------------------------------------- #


def serve_continuous(arch: str, *, reduced=True, slots=4, n_requests=8,
                     prompt_len=8, gen_len=8, arrival_rate=0.5,
                     decode_window=0, dtype=jnp.float32, greedy=True, seed=0,
                     use_decode_kernel=False, exact_moe=False, warmup=False,
                     verbose=True) -> TraceResult:
    """Continuous batching: per-slot admission/eviction on a fixed decode ring.

    One jitted decode step (per-slot (B,) positions) serves every composition
    of in-flight requests; admission is a single-request prefill inserted into
    the slot-major cache at a traced slot index. Nothing recompiles as
    requests churn — asserted on the jit cache sizes at the end.
    """
    cfg, model = _build(arch, reduced=reduced, dtype=dtype,
                        decode_window=decode_window,
                        use_decode_kernel=use_decode_kernel,
                        exact_moe=exact_moe)
    params = model.init(jax.random.PRNGKey(seed))
    cache_len = prompt_len + gen_len
    arrivals, gens = poisson_trace(n_requests, arrival_rate, seed, gen_len)
    prompts = [request_prompt(cfg, seed, r, prompt_len)
               for r in range(n_requests)]

    prefill = jax.jit(model.prefill_cache, static_argnums=2)
    step = jax.jit(model.decode_sample)

    @jax.jit
    def insert_slot(cache, one, b):
        # every decode-cache leaf is slot-major with batch at dim 1
        return jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_slice_in_dim(f, o, b, axis=1),
            cache, one)

    cache = model.init_cache(slots, cache_len)
    if warmup:
        lw, cw = prefill(params, prompts[0], cache_len)
        c2 = insert_slot(cache, cw, jnp.int32(0))
        tw, c2 = step(params, c2, jnp.zeros((slots,), jnp.int32),
                      jnp.zeros((slots,), jnp.int32),
                      jnp.zeros((slots, lw.shape[-1]), jnp.float32))
        jax.block_until_ready(tw)
        cache = model.init_cache(slots, cache_len)
    V = None
    toks = np.zeros((slots,), np.int32)
    pos = np.zeros((slots,), np.int32)
    active = np.zeros((slots,), bool)
    rid_of = np.full((slots,), -1)
    remaining = np.zeros((slots,), np.int64)
    out_tokens = {r: [] for r in range(n_requests)}
    requests = {r: {"arrival": int(arrivals[r]), "start": None,
                    "finish": None} for r in range(n_requests)}
    key = jax.random.PRNGKey(seed + 2)
    next_req, n_done, clock = 0, 0, 0
    per_step_s, t_prefill_total = [], 0.0
    t_run0 = time.perf_counter()

    while n_done < n_requests:
        # --- admission: fill free slots with arrived requests -------------- #
        for b in range(slots):
            if active[b] or next_req >= n_requests \
                    or arrivals[next_req] > clock:
                continue
            r = next_req
            next_req += 1
            tp = time.perf_counter()
            logits1, c1 = prefill(params, prompts[r], cache_len)
            cache = insert_slot(cache, c1, jnp.int32(b))
            jax.block_until_ready(logits1)
            t_prefill_total += time.perf_counter() - tp
            V = logits1.shape[-1]
            noise, key = _noise(key, (1, V), greedy)
            t0 = int(np.asarray(_first_token(logits1, noise,
                                             cfg.vocab_size))[0])
            out_tokens[r].append(t0)
            requests[r]["start"] = clock
            if gens[r] == 1:                      # done at admission
                requests[r]["finish"] = clock
                n_done += 1
                continue
            toks[b], pos[b] = t0, prompt_len
            active[b], rid_of[b], remaining[b] = True, r, gens[r] - 1

        if not active.any():
            # ring empty: jump the clock to the next arrival
            clock = max(clock + 1, int(arrivals[next_req]))
            continue

        # --- one batched decode step over the whole ring ------------------- #
        noise, key = _noise(key, (slots, V), greedy)
        ts = time.perf_counter()
        tok_dev, cache = step(params, cache, jnp.asarray(toks),
                              jnp.asarray(pos), noise)
        tok_dev.block_until_ready()
        per_step_s.append(time.perf_counter() - ts)
        new_toks = np.asarray(tok_dev)
        clock += 1
        for b in range(slots):
            if not active[b]:
                continue
            r = rid_of[b]
            out_tokens[r].append(int(new_toks[b]))
            toks[b] = new_toks[b]
            pos[b] += 1
            remaining[b] -= 1
            if remaining[b] == 0:                 # eviction: free the slot
                requests[r]["finish"] = clock
                active[b], rid_of[b] = False, -1
                n_done += 1

    t_wall = time.perf_counter() - t_run0
    total = int(sum(gens))
    makespan = max(rq["finish"] for rq in requests.values())
    delays = [rq["start"] - rq["arrival"] for rq in requests.values()]
    per = np.asarray(per_step_s, np.float64)
    metrics = {
        "mode": "continuous", "slots": slots, "n_requests": n_requests,
        "total_tokens": total, "makespan_steps": int(makespan),
        "tok_per_step": total / max(makespan, 1),
        "decode_steps": len(per_step_s),
        "wall_s": t_wall, "prefill_s": t_prefill_total,
        "decode_s": float(per.sum()),
        "wall_tok_per_s": total / max(t_wall, 1e-9),
        "p50_step_s": float(np.percentile(per, 50)) if len(per) else 0.0,
        "p99_step_s": float(np.percentile(per, 99)) if len(per) else 0.0,
        "mean_queue_delay_steps": float(np.mean(delays)),
        "max_queue_delay_steps": int(np.max(delays)),
        "jit_cache_sizes": {"step": _jit_cache_size(step),
                            "prefill": _jit_cache_size(prefill),
                            "insert": _jit_cache_size(insert_slot)},
    }
    if verbose:
        print(f"[serve-continuous] {arch}: {n_requests} reqs / {slots} slots: "
              f"{total} tok in {makespan} steps "
              f"({metrics['tok_per_step']:.2f} tok/step, "
              f"{metrics['wall_tok_per_s']:.1f} tok/s wall)")
    return TraceResult({r: np.asarray(t, np.int32)
                        for r, t in out_tokens.items()}, requests, metrics)


def serve_static(arch: str, *, reduced=True, slots=4, n_requests=8,
                 prompt_len=8, gen_len=8, arrival_rate=0.5, decode_window=0,
                 dtype=jnp.float32, greedy=True, seed=0,
                 use_decode_kernel=False, exact_moe=False, warmup=False,
                 verbose=True) -> TraceResult:
    """Static-batching baseline on the SAME Poisson trace as serve_continuous:
    requests are served in arrival-order groups of ``slots``; a group starts
    only when all members have arrived and the previous group has drained, and
    decodes to the longest member's length (short members pad)."""
    cfg, model = _build(arch, reduced=reduced, dtype=dtype,
                        decode_window=decode_window,
                        use_decode_kernel=use_decode_kernel,
                        exact_moe=exact_moe)
    params = model.init(jax.random.PRNGKey(seed))
    cache_len = prompt_len + gen_len
    arrivals, gens = poisson_trace(n_requests, arrival_rate, seed, gen_len)
    prompts = [request_prompt(cfg, seed, r, prompt_len)
               for r in range(n_requests)]

    prefill = jax.jit(model.prefill_cache, static_argnums=2)
    step = jax.jit(model.decode_sample)
    if warmup:
        bw = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *([prompts[0]] * slots))
        lw, cw = prefill(params, bw, cache_len)
        tw, cw = step(params, cw, jnp.zeros((slots,), jnp.int32),
                      jnp.zeros((slots,), jnp.int32),
                      jnp.zeros((slots, lw.shape[-1]), jnp.float32))
        jax.block_until_ready(tw)

    out_tokens = {r: [] for r in range(n_requests)}
    requests = {r: {"arrival": int(arrivals[r]), "start": None,
                    "finish": None} for r in range(n_requests)}
    key = jax.random.PRNGKey(seed + 2)
    clock = 0
    per_step_s, t_prefill_total = [], 0.0
    t_run0 = time.perf_counter()

    for g0 in range(0, n_requests, slots):
        grp = list(range(g0, min(g0 + slots, n_requests)))
        # pad the last group by repeating its final member (outputs ignored)
        padded = grp + [grp[-1]] * (slots - len(grp))
        start = max(clock, max(int(arrivals[r]) for r in grp))
        batch = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *[prompts[r] for r in padded])
        tp = time.perf_counter()
        logits, cache = prefill(params, batch, cache_len)
        jax.block_until_ready(logits)
        t_prefill_total += time.perf_counter() - tp
        V = logits.shape[-1]
        noise, key = _noise(key, (slots, V), greedy)
        toks = _first_token(logits, noise, cfg.vocab_size)
        first = np.asarray(toks)
        for i, r in enumerate(grp):
            out_tokens[r].append(int(first[i]))
            requests[r]["start"] = start
            requests[r]["finish"] = start + int(gens[r]) - 1
        mg = max(int(gens[r]) for r in grp)
        for t in range(mg - 1):
            noise, key = _noise(key, (slots, V), greedy)
            posv = np.full((slots,), prompt_len + t, np.int32)
            ts = time.perf_counter()
            toks, cache = step(params, cache, toks, jnp.asarray(posv), noise)
            toks.block_until_ready()
            per_step_s.append(time.perf_counter() - ts)
            new = np.asarray(toks)
            for i, r in enumerate(grp):
                if t + 1 < int(gens[r]):
                    out_tokens[r].append(int(new[i]))
        clock = start + mg - 1

    t_wall = time.perf_counter() - t_run0
    total = int(sum(gens))
    makespan = max(rq["finish"] for rq in requests.values())
    delays = [rq["start"] - rq["arrival"] for rq in requests.values()]
    per = np.asarray(per_step_s, np.float64)
    metrics = {
        "mode": "static", "slots": slots, "n_requests": n_requests,
        "total_tokens": total, "makespan_steps": int(makespan),
        "tok_per_step": total / max(makespan, 1),
        "decode_steps": len(per_step_s),
        "wall_s": t_wall, "prefill_s": t_prefill_total,
        "decode_s": float(per.sum()),
        "wall_tok_per_s": total / max(t_wall, 1e-9),
        "p50_step_s": float(np.percentile(per, 50)) if len(per) else 0.0,
        "p99_step_s": float(np.percentile(per, 99)) if len(per) else 0.0,
        "mean_queue_delay_steps": float(np.mean(delays)),
        "max_queue_delay_steps": int(np.max(delays)),
        "jit_cache_sizes": {"step": _jit_cache_size(step),
                            "prefill": _jit_cache_size(prefill)},
    }
    if verbose:
        print(f"[serve-static] {arch}: {n_requests} reqs / {slots} slots: "
              f"{total} tok in {makespan} steps "
              f"({metrics['tok_per_step']:.2f} tok/step, "
              f"{metrics['wall_tok_per_s']:.1f} tok/s wall)")
    return TraceResult({r: np.asarray(t, np.int32)
                        for r, t in out_tokens.items()}, requests, metrics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mode", default="reuse",
                    choices=["reuse", "replay", "continuous", "static"])
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size (reuse/replay) or decode slots (traces)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--decode-window", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-greedy", action="store_true")
    ap.add_argument("--decode-kernel", action="store_true",
                    help="fused Pallas decode attention + sampling")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="Poisson arrivals per decode step (trace modes)")
    args = ap.parse_args()
    common = dict(reduced=not args.full, prompt_len=args.prompt_len,
                  gen_len=args.gen_len, decode_window=args.decode_window,
                  seed=args.seed, greedy=not args.no_greedy)
    if args.mode == "reuse":
        serve(args.arch, batch=args.batch,
              use_decode_kernel=args.decode_kernel, **common)
    elif args.mode == "replay":
        serve_replay(args.arch, batch=args.batch, **common)
    else:
        fn = serve_continuous if args.mode == "continuous" else serve_static
        fn(args.arch, slots=args.batch, n_requests=args.requests,
           arrival_rate=args.arrival_rate,
           use_decode_kernel=args.decode_kernel, **common)


if __name__ == "__main__":
    main()
