"""Batched serving driver: prefill a prompt batch, then decode greedily.

On TPU this serves the assigned configs on the production mesh (see
launch/steps.build_serve_step for the sharded serve path); on CPU it runs
reduced configs end-to-end, which is what the serving example and tests use.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import ModelCallConfig, build, sample_batch


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen_len=32,
          decode_window=0, dtype=jnp.float32, greedy=True, seed=0,
          verbose=True):
    cfg = get_config(arch, reduced=reduced)
    call = ModelCallConfig(dtype=dtype, decode_window=decode_window)
    model = build(cfg, call)
    params = model.init(jax.random.PRNGKey(seed))
    prompt = sample_batch(cfg, jax.random.PRNGKey(seed + 1), batch, prompt_len)

    t0 = time.time()
    logits, _ = jax.jit(model.prefill)(params, prompt)
    # decode continues from a fresh cache replayed over the prompt (simple and
    # family-agnostic; a production server would reuse the prefill cache)
    cache = model.init_cache(batch, prompt_len + gen_len)
    decode = jax.jit(model.decode)
    toks = prompt.get("tokens")
    if toks is None:
        toks = jnp.zeros((batch, prompt_len), jnp.int32)
    pos = 0
    for t in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, t], jnp.int32(pos))
        pos += 1
    t_prefill = time.time() - t0

    out = []
    key = jax.random.PRNGKey(seed + 2)
    t1 = time.time()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        pos += 1
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits).astype(jnp.int32)
    t_dec = time.time() - t1
    tput = batch * gen_len / max(t_dec, 1e-9)
    if verbose:
        print(f"[serve] {arch}: prefill {t_prefill:.2f}s, "
              f"decode {gen_len} steps x{batch} = {tput:.1f} tok/s")
    return np.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--decode-window", type=int, default=0)
    args = ap.parse_args()
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen_len=args.gen_len,
          decode_window=args.decode_window)


if __name__ == "__main__":
    main()
