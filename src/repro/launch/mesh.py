"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).

Target hardware: TPU v5e pods — 256 chips/pod in a (16,16) ICI torus;
multi-pod couples 2 pods over DCN. Constants used by the roofline analysis
live in benchmarks/roofline.py.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-sized sharding tests (devices permitting)."""
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(f"need {need} devices, have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])
