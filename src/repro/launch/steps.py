"""Step builders: (arch × input-shape × mesh × mode) -> jit-able function +
abstract inputs + shardings.  Shared by dryrun.py, train.py, serve.py and the
benchmarks.

Shape kinds:
* train   -> SAVIC ``round_step``  (H local steps × M clients + sync)
* prefill -> ``prefill`` (full forward, returns last logits + KV cache)
* decode  -> ``serve_step`` (ONE new token against a seq_len KV cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, get_config, get_shape
from repro.core import PrecondConfig, SavicConfig, engine, objectives, savic
from repro.models import ModelCallConfig, batch_struct, build
from repro.sharding import (AxisPlan, batch_pspecs, cache_pspecs,
                            params_pspecs, plan_for, serve_batch_pspecs)

# archs whose full replica does not fit a 16-chip model group in fp32 training
# (plain mode: M=1, params FSDP-sharded over the data axis; see DESIGN.md §2)
BIG_ARCHS = ("deepseek-67b", "deepseek-v2-236b")

# decode window (ring-buffer KV) used in the long_500k shape on windowed archs
LONG_DECODE_WINDOW = 8192


@dataclasses.dataclass
class BuiltStep:
    fn: Any                   # jit-able python callable
    args: tuple               # abstract ShapeDtypeStructs (or concrete arrays)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def _train_plan(arch: str, mesh, mode: str = "auto") -> AxisPlan:
    multi = "pod" in mesh.axis_names
    if mode == "auto":
        mode = "plain" if arch in BIG_ARCHS else "paper"
    return plan_for(mode, multi), mode


def savic_round_h(shape: ShapeConfig) -> int:
    return 8  # local steps per round lowered in the dry-run (scan: HLO-size free)


def _method_engine_spec(method: str, pc_kind: str,
                        sv: Optional[SavicConfig]) -> engine.EngineSpec:
    """Resolve the engine spec for a train-step method selector."""
    if method == "savic":
        pc = PrecondConfig(kind=pc_kind, alpha=1e-2)
        return savic.engine_spec(pc, sv or SavicConfig(gamma=3e-4, beta1=0.9))
    if sv is not None:
        raise ValueError(f"sv= (SavicConfig) only applies to method='savic', "
                         f"got method={method!r}")
    return engine.method_spec(method, pc_kind=pc_kind)


def build_train_step(arch: str, shape: ShapeConfig, mesh, *, mode: str = "auto",
                     method: str = "savic", pc_kind: str = "adam",
                     call: Optional[ModelCallConfig] = None,
                     reduced: bool = False, h_local: Optional[int] = None,
                     sv: Optional[SavicConfig] = None,
                     engine_spec: Optional[engine.EngineSpec] = None,
                     compression: Optional[engine.CompressionSpec] = None,
                     het_model: Optional[str] = None, het_seed: int = 0,
                     het_sigma: float = 0.6,
                     local_steps: Optional[tuple] = None,
                     asynchrony: Optional[engine.AsyncSpec] = None,
                     controller: Optional[engine.ControllerSpec] = None,
                     objective: Optional[objectives.ObjectiveSpec] = None,
                     labeled_frac: float = 1.0,
                     personal: Optional[tuple] = None,
                     use_fused_kernel: bool = False, seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    plan, mode = _train_plan(arch, mesh, mode)
    if call is None:
        call = ModelCallConfig()
    if mode in ("paper_fsdp", "plain") and call.act_shard is None:
        # pin batch-parallel activations (otherwise the d-sharded embedding
        # wins GSPMD propagation and attention replicates; see EXPERIMENTS §Perf)
        # NB: bind the pspec at definition time — `spec` is rebound to the
        # EngineSpec below, and a late-binding closure here handed THAT to
        # NamedSharding (broke every plain-mode build at trace time)
        act_spec = P(tuple(plan.batch), None, None)
        call = dataclasses.replace(
            call, act_shard=lambda x, _s=act_spec:
                jax.lax.with_sharding_constraint(x, NamedSharding(mesh, _s)))
    if cfg.moe and call.moe_shard is None:
        call = dataclasses.replace(
            call, moe_shard=_moe_shard_fn(cfg, mesh, plan))
    model = build(cfg, call)
    M = plan.clients(mesh) if plan.client else 1
    assert shape.global_batch % M == 0, (shape.global_batch, M)
    b_client = shape.global_batch // M
    H = h_local or savic_round_h(shape)

    spec = engine_spec or _method_engine_spec(method, pc_kind, sv)
    if compression is not None:
        # engine-level knob (like --participation/--sync-dtype): applies to
        # every method, composing with an explicit engine_spec too
        spec = dataclasses.replace(
            spec, sync=dataclasses.replace(spec.sync, compression=compression))
    het_meta = {}
    if het_model is not None and local_steps is None:
        # systems heterogeneity (DESIGN.md §5): sample per-client step times,
        # derive the budgeted H_m vector, record the simulated wall clock
        from repro.data import federated as fed
        step_times = fed.sample_step_times(het_model, M, seed=het_seed,
                                           sigma=het_sigma)
        local_steps = tuple(int(h) for h in
                            fed.local_steps_from_times(step_times, H))
        asy = asynchrony or spec.sync.asynchrony
        het_meta = {
            "het_model": het_model,
            "step_times": [round(float(t), 4) for t in step_times],
            "sim_round_time_sync": round(fed.simulated_round_time(
                step_times, [H] * M, barrier="sync"), 4),
            # budgeted H_m barrier; only an actual staleness buffer makes it
            # an "async" pace (B=0 would mislabel pure H_m budgeting)
            "sim_round_time_budgeted": round(fed.simulated_round_time(
                step_times, local_steps, barrier="sync"), 4),
        }
        if asy.buffer_rounds > 0:
            het_meta["sim_round_time_async"] = round(fed.simulated_round_time(
                step_times, local_steps, barrier="async",
                buffer_rounds=asy.buffer_rounds), 4)
        if controller is not None and controller.enabled \
                and not controller.step_times:
            # the sampled trace IS the controller's observed straggler
            # spread; H_m then comes from the controller, not a static bake
            controller = dataclasses.replace(
                controller,
                step_times=tuple(float(t) for t in step_times))
    if controller is not None and controller.enabled:
        # the controller owns H_m (round-addressable via masking); a static
        # local_steps bake would conflict (build_round_step raises on both)
        local_steps = None
        spec = dataclasses.replace(spec, controller=controller)
        het_meta["controller"] = dataclasses.asdict(controller)
    if local_steps is not None:
        spec = dataclasses.replace(
            spec, client=dataclasses.replace(spec.client,
                                             local_steps=tuple(local_steps)))
    if asynchrony is not None:
        spec = dataclasses.replace(
            spec, sync=dataclasses.replace(spec.sync, asynchrony=asynchrony))
    if use_fused_kernel:
        # engine-level knob: the flat-buffer fused client loop (DESIGN.md §7)
        # is valid for every method/PrecondConfig kind
        spec = dataclasses.replace(
            spec, client=dataclasses.replace(spec.client,
                                             use_fused_kernel=True))
    if personal:
        # client-resident leaves (DESIGN.md §12): engine-level knob like
        # compression/asynchrony — applies to every method / engine_spec
        spec = dataclasses.replace(
            spec, sync=dataclasses.replace(spec.sync,
                                           personal=tuple(personal)))
    client_objective = objectives.build_objective(objective, model=model)
    if client_objective is not None or labeled_frac < 1.0 or personal:
        het_meta["objective"] = {
            "kind": objective.kind if objective is not None else "supervised",
            "labeled_frac": labeled_frac,
            "personal": list(spec.sync.personal),
        }

    # ---- abstract state & batch ----------------------------------------------
    state_shape = jax.eval_shape(
        partial(engine.init_state, init_params_fn=model.init, spec=spec,
                n_clients=M), jax.random.PRNGKey(0))
    micro = batch_struct(cfg, b_client, shape.seq_len)
    if labeled_frac < 1.0:
        # per-SEQUENCE labeled mask emitted by LMRoundLoader(labeled_frac<1);
        # the fully-labeled regime adds no leaf — batch structure (and the
        # compiled program) stay bit-exact pre-objectives
        micro = dict(micro)
        micro["labeled"] = jax.ShapeDtypeStruct((b_client,), jnp.float32)
    batch_shape = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M, H) + s.shape, s.dtype), micro)

    shard_plan = None
    if spec.client.use_fused_kernel:
        bad = _fused_non_fp32(state_shape, spec)
        if bad:
            # genuinely ineligible: the flat view is an fp32 buffer by
            # contract — take the (identical-semantics) tree path
            spec = dataclasses.replace(
                spec, client=dataclasses.replace(spec.client,
                                                 use_fused_kernel=False))
            het_meta["fused_kernel_fallback"] = \
                f"non-fp32 client state ({bad}; flat view is fp32 by contract)"
        elif _ax(mesh, plan.model) > 1 or plan.fsdp_params:
            # model-/FSDP-sharded plan: the single global flat view would make
            # GSPMD reshard the whole client state EVERY local step (measured
            # ~4e5× collective-byte blowup on the 16×16 mesh) — instead run
            # the fused step PER SHARD via shard_map (DESIGN.md §7): each
            # device flattens only its local leaf shards; state pytree,
            # shardings and donation below stay the tree path's
            from repro.utils.flatten import ShardedFlatPlan
            shard_axes = tuple(plan.model) + (tuple(plan.batch)
                                              if plan.fsdp_params else ())
            params_one = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                state_shape["params"])
            pspecs_one = params_pspecs(cfg, params_one, mesh, plan,
                                       client_dim=False)
            shard_plan = ShardedFlatPlan.build(
                mesh, params_one, pspecs_one, shard_axes,
                client=tuple(plan.client) if plan.client else None)
            het_meta["flat_layout_sharded"] = shard_plan.layout.describe()
        else:
            # client-parallel plan (replicated leaves within a client): the
            # original single flat view; layout recorded for dry-run artifacts
            from repro.utils.flatten import FlatLayout
            het_meta["flat_layout"] = FlatLayout.for_tree(
                state_shape["params"], batch_dims=1).describe()
    round_step = engine.build_round_step(model.loss, spec,
                                         shard_plan=shard_plan,
                                         objective=client_objective)

    def step(state, batch):
        # per-round key folded from the carried round counter: restart- and
        # resume-invariant by construction (DESIGN.md §9)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), state["round"])
        return round_step(state, batch, key)

    # ---- shardings (see DESIGN.md §2) ----------------------------------------
    state_spec = _engine_state_spec(cfg, state_shape, mesh, plan, spec)
    batch_spec = batch_pspecs(batch_shape, mesh, plan, client_dim=True)
    metrics_shape = jax.eval_shape(step, state_shape, batch_shape)[1]
    metrics_spec = jax.tree.map(lambda _: P(), metrics_shape)
    metrics_spec["loss_per_client"] = P(plan.client if plan.client else None)
    if "ctrl_h_m" in metrics_shape:
        # realized per-client H_m: client-sharded like loss_per_client
        metrics_spec["ctrl_h_m"] = P(plan.client if plan.client else None)

    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return BuiltStep(
        fn=step,
        args=(state_shape, batch_shape),
        in_shardings=(ns(state_spec), ns(batch_spec)),
        out_shardings=(ns(state_spec), ns(metrics_spec)),
        donate=(0,),
        meta={"mode": mode, "method": method, "clients": M, "h_local": H,
              "b_client": b_client, "cfg": cfg, "plan": plan,
              "engine_spec": spec, **het_meta},
    )


def _fused_non_fp32(state_shape, spec: engine.EngineSpec) -> str:
    """Name the first non-fp32 fused-client-state leaf group, or "".

    Mirrors the engine's trace-time ``all_float32`` gate (DESIGN.md §7) so the
    launch layer can record WHY a build fell back to the tree path — the meta
    contract asserted in tests/test_system.py.
    """
    from repro.utils.flatten import all_float32
    for name in ("params", "mom"):
        if not all_float32(state_shape[name]):
            return name
    if "d" in state_shape["precond"] \
            and spec.precond.kind != "identity" \
            and not all_float32(state_shape["precond"]["d"]):
        return "precond.d"
    return ""


def _engine_state_spec(cfg, state_shape, mesh, plan, spec: engine.EngineSpec):
    """PartitionSpec tree for an engine state pytree (DESIGN.md §2): client
    leaves carry a leading M dim over the client axes; the global D and the
    adaptive server's (m, v) are client-replicated single-replica trees.

    Personalization (DESIGN.md §12) needs no special casing for server/buffer
    specs: their shape-trees are already None-stripped by ``init_state`` and
    ``params_pspecs`` walks paths, so the spec trees come out stripped to
    match. Only the ``ef`` spec is derived from the FULL params spec tree and
    must be stripped explicitly (PartitionSpecs are tuples — containers — so
    the strip needs ``is_leaf``)."""
    pspec_m = params_pspecs(cfg, state_shape["params"], mesh, plan,
                            client_dim=True)
    state_spec = {
        "params": pspec_m,
        "mom": pspec_m,
        "precond": _precond_spec(cfg, state_shape["precond"], mesh, plan,
                                 local=spec.client.scaling == "local"),
        "round": P(),
    }
    if "server" in state_shape:
        pspec_1 = params_pspecs(cfg, state_shape["server"]["m"], mesh, plan,
                                client_dim=False)
        state_spec["server"] = {"m": pspec_1, "v": pspec_1}
    if "ef" in state_shape:
        # EF compression residual: per-client, sharded exactly like params/mom
        state_spec["ef"] = engine.strip_personal(
            spec.sync.personal, pspec_m,
            is_leaf=lambda x: isinstance(x, P))
    if "buffer" in state_shape:
        # staleness delta FIFO (DESIGN.md §5): single-replica shaped with a
        # leading B dim — B is never sharded, inner dims like one replica's
        # params (client-replicated server state, like server.m/v)
        buf_one = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            state_shape["buffer"])
        pspec_buf = params_pspecs(cfg, buf_one, mesh, plan, client_dim=False)
        state_spec["buffer"] = jax.tree.map(
            lambda s: P(None, *s), pspec_buf,
            is_leaf=lambda x: isinstance(x, P))
    if "ctrl" in state_shape:
        # controller knobs/EMAs (DESIGN.md §10): scalars replicated; the (M,)
        # h_m vector rides the client axes like the per-client precond t
        cl_ax = plan.client if plan.client else None
        state_spec["ctrl"] = {
            k: (P(cl_ax) if s.ndim else P())
            for k, s in state_shape["ctrl"].items()}
    return state_spec


def _moe_shard_fn(cfg, mesh, plan):
    """Constraint for the (B, E, C, d/f) MoE buffers: batch over batch(+client
    when M=1 plain) axes, experts over model axes when divisible."""
    baxes = tuple(plan.batch) or None
    E = cfg.moe.n_experts
    n_mdl = 1
    for a in plan.model:
        n_mdl *= mesh.shape[a]
    eaxes = tuple(plan.model) if (plan.model and E % n_mdl == 0) else None

    def f(x, where="dispatch"):
        e = eaxes if where == "dispatch" else None
        spec = P(baxes, e, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return f


def _precond_spec(cfg, precond_shape, mesh, plan, local):
    # local scaling keeps a per-client step counter t of shape (M,)
    t_spec = P(plan.client if plan.client else None) \
        if precond_shape["t"].ndim else P()
    spec = {"t": t_spec}
    if "d" in precond_shape:
        # global D: replicated across clients (no client dim), sharded like a
        # single replica's params; local D carries the leading client dim
        spec["d"] = params_pspecs(cfg, precond_shape["d"], mesh, plan,
                                  client_dim=local)
    return spec


def _serve_plan(arch: str, mesh) -> AxisPlan:
    multi = "pod" in mesh.axis_names
    batch = ("pod", "data") if multi else ("data",)
    fsdp = arch in BIG_ARCHS
    return AxisPlan(client=(), batch=batch, model=("model",),
                    fsdp_params=fsdp)


def _serve_call(arch: str, shape: ShapeConfig, call: Optional[ModelCallConfig]):
    if call is not None:
        return call
    window = LONG_DECODE_WINDOW if shape.name == "long_500k" else 0
    return ModelCallConfig(decode_window=window)


def _bf16_params(params_shape):
    """Serving stores weights in bf16 (training keeps fp32 masters)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        params_shape)


def build_prefill_step(arch: str, shape: ShapeConfig, mesh, *,
                       call: Optional[ModelCallConfig] = None,
                       reduced: bool = False,
                       cache_len: Optional[int] = None):
    """Full-sequence prefill on the serve mesh.

    With ``cache_len`` the step is ``model.prefill_cache``: the returned cache
    is in decode layout, populated so a serve_step continues at
    pos = seq_len with no prompt replay (DESIGN.md §8).
    """
    cfg = get_config(arch, reduced=reduced)
    call = call or ModelCallConfig()
    plan = _serve_plan(arch, mesh)
    if cfg.moe and call.moe_shard is None:
        call = dataclasses.replace(call,
                                   moe_shard=_moe_shard_fn(cfg, mesh, plan))
    model = build(cfg, call)

    params_shape = _bf16_params(jax.eval_shape(model.init,
                                               jax.random.PRNGKey(0)))
    batch_shape = batch_struct(cfg, shape.global_batch, shape.seq_len)
    # labels unused in prefill; keep specs uniform anyway
    pspec = params_pspecs(cfg, params_shape, mesh, plan, client_dim=False)
    bspec = serve_batch_pspecs(batch_shape, mesh, plan)

    if cache_len is not None:
        fn = partial(model.prefill_cache, cache_len=cache_len)
    else:
        fn = model.prefill
    out_shape = jax.eval_shape(fn, params_shape, batch_shape)
    logits_spec = P(tuple(plan.batch), None)
    cache_spec = cache_pspecs(cfg, out_shape[1], mesh, plan)

    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return BuiltStep(
        fn=fn,
        args=(params_shape, batch_shape),
        in_shardings=(ns(pspec), ns(bspec)),
        out_shardings=(ns(logits_spec), ns(cache_spec)),
        meta={"cfg": cfg, "plan": plan, "cache_len": cache_len},
    )


def build_serve_step(arch: str, shape: ShapeConfig, mesh, *,
                     call: Optional[ModelCallConfig] = None,
                     reduced: bool = False, pos_per_slot: bool = False):
    """ONE-token decode against a seq_len-deep KV cache.

    ``pos_per_slot=True`` makes pos a (B,) vector — every slot of the decode
    ring at its own depth (continuous batching; DESIGN.md §8). The cache stays
    slot-major: batch (slot) dim sharded over the data axes by cache_pspecs,
    so one jitted step serves the whole ring across request churn.
    """
    cfg = get_config(arch, reduced=reduced)
    call = _serve_call(arch, shape, call)
    plan = _serve_plan(arch, mesh)
    if cfg.moe and call.moe_shard is None:
        call = dataclasses.replace(call,
                                   moe_shard=_moe_shard_fn(cfg, mesh, plan))
    model = build(cfg, call)
    B = shape.global_batch

    params_shape = _bf16_params(jax.eval_shape(model.init,
                                               jax.random.PRNGKey(0)))
    cache_shape = jax.eval_shape(partial(model.init_cache, B, shape.seq_len))
    token_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((B,) if pos_per_slot else (), jnp.int32)

    def serve_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    pspec = params_pspecs(cfg, params_shape, mesh, plan, client_dim=False)
    cspec = cache_pspecs(cfg, cache_shape, mesh, plan)
    tok_spec = P(tuple(plan.batch)) if B % _ax(mesh, plan.batch) == 0 else P(None)
    logits_spec = P(tok_spec[0] if tok_spec != P(None) else None, None)
    pos_spec = tok_spec if pos_per_slot else P()

    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return BuiltStep(
        fn=serve_step,
        args=(params_shape, cache_shape, token_shape, pos_shape),
        in_shardings=(ns(pspec), ns(cspec), ns(tok_spec), ns(pos_spec)),
        out_shardings=(ns(logits_spec), ns(cspec)),
        donate=(1,),
        meta={"cfg": cfg, "plan": plan, "pos_per_slot": pos_per_slot,
              "decode_window": call.decode_window},
    )


def _ax(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def build_step(arch: str, shape_name: str, mesh, **kw):
    shape = get_shape(shape_name)
    if shape.kind == "train":
        return build_train_step(arch, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(arch, shape, mesh, **kw)
    return build_serve_step(arch, shape, mesh, **kw)
