"""End-to-end training driver for every engine method.

Two launch paths share one spec resolution, data pipeline, round loop and
checkpoint format (DESIGN.md §9):

* ``--mesh none`` (default) — single-host ``jax.jit`` over the engine's
  round step; runs anywhere, used by the CPU examples and tests.
* ``--mesh production|production-2pod|debug`` — the launch-layer path:
  ``steps.build_train_step`` builds the jitted step with the mesh plan's
  shardings and donation (paper / paper_fsdp / plain modes, shard-mapped
  fused local step on sharded plans, DESIGN.md §2/§7). The plan fixes the
  client count M (e.g. 16 on the 16×16 production mesh in paper mode);
  ``--clients`` applies to the single-host path only. Production meshes
  on CPU need ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
  set before jax initializes (see launch/dryrun.py).

Determinism and resume (DESIGN.md §9): the per-round key is
``fold_in(PRNGKey(seed+1), r)`` on both paths (the mesh step folds the
carried ``state["round"]`` counter), data is round-addressable
(``LMRoundLoader.round_batch(r, ...)``), and modal stubs are seeded from
(seed, round) — so train(T) ≡ train(t) + restore + train(T−t) bitwise in
loss, state, and every log field except the wall-clock measurements.

``--method`` selects the round composition (ClientLoop × SyncStrategy ×
ServerUpdate, see core/engine.py): savic (Algorithm 1), the FedOpt baselines
of [42] (fedadagrad / fedadam / fedyogi), and the composed local-adam
scenario (locally-scaled clients + adaptive server, cf. 2409.13155).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 20 --h-local 4 --clients 4 --batch 8 --seq 128 \
      --preconditioner adam --scaling global --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --method local-adam --rounds 5 --clients 2 --batch 2 --seq 64
  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --mesh production --batch 16 --seq 4096 --use-fused-kernel
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import ShapeConfig, get_config
from repro.core import PrecondConfig, SavicConfig, engine, objectives, savic
from repro.data import LMRoundLoader, TokenStream
from repro.data import federated
from repro.models import ModelCallConfig, build


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--h-local", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4,
                    help="client count M (single-host path; mesh plans fix M "
                         "from the client axes)")
    ap.add_argument("--batch", type=int, default=8, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "production", "production-2pod"],
                    help="route the launch through steps.build_train_step on "
                         "this mesh (none = single-host jax.jit fallback)")
    ap.add_argument("--mesh-shape", default="2x2",
                    help="data×model shape for --mesh debug, e.g. 1x1 / 2x4")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "paper", "paper_fsdp", "plain", "diloco"],
                    help="mesh axis plan (auto: plain for BIG_ARCHS else "
                         "paper; see DESIGN.md §2)")
    ap.add_argument("--method", default="savic", choices=list(engine.METHODS))
    ap.add_argument("--preconditioner", default="adam",
                    choices=["identity", "adam", "rmsprop", "oasis",
                             "adahessian", "adagrad"])
    ap.add_argument("--scaling", default="global", choices=["global", "local"])
    ap.add_argument("--gamma", type=float, default=3e-3,
                    help="client step size (γ / η_l)")
    ap.add_argument("--beta1", type=float, default=0.9,
                    help="client heavy-ball momentum (savic/local-adam)")
    ap.add_argument("--alpha", type=float, default=1e-2)
    ap.add_argument("--server-eta", type=float, default=0.1,
                    help="adaptive-server lr η (fed*/local-adam)")
    ap.add_argument("--server-beta1", type=float, default=0.9,
                    help="adaptive-server momentum β₁ (fed*/local-adam)")
    ap.add_argument("--tau", type=float, default=1e-3,
                    help="adaptive-server floor τ (fed*/local-adam)")
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--sync-dtype", default="")
    ap.add_argument("--compression", default="none",
                    choices=list(engine.COMPRESSION_OPS),
                    help="client->server delta compression (engine-level: "
                         "applies to every method)")
    ap.add_argument("--compression-k", type=float, default=0.1,
                    help="kept fraction per leaf for topk/randk")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry the EF residual buffer in the state pytree")
    ap.add_argument("--het-model", default="uniform",
                    choices=list(federated.SYSTEMS_MODELS),
                    help="systems-heterogeneity model for per-client local "
                         "steps H_m (engine-level: applies to every method)")
    ap.add_argument("--het-sigma", type=float, default=0.6,
                    help="lognormal straggler sigma for --het-model lognormal")
    ap.add_argument("--het-seed", type=int, default=0)
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="server staleness buffer depth B (0 = synchronous)")
    ap.add_argument("--staleness-weight", default="constant",
                    choices=list(engine.STALENESS_WEIGHTINGS),
                    help="staleness weighting s(tau) for the delta FIFO")
    ap.add_argument("--controller", action="store_true",
                    help="adaptive communication-budget controller "
                         "(DESIGN.md §10): gradient-noise-driven H_m growth, "
                         "EF-residual-guarded compression k, straggler-"
                         "spread-selected buffer depth. Owns H_m (the "
                         "--het-model trace feeds its step_times); state "
                         "rides the checkpoint bitwise")
    ap.add_argument("--ctrl-h-min", type=int, default=1,
                    help="controller: initial global local-step budget H_t")
    ap.add_argument("--ctrl-noise-target", type=float, default=1.0,
                    help="controller: grow H_t while the gradient-noise EMA "
                         "exceeds this")
    ap.add_argument("--ctrl-k-min", type=float, default=0.05,
                    help="controller: floor of the compression-k schedule")
    ap.add_argument("--ctrl-resid-guard", type=float, default=0.5,
                    help="controller: EF-residual-norm ratio above which k "
                         "grows back toward 1")
    ap.add_argument("--objective", default="supervised",
                    choices=list(objectives.OBJECTIVES),
                    help="client objective (DESIGN.md §12): supervised is the "
                         "identity (bit-exact pre-objectives program); "
                         "consistency / pseudo-label are the semi-supervised "
                         "losses over the labeled subset")
    ap.add_argument("--labeled-frac", type=float, default=1.0,
                    help="fraction of each client's sequences carrying labels "
                         "(<1 attaches the per-sequence 'labeled' mask leaf)")
    ap.add_argument("--unlabeled-weight", type=float, default=1.0,
                    help="λ_u on the unlabeled objective term")
    ap.add_argument("--pseudo-threshold", type=float, default=0.9,
                    help="confidence gate for --objective pseudo-label")
    ap.add_argument("--personalize", default="",
                    help="comma-separated param-path substrings kept client-"
                         "resident (never synced/served; e.g. 'final_norm'). "
                         "Personalizing under a GLOBAL non-identity D is "
                         "rejected at build time (DESIGN.md §12)")
    ap.add_argument("--use-fused-kernel", action="store_true",
                    help="flat-buffer fused client loop: one Pallas pass per "
                         "local step, every preconditioner kind (DESIGN.md "
                         "§7; bit-identical in fp32). Mesh launches run it "
                         "per-shard via shard_map on model-/FSDP-sharded "
                         "plans; the single-host path uses the unsharded "
                         "flat view")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    return ap


def _make_mesh(args):
    if args.mesh == "none":
        return None
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    if args.mesh == "debug":
        shape = tuple(int(x) for x in args.mesh_shape.split("x"))
        return make_debug_mesh(shape)
    return make_production_mesh(multi_pod=args.mesh == "production-2pod")


def _resolve_spec(args, n_clients):
    """CLI knobs -> (EngineSpec, local_steps, step_times); shared by the mesh
    and single-host paths so both train the identical round composition."""
    comp = engine.CompressionSpec(op=args.compression, k=args.compression_k,
                                  error_feedback=args.error_feedback)
    asy = engine.AsyncSpec(buffer_rounds=args.async_buffer,
                           weighting=args.staleness_weight)
    local_steps = None
    step_times = federated.sample_step_times(
        args.het_model, n_clients, seed=args.het_seed, sigma=args.het_sigma)
    ctrl = None
    if args.controller:
        # the controller owns H_m — no static local_steps bake; the sampled
        # straggler trace is its observed spread (DESIGN.md §10)
        ctrl = engine.ControllerSpec(
            enabled=True, h_min=args.ctrl_h_min, h_max=args.h_local,
            noise_target=args.ctrl_noise_target, k_min=args.ctrl_k_min,
            resid_guard=args.ctrl_resid_guard,
            buffer_max=args.async_buffer,
            step_times=tuple(float(t) for t in step_times))
    elif args.het_model != "uniform":
        local_steps = tuple(int(h) for h in federated.local_steps_from_times(
            step_times, args.h_local))
    if args.method == "savic":
        pc = PrecondConfig(kind=args.preconditioner, alpha=args.alpha)
        sv = SavicConfig(gamma=args.gamma, beta1=args.beta1,
                         scaling=args.scaling,
                         participation=args.participation,
                         sync_dtype=args.sync_dtype,
                         use_fused_kernel=args.use_fused_kernel,
                         compression=comp, local_steps=local_steps,
                         asynchrony=asy)
        spec = savic.engine_spec(pc, sv)
    else:
        spec = engine.method_spec(
            args.method, pc_kind=args.preconditioner, alpha=args.alpha,
            beta1=args.beta1, eta=args.server_eta, eta_l=args.gamma,
            tau=args.tau, server_beta1=args.server_beta1,
            participation=args.participation,
            sync_dtype=args.sync_dtype, compression=comp,
            local_steps=local_steps, asynchrony=asy,
            use_fused_kernel=args.use_fused_kernel)
    if ctrl is not None:
        import dataclasses as _dc
        spec = _dc.replace(spec, controller=ctrl)
    personal = tuple(p for p in args.personalize.split(",") if p)
    if personal:
        import dataclasses as _dc
        spec = _dc.replace(spec, sync=_dc.replace(spec.sync,
                                                  personal=personal))
    return spec, local_steps, step_times


def _objective_spec(args) -> objectives.ObjectiveSpec:
    return objectives.ObjectiveSpec(
        kind=args.objective, unlabeled_weight=args.unlabeled_weight,
        pseudo_threshold=args.pseudo_threshold)


def main(argv=None):
    args = _parser().parse_args(argv)
    cfg = get_config(args.arch, reduced=args.reduced)
    call = ModelCallConfig(dtype=getattr(jnp, args.dtype))
    mesh = _make_mesh(args)

    if mesh is not None:
        from repro.launch import steps as steps_mod
        plan, plan_mode = steps_mod._train_plan(args.arch, mesh, args.mode)
        M = plan.clients(mesh) if plan.client else 1
        if M != args.clients:
            print(f"[train] mesh plan '{plan_mode}' fixes M={M} clients "
                  f"(--clients {args.clients} ignored)", flush=True)
    else:
        M = args.clients

    spec, local_steps, step_times = _resolve_spec(args, M)
    model = build(cfg, call)

    wire = engine.bytes_on_wire(spec, jax.eval_shape(model.init,
                                                     jax.random.PRNGKey(0)))
    print(f"[train] sync payload/client/round: {wire['total_bytes']/1e6:.3f} "
          f"MB ({wire['compression_x']}x vs uncompressed)", flush=True)
    sim_t = federated.simulated_round_time(
        step_times, local_steps or [args.h_local] * M,
        barrier="async" if args.async_buffer else "sync",
        buffer_rounds=args.async_buffer)
    if args.het_model != "uniform" or args.async_buffer:
        print(f"[train] het={args.het_model} H_m="
              f"{list(local_steps) if local_steps else 'uniform'} "
              f"buffer={args.async_buffer} simulated round time {sim_t:.3f} "
              f"(rel. units)", flush=True)

    if mesh is not None:
        shape = ShapeConfig(f"train_cli_{args.seq}", args.seq,
                            M * args.batch, "train")
        built = steps_mod.build_train_step(
            args.arch, shape, mesh, mode=args.mode, engine_spec=spec,
            reduced=args.reduced, h_local=args.h_local, call=call,
            objective=_objective_spec(args), labeled_frac=args.labeled_frac,
            seed=args.seed + 1)
        spec = built.meta["engine_spec"]   # fused fallback may have applied
        if "fused_kernel_fallback" in built.meta:
            print(f"[train] fused kernel fallback: "
                  f"{built.meta['fused_kernel_fallback']}", flush=True)
        state_shardings, batch_shardings = built.in_shardings
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate)
        print(f"[train] mesh {dict(mesh.shape)} mode={built.meta['mode']} "
              f"M={M} b_client={args.batch} devices={mesh.size}", flush=True)
        run_step = lambda state, batch, r: jitted(state, batch)
        put_batch = lambda nb: jax.device_put(nb, batch_shardings)
    else:
        client_obj = objectives.build_objective(_objective_spec(args),
                                                model=model)
        round_step = jax.jit(engine.build_round_step(model.loss, spec,
                                                     objective=client_obj))
        root = jax.random.PRNGKey(args.seed + 1)
        # fold_in(root, r), NOT sequential splits from process start: a
        # restored run replays exactly round r's key (DESIGN.md §9)
        run_step = lambda state, batch, r: round_step(
            state, batch, jax.random.fold_in(root, r))
        put_batch = lambda nb: jax.tree.map(jnp.asarray, nb)

    state = engine.init_state(jax.random.PRNGKey(args.seed), model.init, spec,
                              M)
    start_round = 0
    if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
        state, start_round = ckpt_lib.restore(args.ckpt, state)
        print(f"[train] restored round {start_round}")
    if mesh is not None:
        state = jax.device_put(state, state_shardings)

    stream = TokenStream(cfg.vocab_size, seed=args.seed)
    loader = LMRoundLoader(stream, M, args.batch,
                           labeled_frac=args.labeled_frac, seed=args.seed)
    tokens_round = M * args.h_local * args.batch * args.seq
    log = []
    t0 = time.time()
    with mesh if mesh is not None else contextlib.nullcontext():
        for r in range(start_round, args.rounds):
            nb = loader.round_batch(r, args.h_local, args.seq)
            if cfg.family in ("audio", "vlm"):
                nb = _wrap_modal(cfg, nb, args.seed, r)
            batch = put_batch(nb)
            tw = time.perf_counter()
            state, metrics = run_step(state, batch, r)
            loss = float(metrics["loss"])          # blocks on the round
            wall = time.perf_counter() - tw
            drift = float(metrics["client_drift"])
            rec = {"round": r, "loss": loss, "drift": drift}
            extra = ""
            if "step_norm" in metrics:
                rec["step_norm"] = float(metrics["step_norm"])
                extra = f" step {rec['step_norm']:.3e}"
            if "compression_err" in metrics:
                rec["compression_err"] = float(metrics["compression_err"])
            if "staleness" in metrics:
                rec["staleness"] = float(metrics["staleness"])
            if "ctrl_h_m" in metrics:
                # realized knob trajectory (DESIGN.md §10). Per-round
                # sim_round_time (not a cumulative) so a resumed run logs
                # bitwise-identical rounds; consumers sum it themselves.
                h_real = [int(h) for h in np.asarray(metrics["ctrl_h_m"])]
                b_real = int(metrics["ctrl_b_eff"])
                rec["ctrl_h_m"] = h_real
                rec["ctrl_h_t"] = int(metrics["ctrl_h_t"])
                rec["ctrl_k"] = round(float(metrics["ctrl_k"]), 6)
                rec["ctrl_b_eff"] = b_real
                rec["ctrl_gns_ema"] = round(float(metrics["ctrl_gns_ema"]), 6)
                extra += f" H_t {rec['ctrl_h_t']}"
                rec["sim_round_time"] = round(federated.simulated_round_time(
                    step_times, h_real,
                    barrier="async" if args.async_buffer else "sync",
                    buffer_rounds=b_real or args.async_buffer), 4)
            else:
                rec["sim_time"] = round((r + 1) * sim_t, 4)  # simulated clock
            # measurements — the only non-deterministic log fields (§9)
            rec["wall_s"] = round(wall, 4)
            rec["tokens_per_s"] = round(tokens_round / wall, 1)
            log.append(rec)
            print(f"[train] round {r:4d} loss {loss:.4f} drift {drift:.3e}"
                  f"{extra} ({time.time()-t0:.1f}s)", flush=True)
            if args.ckpt and (r + 1) % args.ckpt_every == 0:
                ckpt_lib.save(args.ckpt, r + 1, state)
    if args.ckpt:
        ckpt_lib.save(args.ckpt, args.rounds, state)
    if args.log:
        with open(args.log, "w") as f:
            json.dump(log, f)
    return log


def _wrap_modal(cfg, nb, seed, r):
    """audio/vlm batches need embedding/patch stubs around the token stream.

    Seeded from (seed, round): every round draws fresh modal inputs (a fresh
    ``default_rng(0)`` here used to freeze audio/vlm training on ONE batch
    forever), and the same round reproduces bitwise on resume (DESIGN.md §9).
    The trailing 1 separates this stream from TokenStream.batch_at(r)'s.
    """
    rng = np.random.default_rng((seed, r, 1))
    M, H, b, S = nb["tokens"].shape
    lab = {"labeled": nb["labeled"]} if "labeled" in nb else {}
    if cfg.family == "audio":
        emb = rng.normal(size=(M, H, b, S, cfg.d_model)).astype(np.float32) * .02
        return {"embeds": emb, "labels": nb["labels"], **lab}
    P = cfg.frontend_tokens
    # batch_struct contract: P patch embeddings prepended to S−P text tokens,
    # so the model's position budget stays at --seq on both launch paths
    patches = rng.normal(size=(M, H, b, P, cfg.d_model)).astype(np.float32) * .02
    return {"patches": patches, "tokens": nb["tokens"][..., :S - P],
            "labels": nb["labels"][..., :S - P], **lab}


if __name__ == "__main__":
    main()
