"""End-to-end SAVIC training driver.

On real TPU hardware this runs the full assigned configs on the production
mesh; on CPU (this container) it runs reduced configs with synthetic LM data —
the same code path: config -> model -> SAVIC round loop -> checkpoint.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 20 --h-local 4 --clients 4 --batch 8 --seq 128 \
      --preconditioner adam --scaling global --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.core import PrecondConfig, SavicConfig, savic
from repro.data import LMRoundLoader, TokenStream
from repro.models import ModelCallConfig, build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--h-local", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preconditioner", default="adam",
                    choices=["identity", "adam", "rmsprop", "oasis",
                             "adahessian", "adagrad"])
    ap.add_argument("--scaling", default="global", choices=["global", "local"])
    ap.add_argument("--gamma", type=float, default=3e-3)
    ap.add_argument("--beta1", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=1e-8)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    call = ModelCallConfig(dtype=getattr(jnp, args.dtype))
    model = build(cfg, call)

    pc = PrecondConfig(kind=args.preconditioner, alpha=args.alpha)
    sv = SavicConfig(gamma=args.gamma, beta1=args.beta1, scaling=args.scaling)
    round_step = jax.jit(savic.build_round_step(model.loss, pc, sv))

    state = savic.init_state(jax.random.PRNGKey(args.seed), model.init, pc, sv,
                             args.clients)
    start_round = 0
    if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
        state, start_round = ckpt_lib.restore(args.ckpt, state)
        print(f"[train] restored round {start_round}")

    stream = TokenStream(cfg.vocab_size, seed=args.seed)
    loader = LMRoundLoader(stream, args.clients, args.batch)
    key = jax.random.PRNGKey(args.seed + 1)
    log = []
    t0 = time.time()
    for r in range(start_round, args.rounds):
        key, k = jax.random.split(key)
        nb = loader.round_batch(args.h_local, args.seq)
        if cfg.family in ("audio", "vlm"):
            nb = _wrap_modal(cfg, nb, args)
        batch = jax.tree.map(jnp.asarray, nb)
        state, metrics = round_step(state, batch, k)
        loss = float(metrics["loss"])
        drift = float(metrics["client_drift"])
        log.append({"round": r, "loss": loss, "drift": drift})
        print(f"[train] round {r:4d} loss {loss:.4f} drift {drift:.3e} "
              f"({time.time()-t0:.1f}s)", flush=True)
        if args.ckpt and (r + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt, r + 1, state)
    if args.ckpt:
        ckpt_lib.save(args.ckpt, args.rounds, state)
    if args.log:
        with open(args.log, "w") as f:
            json.dump(log, f)
    return log


def _wrap_modal(cfg, nb, args):
    """audio/vlm batches need embedding/patch stubs around the token stream."""
    rng = np.random.default_rng(0)
    M, H, b, S = nb["tokens"].shape
    if cfg.family == "audio":
        emb = rng.normal(size=(M, H, b, S, cfg.d_model)).astype(np.float32) * .02
        return {"embeds": emb, "labels": nb["labels"]}
    P = cfg.frontend_tokens
    patches = rng.normal(size=(M, H, b, P, cfg.d_model)).astype(np.float32) * .02
    return {"patches": patches, "tokens": nb["tokens"], "labels": nb["labels"]}


if __name__ == "__main__":
    main()
