"""End-to-end training driver for every engine method.

On real TPU hardware this runs the full assigned configs on the production
mesh; on CPU (this container) it runs reduced configs with synthetic LM data —
the same code path: config -> model -> engine round loop -> checkpoint.

``--method`` selects the round composition (ClientLoop × SyncStrategy ×
ServerUpdate, see core/engine.py): savic (Algorithm 1), the FedOpt baselines
of [42] (fedadagrad / fedadam / fedyogi), and the composed local-adam
scenario (locally-scaled clients + adaptive server, cf. 2409.13155).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 20 --h-local 4 --clients 4 --batch 8 --seq 128 \
      --preconditioner adam --scaling global --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --method local-adam --rounds 5 --clients 2 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.core import PrecondConfig, SavicConfig, engine, savic
from repro.data import LMRoundLoader, TokenStream
from repro.data import federated
from repro.models import ModelCallConfig, build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--h-local", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--method", default="savic", choices=list(engine.METHODS))
    ap.add_argument("--preconditioner", default="adam",
                    choices=["identity", "adam", "rmsprop", "oasis",
                             "adahessian", "adagrad"])
    ap.add_argument("--scaling", default="global", choices=["global", "local"])
    ap.add_argument("--gamma", type=float, default=3e-3,
                    help="client step size (γ / η_l)")
    ap.add_argument("--beta1", type=float, default=0.9,
                    help="client heavy-ball momentum (savic/local-adam)")
    ap.add_argument("--alpha", type=float, default=1e-2)
    ap.add_argument("--server-eta", type=float, default=0.1,
                    help="adaptive-server lr η (fed*/local-adam)")
    ap.add_argument("--server-beta1", type=float, default=0.9,
                    help="adaptive-server momentum β₁ (fed*/local-adam)")
    ap.add_argument("--tau", type=float, default=1e-3,
                    help="adaptive-server floor τ (fed*/local-adam)")
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--sync-dtype", default="")
    ap.add_argument("--compression", default="none",
                    choices=list(engine.COMPRESSION_OPS),
                    help="client->server delta compression (engine-level: "
                         "applies to every method)")
    ap.add_argument("--compression-k", type=float, default=0.1,
                    help="kept fraction per leaf for topk/randk")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry the EF residual buffer in the state pytree")
    ap.add_argument("--het-model", default="uniform",
                    choices=list(federated.SYSTEMS_MODELS),
                    help="systems-heterogeneity model for per-client local "
                         "steps H_m (engine-level: applies to every method)")
    ap.add_argument("--het-sigma", type=float, default=0.6,
                    help="lognormal straggler sigma for --het-model lognormal")
    ap.add_argument("--het-seed", type=int, default=0)
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="server staleness buffer depth B (0 = synchronous)")
    ap.add_argument("--staleness-weight", default="constant",
                    choices=list(engine.STALENESS_WEIGHTINGS),
                    help="staleness weighting s(tau) for the delta FIFO")
    ap.add_argument("--use-fused-kernel", action="store_true",
                    help="flat-buffer fused client loop: one Pallas pass per "
                         "local step, every preconditioner kind (DESIGN.md "
                         "§7; bit-identical in fp32). On mesh launches "
                         "(steps.py) model-/FSDP-sharded plans run it "
                         "per-shard via shard_map; this single-host driver "
                         "uses the unsharded flat view")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    call = ModelCallConfig(dtype=getattr(jnp, args.dtype))
    model = build(cfg, call)

    comp = engine.CompressionSpec(op=args.compression, k=args.compression_k,
                                  error_feedback=args.error_feedback)
    asy = engine.AsyncSpec(buffer_rounds=args.async_buffer,
                           weighting=args.staleness_weight)
    local_steps = None
    step_times = federated.sample_step_times(
        args.het_model, args.clients, seed=args.het_seed, sigma=args.het_sigma)
    if args.het_model != "uniform":
        local_steps = tuple(int(h) for h in federated.local_steps_from_times(
            step_times, args.h_local))
    if args.method == "savic":
        pc = PrecondConfig(kind=args.preconditioner, alpha=args.alpha)
        sv = SavicConfig(gamma=args.gamma, beta1=args.beta1,
                         scaling=args.scaling,
                         participation=args.participation,
                         sync_dtype=args.sync_dtype,
                         use_fused_kernel=args.use_fused_kernel,
                         compression=comp, local_steps=local_steps,
                         asynchrony=asy)
        spec = savic.engine_spec(pc, sv)
    else:
        spec = engine.method_spec(
            args.method, pc_kind=args.preconditioner, alpha=args.alpha,
            beta1=args.beta1, eta=args.server_eta, eta_l=args.gamma,
            tau=args.tau, server_beta1=args.server_beta1,
            participation=args.participation,
            sync_dtype=args.sync_dtype, compression=comp,
            local_steps=local_steps, asynchrony=asy,
            use_fused_kernel=args.use_fused_kernel)
    round_step = jax.jit(engine.build_round_step(model.loss, spec))
    wire = engine.bytes_on_wire(spec, jax.eval_shape(model.init,
                                                     jax.random.PRNGKey(0)))
    print(f"[train] sync payload/client/round: {wire['total_bytes']/1e6:.3f} "
          f"MB ({wire['compression_x']}x vs uncompressed)", flush=True)
    sim_t = federated.simulated_round_time(
        step_times, local_steps or [args.h_local] * args.clients,
        barrier="async" if args.async_buffer else "sync",
        buffer_rounds=args.async_buffer)
    if args.het_model != "uniform" or args.async_buffer:
        print(f"[train] het={args.het_model} H_m="
              f"{list(local_steps) if local_steps else 'uniform'} "
              f"buffer={args.async_buffer} simulated round time {sim_t:.3f} "
              f"(rel. units)", flush=True)

    state = engine.init_state(jax.random.PRNGKey(args.seed), model.init, spec,
                              args.clients)
    start_round = 0
    if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
        state, start_round = ckpt_lib.restore(args.ckpt, state)
        print(f"[train] restored round {start_round}")

    stream = TokenStream(cfg.vocab_size, seed=args.seed)
    loader = LMRoundLoader(stream, args.clients, args.batch)
    key = jax.random.PRNGKey(args.seed + 1)
    log = []
    t0 = time.time()
    for r in range(start_round, args.rounds):
        key, k = jax.random.split(key)
        nb = loader.round_batch(args.h_local, args.seq)
        if cfg.family in ("audio", "vlm"):
            nb = _wrap_modal(cfg, nb, args)
        batch = jax.tree.map(jnp.asarray, nb)
        state, metrics = round_step(state, batch, k)
        loss = float(metrics["loss"])
        drift = float(metrics["client_drift"])
        rec = {"round": r, "loss": loss, "drift": drift}
        extra = ""
        if "step_norm" in metrics:
            rec["step_norm"] = float(metrics["step_norm"])
            extra = f" step {rec['step_norm']:.3e}"
        if "compression_err" in metrics:
            rec["compression_err"] = float(metrics["compression_err"])
        if "staleness" in metrics:
            rec["staleness"] = float(metrics["staleness"])
        rec["sim_time"] = round((r + 1) * sim_t, 4)  # simulated wall clock
        log.append(rec)
        print(f"[train] round {r:4d} loss {loss:.4f} drift {drift:.3e}"
              f"{extra} ({time.time()-t0:.1f}s)", flush=True)
        if args.ckpt and (r + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt, r + 1, state)
    if args.ckpt:
        ckpt_lib.save(args.ckpt, args.rounds, state)
    if args.log:
        with open(args.log, "w") as f:
            json.dump(log, f)
    return log


def _wrap_modal(cfg, nb, args):
    """audio/vlm batches need embedding/patch stubs around the token stream."""
    rng = np.random.default_rng(0)
    M, H, b, S = nb["tokens"].shape
    if cfg.family == "audio":
        emb = rng.normal(size=(M, H, b, S, cfg.d_model)).astype(np.float32) * .02
        return {"embeds": emb, "labels": nb["labels"]}
    P = cfg.frontend_tokens
    patches = rng.normal(size=(M, H, b, P, cfg.d_model)).astype(np.float32) * .02
    return {"patches": patches, "tokens": nb["tokens"], "labels": nb["labels"]}


if __name__ == "__main__":
    main()
