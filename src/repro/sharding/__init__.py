from repro.sharding.partitioner import (AxisPlan, batch_pspecs, cache_pspecs,  # noqa
                                        params_pspecs, plan_for,
                                        serve_batch_pspecs, to_shardings)
