"""Sharding rules: params / optimizer state / batches / caches -> PartitionSpec.

Axis semantics (see DESIGN.md §2): mesh axes are partitioned into
``client_axes`` (SAVIC clients — cross-client traffic only at sync),
``batch_axes`` (intra-client data parallel / FSDP) and ``model_axes``
(tensor/expert parallel inside a replica).

Parameters in SAVIC training carry a leading client dim M (sharded over
``client_axes``); serving params have no client dim. Rules are path-based
with config-aware divisibility checks: a dim is only sharded if divisible by
the mesh-axes extent, so every assigned arch lowers on the fixed production
mesh without uneven-sharding surprises.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.utils.tree import tree_from_paths


@dataclasses.dataclass(frozen=True)
class AxisPlan:
    """How mesh axes are assigned to roles for a given run mode."""
    client: Tuple[str, ...] = ()      # SAVIC client axes (M = prod of sizes)
    batch: Tuple[str, ...] = ()       # intra-client DP/FSDP axes
    model: Tuple[str, ...] = ("model",)
    fsdp_params: bool = False         # additionally shard params over batch axes

    def clients(self, mesh: Mesh) -> int:
        m = 1
        for a in self.client:
            m *= mesh.shape[a]
        return m


def plan_for(mode: str, multi_pod: bool) -> AxisPlan:
    """Canonical plans. mode: paper | paper_fsdp | diloco | plain."""
    if mode == "paper":
        client = ("pod", "data") if multi_pod else ("data",)
        return AxisPlan(client=client, batch=(), model=("model",))
    if mode == "paper_fsdp":
        # SAVIC clients on data(+pod); INSIDE a client the 16 "model"-axis
        # chips do batch-parallel + FSDP instead of TP — the right layout for
        # archs whose head counts don't divide the model axis (beyond-paper
        # §Perf optimization; see EXPERIMENTS.md).
        client = ("pod", "data") if multi_pod else ("data",)
        return AxisPlan(client=client, batch=("model",), model=(),
                        fsdp_params=True)
    if mode == "diloco":
        if not multi_pod:
            raise ValueError("diloco mode needs the multi-pod mesh (client=pod)")
        return AxisPlan(client=("pod",), batch=("data",), model=("model",))
    if mode == "plain":
        batch = ("pod", "data") if multi_pod else ("data",)
        return AxisPlan(client=(), batch=batch, model=("model",), fsdp_params=True)
    raise ValueError(mode)


def _axsize(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(axes: Sequence[str], dim: int, mesh: Mesh):
    """Return axes tuple if dim divisible by their extent, else None."""
    if not axes:
        return None
    return tuple(axes) if dim % _axsize(mesh, axes) == 0 else None


def _param_spec(path: str, shape, cfg: ModelConfig, mesh: Mesh, plan: AxisPlan,
                stacked: bool, client_dim: bool):
    """PartitionSpec for one parameter leaf.

    ``stacked``: leading layer dim (inside blocks/stack). ``client_dim``:
    leading SAVIC client dim present.
    """
    mdl = plan.model
    fsdp = plan.batch if plan.fsdp_params else ()

    lead = []
    if client_dim:
        lead.append(tuple(plan.client) if plan.client else None)
    core = list(shape[len(lead):])
    if stacked:
        lead.append(None)               # layer-scan dim never sharded
        core = core[1:]

    def spec(*dims):
        return P(*lead, *dims)

    nd = len(core)
    # ---- rules (most-specific first) ----------------------------------------
    if re.search(r"experts/(wg|wu)$", path):        # (E, d, f)
        e = _maybe(mdl, core[0], mesh)
        if e:
            return spec(e, _maybe(fsdp, core[1], mesh), None)
        return spec(None, _maybe(fsdp, core[1], mesh), _maybe(mdl, core[2], mesh))
    if re.search(r"experts/wd$", path):             # (E, f, d)
        e = _maybe(mdl, core[0], mesh)
        if e:
            return spec(e, None, _maybe(fsdp, core[2], mesh))
        return spec(None, _maybe(mdl, core[1], mesh), _maybe(fsdp, core[2], mesh))
    if re.search(r"router/w$", path):               # (d, E) replicate
        return spec(None, None)
    if re.search(r"(wq_b|wk_b|wv_b)/w$", path) and nd == 3:  # MLA (r, H, nd)
        return spec(None, _maybe(mdl, core[1], mesh), None)
    if re.search(r"(wq|wk|wv)/w$", path) and nd == 3:   # (d, H, hd) head-major
        return spec(_maybe(fsdp, core[0], mesh), _maybe(mdl, core[1], mesh),
                    None)
    if re.search(r"(wq|wk|wv)/b$", path) and nd == 2:   # (H, hd)
        return spec(_maybe(mdl, core[0], mesh), None)
    if re.search(r"wo/w$", path) and nd == 3:           # (H, hd, d)
        return spec(_maybe(mdl, core[0], mesh), None,
                    _maybe(fsdp, core[2], mesh))
    if re.search(r"embed/(table)$", path):          # (V, d)
        return spec(_maybe(mdl, core[0], mesh), _maybe(fsdp, core[1], mesh))
    if re.search(r"embed/head$", path):             # (d, V)
        return spec(_maybe(fsdp, core[0], mesh), _maybe(mdl, core[1], mesh))
    if re.search(r"(wq|wq_b|wk_b|wv_b|wg|wu|wx|wz)/w$", path):   # (d_in, big)
        return spec(_maybe(fsdp, core[0], mesh), _maybe(mdl, core[1], mesh))
    if re.search(r"(wk|wv)/w$", path):              # kv proj: shard if divisible
        return spec(_maybe(fsdp, core[0], mesh), _maybe(mdl, core[1], mesh))
    if re.search(r"(wo|wd)/w$", path):              # (big, d)
        return spec(_maybe(mdl, core[0], mesh), _maybe(fsdp, core[1], mesh))
    if re.search(r"(wq_a|wkv_a|wB|wC|wdt)/w$", path):  # (d, small) replicate-ish
        return spec(_maybe(fsdp, core[0], mesh), None)
    if re.search(r"conv_x$", path):                 # (d_in, K)
        return spec(_maybe(mdl, core[0], mesh), None)
    if nd == 2:
        return spec(None, None)
    if nd == 1 or nd == 0:
        return spec(*([None] * nd))
    return spec(*([None] * nd))


def params_pspecs(cfg: ModelConfig, params_shape, mesh: Mesh, plan: AxisPlan,
                  client_dim: bool):
    """PartitionSpec tree matching a params (shape-)tree."""

    def one(path, leaf):
        stacked = "/stack/" in f"/{path}/"
        return _param_spec(path, leaf.shape, cfg, mesh, plan, stacked,
                           client_dim)

    return tree_from_paths(params_shape, one)


def batch_pspecs(batch_shape, mesh: Mesh, plan: AxisPlan, client_dim: bool,
                 has_h_dim: bool = True):
    """SAVIC round batch (M, H, b, ...): client dim over client axes, H (local
    steps) never sharded, per-client batch dim b over batch axes."""

    def one(path, leaf):
        dims = []
        shape = leaf.shape
        if client_dim:
            dims.append(tuple(plan.client) if plan.client else None)
        if has_h_dim:
            dims.append(None)                      # H local-step dim
        i = len(dims)
        if len(shape) > i:
            dims.append(_maybe(plan.batch, shape[i], mesh))
        dims += [None] * (len(shape) - len(dims))
        return P(*dims)

    return tree_from_paths(batch_shape, one)


def serve_batch_pspecs(batch_shape, mesh: Mesh, plan: AxisPlan):
    """Serving inputs: batch dim over (client+batch) axes jointly if divisible,
    else replicated (long_500k B=1)."""
    axes = tuple(plan.client) + tuple(plan.batch)

    def one(path, leaf):
        shape = leaf.shape
        dims = [_maybe(axes, shape[0], mesh)] if shape else []
        dims += [None] * (len(shape) - len(dims))
        return P(*dims)

    return tree_from_paths(batch_shape, one)


def cache_pspecs(cfg: ModelConfig, cache_shape, mesh: Mesh, plan: AxisPlan):
    """Decode caches.

    Layout (L, B, S, H, D) or mamba state dicts. Strategy: shard batch over
    (client+batch) axes when divisible; otherwise shard the sequence dim
    (long_500k B=1 -> sequence-sharded KV, GSPMD inserts the online-softmax
    collectives); shard heads/state over model axes when divisible.
    """
    daxes = tuple(plan.client) + tuple(plan.batch)

    def one(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if "mamba" in path:
            # (L, B, ...) state/conv tails: batch over daxes, heads over model
            dims = [None, _maybe(daxes, shape[1], mesh)]
            if "h" in path.split("/")[-1] and nd >= 3:
                dims.append(_maybe(plan.model, shape[2], mesh))
            dims += [None] * (nd - len(dims))
            return P(*dims)
        if nd >= 4:  # (L, B, S, H[, D]) attention KV / (napp,B,S,H,D) shared
            b = _maybe(daxes, shape[1], mesh)
            s = None if b else _maybe(daxes, shape[2], mesh)
            h = _maybe(plan.model, shape[3], mesh)
            dims = [None, b, s, h] + [None] * (nd - 4)
            return P(*dims)
        if nd == 3:  # (L, B, S) or (L, B, r) latents: (None, batch, seq?)
            b = _maybe(daxes, shape[1], mesh)
            s = None if b else _maybe(daxes, shape[2], mesh)
            return P(None, b, s)
        return P(*([None] * nd))

    return tree_from_paths(cache_shape, one)


def opt_state_like_params(pspecs):
    """Optimizer state (momentum, preconditioner stats) shards like params."""
    return pspecs


def to_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
