"""FedOpt baseline — Algorithm 2 of Reddi et al. [42] (the paper §5.2 compares
against it): FedAdaGrad / FedAdam / FedYogi.

Clients run K plain local SGD steps from the server point x_t; the server
treats Δ_t = mean_m (x_{m,K} - x_t) as a pseudo-gradient and applies an
adaptive update:

    m_t = β₁ m_{t-1} + (1-β₁) Δ_t
    v_t = v_{t-1} + Δ_t²                     (FedAdaGrad)
    v_t = β₂ v_{t-1} + (1-β₂) Δ_t²           (FedAdam)
    v_t = v_{t-1} - (1-β₂) Δ_t² sign(v_{t-1}-Δ_t²)   (FedYogi)
    x_{t+1} = x_t + η m_t / (√v_t + τ)

This module exists so the paper's §5.2 critique is testable: the benchmark
harness sweeps τ→0 and shows the iterate stalls (x_{t+1} ≈ x_t) when
v_{-1} = τ², as the paper argues.

Since the round-engine refactor this is a thin method definition over
``core/engine.py``: FedOpt = plain-SGD ClientLoop (momentum reset each round)
× SyncStrategy × adaptive ServerUpdate. The public API keeps the original
single-replica state layout ``{"params", "m", "v", "round"}``; the adapter
broadcasts to the engine's (M, ...) client layout at round time and projects
back (clients are identical at round boundaries, so the projection is exact).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import engine


@dataclasses.dataclass(frozen=True)
class FedOptConfig:
    server_opt: str = "adam"       # adagrad | adam | yogi
    eta: float = 0.1               # server lr η
    eta_l: float = 0.05            # client lr η_l
    beta1: float = 0.9
    beta2: float = 0.999
    tau: float = 1e-3              # adaptivity floor τ
    v_init: float = None           # v_{-1}; default τ² (the paper's pain point)
    client_momentum: float = 0.0
    # per-client local-step vector H_m (systems heterogeneity, DESIGN.md §5).
    # The staleness buffer is spec'd at the engine level only: this module's
    # historical single-replica state layout has no buffer slot — use
    # engine.method_spec(..., async_buffer=) for buffered FedOpt.
    local_steps: tuple = None


def engine_spec(cfg: FedOptConfig) -> engine.EngineSpec:
    """FedOptConfig -> the engine's three-layer spec."""
    spec = engine.method_spec(
        "fed" + cfg.server_opt, eta=cfg.eta, eta_l=cfg.eta_l, tau=cfg.tau,
        server_beta1=cfg.beta1, server_beta2=cfg.beta2, v_init=cfg.v_init,
        local_steps=cfg.local_steps)
    if cfg.client_momentum:
        spec = dataclasses.replace(spec, client=dataclasses.replace(
            spec.client, momentum=cfg.client_momentum))
    return spec


def init_state(key, init_params_fn, cfg: FedOptConfig):
    params = init_params_fn(key)
    v0 = cfg.v_init if cfg.v_init is not None else cfg.tau ** 2
    return {
        "params": params,
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(lambda p: jnp.full_like(p, v0), params),
        "round": jnp.int32(0),
    }


def build_round_step(loss_fn: Callable, cfg: FedOptConfig):
    """Returns round_step(state, batch, key); batch leaves (M, K, ...)."""
    spec = engine_spec(cfg)
    eng_step = engine.build_round_step(loss_fn, spec)

    def round_step(state, batch, key):
        M = jax.tree.leaves(batch)[0].shape[0]
        params_m = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (M,) + p.shape),
            state["params"])
        eng_state = {
            "params": params_m,
            "mom": jax.tree.map(jnp.zeros_like, params_m),
            "precond": {"t": state["round"]},
            "server": {"m": state["m"], "v": state["v"]},
            "round": state["round"],
        }
        eng_state, met = eng_step(eng_state, batch, key)
        new_state = {
            "params": engine.average_params(eng_state),
            "m": eng_state["server"]["m"],
            "v": eng_state["server"]["v"],
            "round": eng_state["round"],
        }
        return new_state, {"loss": met["loss"], "step_norm": met["step_norm"]}

    return round_step
