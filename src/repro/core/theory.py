"""Closed-form predictors from the paper's theorems — used by the benchmark
harness to validate the implementation against the paper's own claims.

Theorem 1 (identical data, μ>0, γ ≤ α/4L):
    E‖x̂_T − x*‖² = O( (1−γμ/2Γ)^T (Γ/α)‖x₀−x*‖²
                       + γΓσ²/(α²μM) + Lγ²Γ(H−1)σ²/(μα³) )

Theorem 2 (heterogeneous, γ ≤ α/(10(H−1)L)):
    E[f(x̄) − f*] ≤ (1−γμ/2Γ)^T Γ‖x₀−x*‖²/γ + γσ²_dif(9(H−1)/2α + 8/Mα)

These are upper bounds with unspecified constants; the harness fits the
*shape*: (a) geometric contraction factor ≈ (1−γμ/2Γ) during the transient,
(b) noise-ball ∝ γ/M with an additional (H−1)γ² term, (c) α-sensitivity.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    mu: float
    L: float
    sigma2: float          # Assumption-2 variance σ²
    alpha: float           # preconditioner floor
    Gamma: float           # preconditioner cap
    M: int
    H: int

    @property
    def kappa(self):
        return self.L / self.mu

    @property
    def kappa_hat(self):
        return self.L * self.Gamma / (self.mu * self.alpha)


def thm1_rate(spec: ProblemSpec, gamma: float) -> float:
    """Per-step contraction factor of the bias term."""
    return 1.0 - gamma * spec.mu / (2.0 * spec.Gamma)


def thm1_noise_ball(spec: ProblemSpec, gamma: float) -> float:
    """Stationary E‖x̂−x*‖² level (up to the theorem's absolute constants)."""
    a, G = spec.alpha, spec.Gamma
    return (4.0 * G * gamma * spec.sigma2 / (spec.mu * spec.M * a**2)
            + 8.0 * G * gamma**2 * spec.L * (spec.H - 1) * spec.sigma2
            / (spec.mu * a**3))


def thm1_gamma_max(spec: ProblemSpec) -> float:
    return spec.alpha / (4.0 * spec.L)


def thm2_gamma_max(spec: ProblemSpec) -> float:
    return spec.alpha / (10.0 * max(spec.H - 1, 1) * spec.L)


def thm2_bound(spec: ProblemSpec, gamma: float, T: int, r0: float,
               sigma2_dif: float) -> float:
    """Full Theorem-2 right-hand side (f-gap)."""
    a, G = spec.alpha, spec.Gamma
    bias = (1.0 - gamma * spec.mu / (2.0 * G)) ** T * G * r0 / gamma
    noise = gamma * sigma2_dif * (9.0 * (spec.H - 1) / (2.0 * a)
                                  + 8.0 / (spec.M * a))
    return bias + noise


def cor2_params(spec: ProblemSpec, t_extra: float = 1.0):
    """Corollary 2's (γ, T) choice: γ = Γ/(μa), a = 4κ̂ + t, T = 4a·log a."""
    a = 4.0 * spec.kappa_hat + t_extra
    gamma = spec.Gamma / (spec.mu * a)
    T = int(np.ceil(4.0 * a * np.log(max(a, np.e))))
    return gamma, T


def local_sgd_noise_ball(spec: ProblemSpec, gamma: float) -> float:
    """Unscaled Local SGD (Khaled et al. [36]) noise ball — the Γ/α-free
    comparison point the paper's §5.1 discusses."""
    return (4.0 * gamma * spec.sigma2 / (spec.mu * spec.M)
            + 8.0 * gamma**2 * spec.L * (spec.H - 1) * spec.sigma2 / spec.mu)
