"""Pluggable client objectives: semi-supervised losses for federated clients.

Real federated traffic is mostly unlabeled: each client holds a local pool of
which only a ``labeled_fraction`` carries labels (``data/federated.py::
labeled_mask``; the loader attaches a per-example 0/1 ``"labeled"`` leaf to
round batches). The engine's scaling machinery (DESIGN.md §1-§3) is
objective-agnostic — it consumes ``grad(loss)`` and nothing else — so a
client objective is just a (possibly stochastic) loss the ClientLoop
differentiates instead of the supervised one:

  supervised    the identity objective. The engine ignores the ClientObjective
                entirely and runs the exact pre-objectives program
                (``grad_fn = value_and_grad(loss_fn)``, unkeyed) — the bitwise
                contract pinned by tests/test_objectives.py.
  consistency   Π-model consistency regularization (Laine & Aila 2017; the
                ladder-network family): supervised CE over the labeled subset
                plus ``unlabeled_weight`` × the mean squared disagreement
                between the prediction on a stochastically perturbed view and
                the (stop-gradient) prediction on the clean view, over ALL
                examples.
  pseudo-label  Lee 2013 / FixMatch-style self-training: supervised CE over
                the labeled subset plus ``unlabeled_weight`` × CE against the
                model's own argmax label on UNLABELED examples whose softmax
                confidence clears ``pseudo_threshold`` (targets are
                stop-gradient; an empty gate contributes 0, not NaN).

The stochastic view draws from a PRNG key the engine derives per
(round, local step, client) — ``fold_in(step_key, _OBJECTIVE_FOLD)`` — so the
objective noise is round-addressable (DESIGN.md §9) and decoupled from the
Hutchinson probe stream. Missing ``"labeled"`` leaf = everything labeled
(masks default to 1), so a semi-supervised objective on a fully-labeled batch
degrades gracefully to supervised + regularizer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

OBJECTIVES = ("supervised", "consistency", "pseudo-label")

# decouples the objective's noise stream from the per-step key's other
# consumers (Hutchinson uses fold_in(key, 7) at sync; compression 17;
# participation 3)
_OBJECTIVE_FOLD = 11


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """Declarative knob set; ``kind="supervised"`` is the identity."""
    kind: str = "supervised"
    unlabeled_weight: float = 1.0   # λ_u on the unlabeled term
    pseudo_threshold: float = 0.9   # confidence gate (pseudo-label)
    noise_sigma: float = 0.1        # perturbation scale (consistency)

    def __post_init__(self):
        if self.kind not in OBJECTIVES:
            raise ValueError(f"objective kind {self.kind!r}; expected one of "
                             f"{OBJECTIVES}")
        if self.unlabeled_weight < 0.0:
            raise ValueError(f"unlabeled_weight={self.unlabeled_weight}; "
                             f"expected >= 0")
        if not 0.0 < self.pseudo_threshold < 1.0:
            raise ValueError(f"pseudo_threshold={self.pseudo_threshold}; "
                             f"expected in (0, 1)")
        if self.noise_sigma < 0.0:
            raise ValueError(f"noise_sigma={self.noise_sigma}; expected >= 0")

    def is_identity(self) -> bool:
        """True iff the engine must emit the exact pre-objectives program."""
        return self.kind == "supervised"


@dataclasses.dataclass(frozen=True)
class ClientObjective:
    """What the ClientLoop differentiates: ``loss(params, micro, key)``.

    ``base_loss(params, micro)`` is the plain supervised loss the objective
    wraps — the engine keeps using it for curvature probes (Hutchinson D̂
    stats) and identity short-circuits.
    """
    spec: ObjectiveSpec
    loss: Callable                  # (params, micro, key) -> scalar
    base_loss: Callable             # (params, micro) -> scalar

    def is_identity(self) -> bool:
        return self.spec.is_identity()


def _labeled_of(micro, like):
    """Per-example labeled mask: the batch's ``"labeled"`` leaf, or all-ones
    (fully supervised batch) when absent. ``like`` fixes the shape."""
    lab = micro.get("labeled") if isinstance(micro, dict) else None
    if lab is None:
        return jnp.ones(like.shape[0], jnp.float32)
    return lab.astype(jnp.float32)


def _masked_ce(logits, y, mask):
    """Mean CE over examples with mask=1 (0/0-safe: empty mask -> 0)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, y[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def classification_objective(spec: ObjectiveSpec,
                             logits_fn: Callable) -> ClientObjective:
    """Semi-supervised objective over classification microbatches
    ``{"x": (b, D), "y": (b,), ["labeled": (b,)]}``.

    ``logits_fn(params, x) -> (b, C)``. The consistency view perturbs the
    input with N(0, noise_sigma²) noise drawn from the objective key.
    """
    def base_loss(params, micro):
        return _masked_ce(logits_fn(params, micro["x"]), micro["y"],
                          jnp.ones(micro["y"].shape[0], jnp.float32))

    if spec.is_identity():
        return ClientObjective(spec=spec, loss=lambda p, mc, k: base_loss(
            p, mc), base_loss=base_loss)

    def loss(params, micro, key):
        x, y = micro["x"], micro["y"]
        labeled = _labeled_of(micro, y)
        logits = logits_fn(params, x)
        sup = _masked_ce(logits, y, labeled)
        if spec.kind == "consistency":
            x_aug = x + spec.noise_sigma * jax.random.normal(
                key, x.shape, x.dtype)
            p_clean = jax.lax.stop_gradient(jax.nn.softmax(logits, axis=-1))
            p_aug = jax.nn.softmax(logits_fn(params, x_aug), axis=-1)
            unsup = jnp.mean(jnp.sum((p_aug - p_clean) ** 2, axis=-1))
        else:  # pseudo-label
            probs = jax.nn.softmax(logits, axis=-1)
            conf = jnp.max(probs, axis=-1)
            pseudo = jax.lax.stop_gradient(jnp.argmax(logits, axis=-1))
            gate = (conf >= spec.pseudo_threshold).astype(jnp.float32) \
                * (1.0 - labeled)
            unsup = _masked_ce(logits, pseudo, gate)
        return sup + spec.unlabeled_weight * unsup

    return ClientObjective(spec=spec, loss=loss, base_loss=base_loss)


def lm_objective(spec: ObjectiveSpec, model) -> ClientObjective:
    """Semi-supervised objective over LM microbatches
    ``{"tokens": (b, S), "labels": (b, S), ["labeled": (b,)]}``.

    The labeled mask is per SEQUENCE (a client's document either has curated
    targets or not). Supervised term: the model's own masked CE with the
    labels of unlabeled sequences forced to the ignore id (-1) — bit-equal to
    ``model.loss`` when everything is labeled. Unlabeled terms run on
    ``model.logits``:

      pseudo-label  per-position argmax targets on unlabeled sequences,
                    gated by softmax confidence.
      consistency   a token-dropout view (each position independently
                    replaced by a uniform random token with prob
                    ``noise_sigma``) must match the clean predictive
                    distribution (stop-gradient) in mean squared probability.
    """
    V = model.cfg.vocab_size
    base_loss = model.loss

    if spec.is_identity():
        return ClientObjective(spec=spec, loss=lambda p, mc, k: base_loss(
            p, mc), base_loss=base_loss)

    def loss(params, micro, key):
        toks, labels = micro["tokens"], micro["labels"]
        labeled = _labeled_of(micro, labels)                   # (b,)
        lab_col = labeled[:, None]
        sup_labels = jnp.where(lab_col > 0, labels, -1)
        sup = base_loss(params, {"tokens": toks, "labels": sup_labels})
        if spec.kind == "consistency":
            logits = model.logits(params, micro)               # (b, S, V)
            k1, k2 = jax.random.split(key)
            drop = jax.random.bernoulli(k1, spec.noise_sigma, toks.shape)
            rand = jax.random.randint(k2, toks.shape, 0, V, toks.dtype)
            aug = dict(micro)
            aug["tokens"] = jnp.where(drop, rand, toks)
            p_clean = jax.lax.stop_gradient(jax.nn.softmax(logits, axis=-1))
            p_aug = jax.nn.softmax(model.logits(params, aug), axis=-1)
            unsup = jnp.mean(jnp.sum((p_aug - p_clean) ** 2, axis=-1))
        else:  # pseudo-label
            logits = model.logits(params, micro)               # (b, S, V)
            probs = jax.nn.softmax(logits, axis=-1)
            conf = jnp.max(probs, axis=-1)                     # (b, S)
            pseudo = jax.lax.stop_gradient(jnp.argmax(logits, axis=-1))
            gate = (conf >= spec.pseudo_threshold).astype(jnp.float32) \
                * (1.0 - lab_col) * (labels >= 0)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.take_along_axis(logp, pseudo[..., None],
                                      axis=-1)[..., 0]
            unsup = jnp.sum(ce * gate) / jnp.maximum(jnp.sum(gate), 1.0)
        return sup + spec.unlabeled_weight * unsup

    return ClientObjective(spec=spec, loss=loss, base_loss=base_loss)


def build_objective(spec: Optional[ObjectiveSpec], *, logits_fn=None,
                    model=None) -> Optional[ClientObjective]:
    """CLI/bench glue: None or identity spec -> None (the engine's
    pre-objectives program); otherwise dispatch on what the caller has."""
    if spec is None or spec.is_identity():
        return None
    if model is not None:
        return lm_objective(spec, model)
    if logits_fn is not None:
        return classification_objective(spec, logits_fn)
    raise ValueError("semi-supervised objective needs logits_fn or model")
