"""Adaptive communication-budget controller (DESIGN.md §10).

Every communication knob of the round engine — per-client local steps H_m,
compression fraction k, async buffer depth B — is a static spec constant.
This module adapts them *during* training as a pure, jit-compatible layer:

    ctrl_state, knobs = controller_step(spec, ctrl_state, obs)

driven by three per-round signals the engine already produces:

  * **Gradient-noise scale** from the per-client round deltas
    (Lau et al., arXiv:2406.13936 — adaptive batch-size/local-step growth):
    with Δ_m = x_{m,H_m} − x_t the ratio

        gns = (E_m‖Δ_m‖² − ‖Δ̄‖²) / ‖Δ̄‖²

    estimates noise/signal of the update stream. While its EMA exceeds
    ``noise_target`` the global step budget H_t grows geometrically
    (small cheap rounds early, full-budget rounds once noise dominates) —
    the local-step analogue of critical-batch-size growth.
  * **Error-feedback residual norm** guards the compression schedule:
    the EMA of ‖u − C(u)‖/‖u‖ (the compressor's observed contraction on its
    actual input, EF-carry included) above ``resid_guard`` grows k toward
    ``k_max``; below it, k decays toward ``k_min`` — spend bytes only when
    the residual shows the wire is dropping signal.
  * **Straggler spread** selects the async depth: with relative step times
    t_m, the spread max(t)/min(t) divided by ``spread_per_slot`` picks how
    many staleness slots b_eff ∈ [1, buffer_max] the server actually
    weights (the engine masks staleness weights to ages < b_eff).

H_m allocation is the fixed wall-clock-budget rule of
``data.federated.local_steps_from_times`` — budget = H_t · min(t), client m
runs ⌊budget/t_m⌋ steps — with one deliberate extension: when a staleness
buffer is available (``buffer_max > 0``), clients slower than the whole
budget sit the round out (H_m = 0, FedBuff semantics: their contribution
is covered by the staleness window), so the simulated round time is
bounded by H_t · min(t) instead of the slowest straggler. Without a buffer
the ≥ 1 floor of the static rule is kept.

Everything is float32/int32 state in the ``state["ctrl"]`` pytree leaf, so
checkpointing, donation and sharding flow through the existing engine
machinery unchanged, and ``tests/_reference_controller.py`` replays the
whole trajectory in numpy. ``enabled=False`` (the default) adds no state
leaf and changes no engine program — the bit-exact identity contract of
DESIGN.md §6, pinned in tests/test_controller.py.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

_TINY = 1e-12


def _ema_update(ema: float, old, new):
    """old·ema + new·(1−ema).

    NB: LLVM may contract the mul+add into an FMA (single rounding), so the
    numpy oracle (tests/_reference_controller.py) replays the float EMAs to
    within 1 ulp, not bitwise. Every INTEGER knob (H_t, H_m, b_eff) goes
    through exact python-int lookup tables below precisely so those replay
    bitwise regardless — float rounding never reaches a floor()."""
    return ema * old + (1.0 - ema) * new


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Knob schedule parameters. ``enabled=False`` is the identity."""
    enabled: bool = False
    # ---- H_m / local-step growth (Lau et al., arXiv:2406.13936) ----------
    h_min: int = 1                 # initial global step budget H_t
    h_max: int = 8                 # cap; must be <= the round's H (traced)
    noise_target: float = 1.0      # grow H_t while gns EMA exceeds this
    h_growth: float = 1.5          # geometric growth factor (>= next int)
    ema: float = 0.7               # EMA retention for gns / residual stats
    # ---- compression-k schedule, EF-residual-norm guarded ----------------
    k_min: float = 0.05
    k_max: float = 1.0             # also the initial k
    resid_guard: float = 0.5       # ‖u − C(u)‖/‖u‖ EMA above this grows k
    k_shrink: float = 0.8
    k_growth: float = 1.25
    # ---- async depth from the observed straggler spread ------------------
    buffer_max: int = 0            # 0 = depth not managed (b_eff fixed at 1)
    spread_per_slot: float = 1.0   # one staleness slot per this much spread
    # ---- the observed straggler trace (relative step times, len M) -------
    step_times: tuple = ()         # () = homogeneous clients

    def __post_init__(self):
        if self.h_min < 1 or self.h_max < self.h_min:
            raise ValueError(f"need 1 <= h_min <= h_max, got "
                             f"[{self.h_min}, {self.h_max}]")
        if not 0.0 < self.ema < 1.0:
            raise ValueError(f"ema={self.ema}; expected 0 < ema < 1")
        if not 0.0 < self.k_min <= self.k_max <= 1.0:
            raise ValueError(f"need 0 < k_min <= k_max <= 1, got "
                             f"[{self.k_min}, {self.k_max}]")
        if not 0.0 < self.k_shrink <= 1.0:
            raise ValueError(f"k_shrink={self.k_shrink}")
        if self.k_growth < 1.0:
            raise ValueError(f"k_growth={self.k_growth}; expected >= 1")
        if self.h_growth <= 1.0:
            raise ValueError(f"h_growth={self.h_growth}; expected > 1")
        if self.resid_guard <= 0.0 or self.spread_per_slot <= 0.0:
            raise ValueError("resid_guard and spread_per_slot must be > 0")
        if self.buffer_max < 0:
            raise ValueError(f"buffer_max={self.buffer_max}")
        ts = tuple(float(t) for t in self.step_times)
        if any(t <= 0.0 for t in ts):
            raise ValueError("step_times must be positive")
        object.__setattr__(self, "step_times", ts)


def half_up(x: float) -> int:
    """Half-up integer rounding — round(2.5) banker's-rounds to 2; this is 3."""
    return int(math.floor(x + 0.5))


def buffer_depth(spec: ControllerSpec) -> int:
    """Selected staleness depth b_eff from the observed straggler spread.

    One slot per ``spread_per_slot`` of max(t)/min(t), clipped to
    [1, buffer_max]; 1 when depth is unmanaged (buffer_max = 0) or the trace
    is homogeneous. A spec constant — the engine masks staleness weights to
    ages < b_eff, so a shallow selection on a mild trace costs nothing.
    """
    if spec.buffer_max <= 0:
        return 1
    spread = (max(spec.step_times) / min(spec.step_times)
              if spec.step_times else 1.0)
    return max(1, min(spec.buffer_max, half_up(spread / spec.spread_per_slot)))


def budget_table(spec: ControllerSpec, n_clients: int) -> tuple:
    """Row h = the per-client H_m vector for global budget H_t = h.

    Exact python-double math mirroring ``data.federated.local_steps_from_times``
    (budget = h · min(t), client m runs ⌊budget/t_m⌋ steps), except that with
    a staleness buffer available the ≥1 floor drops to 0 (stragglers sit the
    round out). The controller indexes this table in-trace, so the integer
    H_m schedule is independent of float32 rounding and replays bitwise in
    the numpy oracle."""
    ts = spec.step_times
    if ts and len(ts) != n_clients:
        raise ValueError(f"step_times has {len(ts)} entries for "
                         f"{n_clients} clients")
    if not ts:
        ts = (1.0,) * n_clients
    lo = 0 if spec.buffer_max > 0 else 1
    tmin = min(ts)
    return tuple(
        tuple(max(lo, min(h, int(math.floor(h * tmin / t + 1e-6))))
              for t in ts)
        for h in range(spec.h_max + 1))


def growth_table(spec: ControllerSpec) -> tuple:
    """grown[h] = min(h_max, max(h+1, half_up(h · h_growth))) — the H_t
    geometric-growth step, precomputed in exact python math."""
    return tuple(
        min(spec.h_max, max(h + 1, half_up(h * spec.h_growth)))
        for h in range(spec.h_max + 1))


def budget_h(spec: ControllerSpec, h_t, n_clients: int):
    """Per-client H_m under the wall-clock budget h_t · min(t): a traced
    lookup into the exact ``budget_table`` (h_t is a traced i32 scalar)."""
    table = jnp.asarray(budget_table(spec, n_clients), jnp.int32)
    return table[jnp.asarray(h_t, jnp.int32)]


def init_ctrl_state(spec: ControllerSpec, n_clients: int) -> dict:
    """The ``state["ctrl"]`` leaf: this-round knobs + EMA statistics.

    ``h_m``/``k``/``b_eff`` are the knobs the NEXT ``round_step`` call will
    realize; ``controller_step`` rolls them forward from the round's
    observations. All leaves are arrays, so the controller checkpoints
    bitwise through ``checkpoint.save/restore`` with zero special cases.
    """
    return {
        "t": jnp.int32(0),
        "gns_ema": jnp.float32(0.0),
        "resid_ema": jnp.float32(0.0),
        "h_t": jnp.int32(spec.h_min),
        "h_m": budget_h(spec, spec.h_min, n_clients),
        "k": jnp.float32(spec.k_max),
        "b_eff": jnp.int32(buffer_depth(spec)),
    }


def controller_step(spec: ControllerSpec, ctrl_state: dict, obs: dict):
    """Pure knob update: (ctrl_state, obs) -> (ctrl_state', knobs).

    ``obs`` holds this round's scalars, all float32:
      delta_sq_mean  E_m‖Δ_m‖² over the raw per-client round deltas
      delta_sq_avg   ‖(1/M)Σ_m Δ_m‖²
      payload_sq     Σ_m‖u_m‖² of the compressor input (0: no compression)
      resid_sq       Σ_m‖u_m − C(u_m)‖² dropped by the wire (0: none)

    Replayed by tests/_reference_controller.py (numpy oracle): integer knobs
    (H_t, H_m, b_eff) bitwise via the exact lookup tables; float EMAs to
    within 1 ulp (LLVM may contract their mul+add into an FMA).
    """
    M = ctrl_state["h_m"].shape[0]
    first = ctrl_state["t"] == 0

    # -- gradient-noise scale -> monotone H_t growth -----------------------
    d2m = jnp.asarray(obs["delta_sq_mean"], jnp.float32)
    d2a = jnp.asarray(obs["delta_sq_avg"], jnp.float32)
    gns = jnp.maximum(d2m - d2a, 0.0) / jnp.maximum(d2a, _TINY)
    gns_ema = jnp.where(first, gns,
                        _ema_update(spec.ema, ctrl_state["gns_ema"], gns))
    h_t = ctrl_state["h_t"]
    grown = jnp.asarray(growth_table(spec), jnp.int32)[h_t]
    h_t = jnp.where(gns_ema > spec.noise_target, grown, h_t)
    h_m = budget_h(spec, h_t, M)

    # -- EF-residual-norm guard -> compression-k schedule ------------------
    payload = jnp.asarray(obs["payload_sq"], jnp.float32)
    resid = jnp.asarray(obs["resid_sq"], jnp.float32)
    ratio = jnp.sqrt(resid / jnp.maximum(payload, _TINY))
    resid_ema = jnp.where(
        payload > 0.0,
        jnp.where(first, ratio,
                  _ema_update(spec.ema, ctrl_state["resid_ema"], ratio)),
        ctrl_state["resid_ema"])
    k = ctrl_state["k"]
    k = jnp.where(
        payload > 0.0,
        jnp.where(resid_ema > spec.resid_guard,
                  jnp.minimum(k * spec.k_growth, spec.k_max),
                  jnp.maximum(k * spec.k_shrink, spec.k_min)),
        k).astype(jnp.float32)

    new_state = {
        "t": ctrl_state["t"] + 1,
        "gns_ema": gns_ema.astype(jnp.float32),
        "resid_ema": resid_ema.astype(jnp.float32),
        "h_t": h_t.astype(jnp.int32),
        "h_m": h_m,
        "k": k,
        "b_eff": jnp.int32(buffer_depth(spec)),
    }
    knobs = {"h_m": h_m, "k": k, "b_eff": new_state["b_eff"]}
    return new_state, knobs
