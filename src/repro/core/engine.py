"""Generic distributed-round engine: ClientLoop × SyncStrategy × ServerUpdate.

The paper describes scaling generically — one analysis, swappable D̂ rules.
This module does the same for the *round structure*: every local method in the
repo (SAVIC / Algorithm 1, the FedOpt baselines of [42], and composed scenarios
such as Local-Adam with an adaptive server, cf. arXiv:2409.13155) is one
configuration of three orthogonal layers:

  * **ClientLoop**   — H local steps on each of M clients, ``vmap`` over M
    inside a ``lax.scan`` over H (XLA provably emits no cross-client collective
    inside the scan). The per-step update is pluggable: plain SGD, heavy-ball,
    or locally-scaled via ``preconditioner.py``. With ``use_fused_kernel`` the
    whole client state rides as per-client flat fp32 buffers and each local
    step is ONE fused Pallas pass (``kernels.ops.fused_local_step``) for every
    D̂ rule — bit-identical (fp32) to the tree path (DESIGN.md §7). On
    model-/FSDP-sharded plans the launch layer supplies a ``ShardedFlatPlan``
    and the same loop runs per shard via ``shard_map`` (per-device flat
    blocks; zero flat-buffer collectives).
  * **SyncStrategy** — the only cross-client traffic per round: full mean,
    weighted partial participation (FedAvg-style client sampling), quantized
    ``sync_dtype`` all-reduce, and a pluggable delta **compression** layer
    (``none | topk | randk | int8-stochastic``, optional EF error-feedback
    residual; DESIGN.md §4). Lifted out of SAVIC so *every* method gets them.
  * **ServerUpdate** — what the server does with the synchronized average:
    identity averaging (Algorithm 1), or an adaptive m/v server step
    (FedAdaGrad / FedAdam / FedYogi, Algorithm 2 of [42]).

Distribution contract (see DESIGN.md §2): every client-state leaf carries a
leading client dim M sharded over the plan's client axes; the global D and the
adaptive server's (m, v) are client-replicated (no M dim). The state pytree is

    {"params": (M, ...), "mom": (M, ...), "precond": {...}, "round": i32,
     ["server": {"m": (...), "v": (...)}], ["ef": (M, ...)],
     ["buffer": (B, ...)], ["ctrl": {...}]}

with the ``server`` entry present only for adaptive-server methods, the
``ef`` error-feedback residual (per-client, shaped like ``params``) present
only when the sync compression carries a residual (DESIGN.md §4), and the
``buffer`` staleness FIFO (single-replica shaped, leading B dim) present only
for a staleness-buffered server (``AsyncSpec``, DESIGN.md §5). The ClientLoop
additionally supports a per-client local-step vector H_m
(``ClientLoopSpec.local_steps``), realized as masking inside the same
scan×vmap program.

``core/savic.py`` and ``core/fedopt.py`` are thin method definitions over this
engine; new methods are a ~50-line preset (see ``method_spec``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import controller as CTRL
from repro.core.controller import ControllerSpec
from repro.core import preconditioner as PC
from repro.core.preconditioner import PrecondConfig
from repro.utils.flatten import FlatLayout, all_float32


# --------------------------------------------------------------------------- #
# Specs — one frozen dataclass per layer
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ClientLoopSpec:
    """H local steps per client: x ← x − lr·D̂⁻¹m,  m ← momentum·m + g.

    ``local_steps`` is the per-client local-step vector H_m (systems
    heterogeneity, DESIGN.md §5): client m performs ``local_steps[m]`` of the
    round's H microbatch steps and then idles at the sync barrier. Implemented
    as masking inside the scan-over-H × vmap-over-M program — one jit'd
    computation regardless of how ragged H_m is. ``None`` (or all entries
    equal to the batch's H) is the uniform regime and emits the exact
    pre-heterogeneity program.
    """
    lr: float = 0.1                # local step size (γ of Alg. 1, η_l of [42])
    momentum: float = 0.0          # heavy-ball β₁ on the client
    scaling: str = "global"        # "global" (D̂ updated at sync) | "local"
    # D-stat at sync for global scaling: "avg_grad" (from the client-averaged
    # sync gradient) | "avg_local" (average of per-client stats)
    stat_source: str = "avg_grad"
    weight_decay: float = 0.0
    grad_clip: float = 0.0         # global-norm clip per local step (0 = off)
    # flat-buffer fused local step (DESIGN.md §7): ONE Pallas pass per step
    # for every PrecondConfig kind, bit-identical (fp32) to the tree path
    use_fused_kernel: bool = False
    reset_momentum: bool = False   # zero m at round start (FedOpt clients)
    local_steps: Optional[tuple] = None  # per-client H_m (None = uniform H)

    def __post_init__(self):
        if self.scaling not in ("global", "local"):
            raise ValueError(self.scaling)
        if self.local_steps is not None:
            hs = tuple(int(h) for h in self.local_steps)
            if not hs or any(h < 1 for h in hs):
                raise ValueError(f"local_steps must be a non-empty tuple of "
                                 f"ints >= 1, got {self.local_steps!r}")
            object.__setattr__(self, "local_steps", hs)


COMPRESSION_OPS = ("none", "topk", "randk", "int8-stochastic")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Compression of the client→server round delta Δ_m = x_{m,H} − x_t.

    Operators (DESIGN.md §4; cf. arXiv:2109.05109 / arXiv:2409.13155):
      none             identity — the uncompressed sync path, bit-for-bit.
      topk             keep the k·dim largest-|Δ| entries per leaf per client
                       (biased — pair with ``error_feedback``).
      randk            keep k·dim uniformly sampled entries, rescaled by
                       dim/(k·dim) so the compressor is unbiased. With
                       ``error_feedback`` the rescale is dropped: EF needs a
                       contractive compressor, and the dim/k amplification
                       would grow the residual ~(dim/k − 1)× per round
                       (unrescaled randk is a masking sparsifier, so the EF
                       residual is its exact complement, like topk).
      int8-stochastic  per-(client, leaf) absmax/127 scale, stochastic-round
                       int8 encode + fp32 decode (unbiased). With
                       ``use_fused_kernel`` the encode+decode runs as the
                       fused Pallas ``quantize_update`` kernel.

    ``error_feedback`` carries the EF residual e_m in the state pytree
    (``state["ef"]``, leading M dim): u_m = Δ_m + e_m is compressed instead of
    Δ_m and e'_m = u_m − C(u_m) is what the wire dropped this round.
    """
    op: str = "none"
    k: float = 1.0                 # kept fraction per leaf (topk / randk)
    error_feedback: bool = False   # EF residual buffer (state["ef"])
    use_fused_kernel: bool = False # Pallas quantize_update (int8-stochastic)

    def __post_init__(self):
        if self.op not in COMPRESSION_OPS:
            raise ValueError(
                f"compression op {self.op!r}; expected one of {COMPRESSION_OPS}")
        if not 0.0 < self.k <= 1.0:
            raise ValueError(f"compression k={self.k}; expected 0 < k <= 1")

    def is_identity(self) -> bool:
        """True iff this spec provably compresses nothing. The engine then
        emits the exact uncompressed sync program (the bit-for-bit contract
        pinned by tests/test_compression.py) and carries no ``ef`` leaf."""
        return self.op == "none" or (self.op in ("topk", "randk")
                                     and self.k >= 1.0)


STALENESS_WEIGHTINGS = ("constant", "polynomial")


@dataclasses.dataclass(frozen=True)
class AsyncSpec:
    """FedBuff-style server staleness buffer (DESIGN.md §5).

    With ``buffer_rounds = B > 0`` the server keeps a delta FIFO
    ``state["buffer"]`` of the last B participation-weighted round deltas
    Δ̄(t), Δ̄(t−1), …, Δ̄(t−B+1) (single-replica shaped, leading B dim, sharded
    like one replica's params). Each round the freshly aggregated delta is
    enqueued and the server applies the staleness-weighted combination

        Δ_applied(t) = Σ_τ w_τ · Δ̄(t−τ),   w_τ ∝ s(τ)·[t ≥ τ],  Σ_τ w_τ = 1

    with s(τ) = 1 (``constant``) or (1+τ)^-poly_a (``polynomial``,
    cf. FedBuff / arXiv:2106.06639's staleness scaling). Because every delta
    transits each slot exactly once, its total applied mass is 1 — the buffer
    is a staleness-weighted smoothing of the update stream, which is what a
    lag-τ asynchronous server pace simulates in a single-program round loop.

    ``buffer_rounds = 0`` is fully synchronous and emits the exact
    pre-buffer program (identity short-circuit, same discipline as
    ``CompressionSpec.is_identity``). B = 1 holds only fresh deltas
    (staleness 0) and reduces to plain delta averaging.
    """
    buffer_rounds: int = 0         # B; 0 = fully synchronous (identity)
    weighting: str = "constant"    # staleness weight s(τ)
    poly_a: float = 0.5            # exponent for the polynomial weighting

    def __post_init__(self):
        if int(self.buffer_rounds) != self.buffer_rounds \
                or self.buffer_rounds < 0:
            raise ValueError(f"buffer_rounds={self.buffer_rounds}; expected "
                             f"an int >= 0")
        object.__setattr__(self, "buffer_rounds", int(self.buffer_rounds))
        if self.weighting not in STALENESS_WEIGHTINGS:
            raise ValueError(f"staleness weighting {self.weighting!r}; "
                             f"expected one of {STALENESS_WEIGHTINGS}")
        if self.poly_a <= 0.0:
            raise ValueError(f"poly_a={self.poly_a}; expected > 0")

    def is_identity(self) -> bool:
        """True iff no buffering happens: the engine emits the bit-exact
        synchronous program and carries no ``buffer`` leaf."""
        return self.buffer_rounds == 0


@dataclasses.dataclass(frozen=True)
class SyncSpec:
    """The weighted, optionally quantized/compressed, optionally partial,
    optionally staleness-buffered sync average.

    ``personal`` (DESIGN.md §12) is a tuple of path substrings naming
    CLIENT-RESIDENT parameter leaves — a personalization mask. A leaf whose
    "/"-joined tree path contains any pattern (e.g. ``("final_norm",)`` for
    the LM's local head) is excluded from the entire sync surface: it is
    never averaged, compressed, buffered, EF-tracked, broadcast back, or fed
    to the adaptive server — each client keeps its own copy across rounds,
    exactly like the per-client D under local scaling. The empty default
    touches nothing: the engine emits the bit-exact pre-personalization
    program.
    """
    participation: float = 1.0     # fraction of clients entering the average
    sync_dtype: str = ""           # all-reduce dtype ("" = full precision)
    average_momentum: bool = True  # also average momentum buffers at sync
    compression: CompressionSpec = CompressionSpec()
    asynchrony: AsyncSpec = AsyncSpec()
    personal: tuple = ()           # client-resident leaf path patterns

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(f"participation={self.participation}; "
                             f"expected 0 < p <= 1")
        if isinstance(self.personal, str):
            # a bare string would silently become a tuple of characters
            raise ValueError(f"personal={self.personal!r}; expected a tuple "
                             f"of path-substring patterns, not a bare string")
        pats = tuple(self.personal) if self.personal else ()
        if not all(isinstance(p, str) and p for p in pats):
            raise ValueError(f"personal={self.personal!r}; expected a tuple "
                             f"of non-empty path-substring patterns")
        object.__setattr__(self, "personal", pats)
        if self.sync_dtype:
            try:
                jnp.dtype(self.sync_dtype)
            except TypeError:
                raise ValueError(f"sync_dtype {self.sync_dtype!r} is not a "
                                 f"dtype") from None
        if not isinstance(self.compression, CompressionSpec):
            raise ValueError(f"compression must be a CompressionSpec, got "
                             f"{type(self.compression).__name__}")
        if not isinstance(self.asynchrony, AsyncSpec):
            raise ValueError(f"asynchrony must be an AsyncSpec, got "
                             f"{type(self.asynchrony).__name__}")


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """What the server does with the sync average.

    ``sync_dtype`` / ``sync_k`` compress the **server** adaptive state m/v
    (arXiv:2109.05109 regime): replicas agreeing on the adaptive server step
    only need the compressed view, so the per-round server-state sync leg
    stops scaling with the full fp32 m/v trees. ``sync_k < 1`` keeps one
    shared largest-|m| index set per leaf for both trees (a dropped
    coordinate contributes no step; its v falls back to the v_init floor);
    ``sync_dtype`` round-trips both trees through that dtype (QDQ behind
    optimization barriers, same discipline as ``SyncSpec.sync_dtype``).
    Defaults are the identity: bit-exact pre-feature program.
    """
    kind: str = "average"          # "average" (Alg. 1) | "adaptive" ([42])
    opt: str = "adam"              # adagrad | adam | yogi   (adaptive only)
    eta: float = 0.1               # server lr η
    beta1: float = 0.9
    beta2: float = 0.999
    tau: float = 1e-3              # adaptivity floor τ
    v_init: Optional[float] = None # v_{-1}; default τ² (the §5.2 pain point)
    sync_dtype: str = ""           # m/v sync dtype ("" = full precision)
    sync_k: float = 1.0            # kept fraction of the m/v trees (top-|m|)

    def __post_init__(self):
        if self.kind not in ("average", "adaptive"):
            raise ValueError(self.kind)
        if self.kind == "adaptive" and self.opt not in ("adagrad", "adam",
                                                        "yogi"):
            raise ValueError(self.opt)
        if not 0.0 < self.sync_k <= 1.0:
            raise ValueError(f"sync_k={self.sync_k}; expected 0 < k <= 1")
        if self.sync_dtype:
            try:
                jnp.dtype(self.sync_dtype)
            except TypeError:
                raise ValueError(f"sync_dtype {self.sync_dtype!r} is not a "
                                 f"dtype") from None
        if self.kind == "average" and not self.sync_identity():
            raise ValueError("server sync_dtype/sync_k compress the adaptive "
                             "m/v state; an averaging server has none")

    def sync_identity(self) -> bool:
        """True iff the server m/v state moves uncompressed (bit-exact)."""
        return not self.sync_dtype and self.sync_k >= 1.0


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    client: ClientLoopSpec = ClientLoopSpec()
    sync: SyncSpec = SyncSpec()
    server: ServerSpec = ServerSpec()
    precond: PrecondConfig = PrecondConfig(kind="identity")
    # adaptive communication-budget controller (core/controller.py,
    # DESIGN.md §10); the disabled default adds no state leaf and changes
    # no program
    controller: ControllerSpec = ControllerSpec()

    def __post_init__(self):
        if not isinstance(self.controller, ControllerSpec):
            raise ValueError(f"controller must be a ControllerSpec, got "
                             f"{type(self.controller).__name__}")


# --------------------------------------------------------------------------- #
# Method presets — each method is a ~10-line spec
# --------------------------------------------------------------------------- #

METHODS = ("savic", "fedavg", "fedadagrad", "fedadam", "fedyogi", "local-adam")


def method_spec(method: str, *, pc_kind: str = "adam", alpha: float = 1e-2,
                gamma: float = 3e-4, beta1: float = 0.9, scaling: str = "global",
                eta: float = 0.1, eta_l: float = 0.05, tau: float = 1e-3,
                server_beta1: float = 0.9, server_beta2: float = 0.999,
                v_init: Optional[float] = None,
                participation: float = 1.0, sync_dtype: str = "",
                compression="none", compression_k: float = 1.0,
                error_feedback: bool = False,
                local_steps: Optional[tuple] = None,
                asynchrony=None, async_buffer: int = 0,
                staleness_weight: str = "constant",
                server_sync_dtype: str = "", server_sync_k: float = 1.0,
                controller: Optional[ControllerSpec] = None,
                personal: tuple = (),
                use_fused_kernel: bool = False) -> EngineSpec:
    """Canonical EngineSpec for each named method.

    savic       Algorithm 1: locally-scaled heavy-ball clients, plain average.
    fedavg      plain Local SGD clients (no momentum), plain average.
    fedadagrad / fedadam / fedyogi
                Algorithm 2 of [42]: plain SGD clients (momentum reset each
                round), adaptive server on the pseudo-gradient Δ. ``beta1``
                (client heavy-ball) does not apply; server momentum is
                ``server_beta1``.
    local-adam  composed scenario (cf. 2409.13155): locally-scaled clients
                (per-client D updated every step) AND an adaptive Adam server.

    ``compression`` is either a CompressionSpec or an operator name (then
    ``compression_k`` / ``error_feedback`` fill in the rest) — every method
    gets compressed sync for free, opening the compressed-FedAdam /
    compressed-Local-Adam scenario family. ``use_fused_kernel`` enables both
    fused Pallas kernels: the client-loop ``scaled_update`` and (for
    int8-stochastic) the sync ``quantize_update``.

    ``local_steps`` (per-client H_m) and ``asynchrony`` (an AsyncSpec; or the
    ``async_buffer``/``staleness_weight`` shorthand) are engine-level too:
    every method runs under systems heterogeneity and a staleness-buffered
    server (DESIGN.md §5). ``controller`` (a ControllerSpec) and the
    ``server_sync_dtype``/``server_sync_k`` server-state compression are
    likewise method-agnostic (DESIGN.md §10). ``personal`` is the
    client-resident leaf mask (``SyncSpec.personal``, DESIGN.md §12) —
    method-agnostic too, though methods with a GLOBAL non-identity D (savic's
    default scaling) must switch to ``scaling="local"`` to combine with it.
    """
    comp = compression if isinstance(compression, CompressionSpec) \
        else CompressionSpec(op=compression, k=compression_k,
                             error_feedback=error_feedback,
                             use_fused_kernel=use_fused_kernel)
    asy = asynchrony if isinstance(asynchrony, AsyncSpec) \
        else AsyncSpec(buffer_rounds=async_buffer, weighting=staleness_weight)
    sync = SyncSpec(participation=participation, sync_dtype=sync_dtype,
                    compression=comp, asynchrony=asy)
    if method == "savic":
        # one source of truth for the SAVIC composition: SavicConfig ->
        # engine_spec in core/savic.py (lazy import; savic imports engine)
        from repro.core.savic import SavicConfig, engine_spec
        spec = engine_spec(
            PrecondConfig(kind=pc_kind, alpha=alpha),
            SavicConfig(gamma=gamma, beta1=beta1, scaling=scaling,
                        use_fused_kernel=use_fused_kernel,
                        participation=participation, sync_dtype=sync_dtype,
                        compression=comp, local_steps=local_steps,
                        asynchrony=asy))
    elif method == "fedavg":
        # plain Local SGD clients (no momentum), plain average — textbook
        # FedAvg; heavy-ball local SGD is savic with pc_kind="identity"
        spec = EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=0.0,
                                  use_fused_kernel=use_fused_kernel,
                                  local_steps=local_steps),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="average"),
            precond=PrecondConfig(kind="identity"))
    elif method in ("fedadagrad", "fedadam", "fedyogi"):
        spec = EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=0.0, reset_momentum=True,
                                  use_fused_kernel=use_fused_kernel,
                                  local_steps=local_steps),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="adaptive", opt=method[3:], eta=eta,
                              beta1=server_beta1, beta2=server_beta2, tau=tau,
                              v_init=v_init, sync_dtype=server_sync_dtype,
                              sync_k=server_sync_k),
            precond=PrecondConfig(kind="identity"))
    elif method == "local-adam":
        spec = EngineSpec(
            client=ClientLoopSpec(lr=eta_l, momentum=beta1, scaling="local",
                                  use_fused_kernel=use_fused_kernel,
                                  local_steps=local_steps),
            sync=dataclasses.replace(sync, average_momentum=False),
            server=ServerSpec(kind="adaptive", opt="adam", eta=eta,
                              beta1=server_beta1, beta2=server_beta2, tau=tau,
                              v_init=v_init, sync_dtype=server_sync_dtype,
                              sync_k=server_sync_k),
            precond=PrecondConfig(kind=pc_kind, alpha=alpha))
    else:
        raise ValueError(f"method {method}; expected one of {METHODS}")
    if spec.server.kind == "average" and (server_sync_dtype
                                          or server_sync_k < 1.0):
        raise ValueError(f"{method} has an averaging server: no adaptive "
                         f"m/v state to compress")
    if controller is not None:
        spec = dataclasses.replace(spec, controller=controller)
    if personal:
        spec = dataclasses.replace(
            spec, sync=dataclasses.replace(spec.sync,
                                           personal=tuple(personal)))
    return spec


# --------------------------------------------------------------------------- #
# State
# --------------------------------------------------------------------------- #


def init_state(key, init_params_fn, spec: EngineSpec, n_clients: int):
    """x_0^m = x_0 (identical start). Server m/v shaped like one replica."""
    params = init_params_fn(key)
    params_m = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape), params)
    mom = jax.tree.map(jnp.zeros_like, params_m)
    if spec.client.scaling == "local":
        pstate = PC.init_state(spec.precond, params_m)  # per-client D (M dim)
        if "d" in pstate:
            pstate["t"] = jnp.zeros((n_clients,), jnp.int32)  # per-client t
    else:
        pstate = PC.init_state(spec.precond, params)    # global D (no M dim)
    state = {
        "params": params_m,
        "mom": mom,
        "precond": pstate,
        "round": jnp.int32(0),
    }
    # personalization (DESIGN.md §12): server/ef/buffer state exists only for
    # the SYNCED leaves — personal leaves never reach the sync surface, so
    # their slots are None-stripped out of every server-side tree. The empty
    # mask strips nothing: bit-exact pre-personalization state.
    personal = spec.sync.personal
    params_sync = strip_personal(personal, params)
    if spec.server.kind == "adaptive":
        v0 = spec.server.v_init if spec.server.v_init is not None \
            else spec.server.tau ** 2
        state["server"] = {
            "m": jax.tree.map(jnp.zeros_like, params_sync),
            "v": jax.tree.map(lambda p: jnp.full_like(p, v0), params_sync),
        }
    comp = spec.sync.compression
    if comp.error_feedback and not comp.is_identity():
        # EF residual e_m: per-client, shaped like params (DESIGN.md §4).
        # Identity compression drops nothing, so the leaf would stay zero —
        # omitted to keep the state pytree (and program) bit-identical.
        state["ef"] = jax.tree.map(jnp.zeros_like,
                                   strip_personal(personal, params_m))
    asy = spec.sync.asynchrony
    if not asy.is_identity():
        # staleness delta FIFO: single-replica shaped, leading B dim, sharded
        # like one replica's params (DESIGN.md §5) — server state, like m/v
        state["buffer"] = jax.tree.map(
            lambda p: jnp.zeros((asy.buffer_rounds,) + p.shape, p.dtype),
            params_sync)
    if spec.controller.enabled:
        # controller knobs + EMA stats (DESIGN.md §10): small scalar/(M,)
        # leaves that ride the state pytree through checkpoint/shard/donate
        state["ctrl"] = CTRL.init_ctrl_state(spec.controller, n_clients)
    return state


def strip_personal(personal: tuple, tree, is_leaf=None):
    """Replace every personal leaf (path contains a ``personal`` pattern)
    with ``None`` — jax pytrees treat ``None`` as an empty subtree, so the
    stripped tree's leaves are exactly the SYNCED leaves: ``jax.tree.map``
    over stripped trees touches no personal state and ``jax.tree.leaves``
    counts no personal bytes. The empty mask returns the tree unchanged
    (bit-exact identity; DESIGN.md §12)."""
    if not personal:
        return tree
    # ``is_leaf`` lets the launch layer strip trees whose leaves are
    # themselves containers (PartitionSpec tuples in sharding-spec trees)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree,
                                                         is_leaf=is_leaf)
    new = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        s = "/".join(keys)
        new.append(None if any(pat in s for pat in personal) else leaf)
    return jax.tree_util.tree_unflatten(treedef, new)


def _merge_personal(stripped, full, merge_fn):
    """Recombine a synced (None-stripped) tree with the full per-client tree:
    personal positions keep ``full``'s leaf, synced positions get
    ``merge_fn(stripped_leaf, full_leaf)``. Treating ``None`` as a leaf makes
    the stripped tree's structure match the full one's."""
    return jax.tree.map(
        lambda s, f: f if s is None else merge_fn(s, f),
        stripped, full, is_leaf=lambda x: x is None)


def average_params(state):
    """The server/averaged point x̂ (clients are identical post-sync)."""
    return jax.tree.map(lambda p: p[0], state["params"])


def client_drift(params_m):
    """(1/M)Σ‖x^m − x̂‖² — the V_t of the analysis (0 right after sync)."""
    def per_leaf(p):
        mean = p.mean(axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)
    return sum(jax.tree.leaves(jax.tree.map(per_leaf, params_m)))


# --------------------------------------------------------------------------- #
# ClientLoop
# --------------------------------------------------------------------------- #


def _clip(grads, max_norm):
    if not max_norm:
        return grads
    nrm = jnp.sqrt(sum(jnp.vdot(g, g).real
                       for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / nrm)
    return jax.tree.map(lambda g: g * scale, grads)


def _apply_update(params, mom, grads, pstate, spec: EngineSpec):
    """x ← x − lr·D̂⁻¹m,  m ← momentum·m + g   (heavy-ball, scaled)."""
    cl, pc = spec.client, spec.precond
    g = grads
    if cl.weight_decay:
        g = jax.tree.map(lambda gi, p: gi + cl.weight_decay * p, g, params)
    mom = jax.tree.map(lambda m, gi: cl.momentum * m + gi, mom, g)
    direction = PC.precondition(pc, pstate, mom)
    params = jax.tree.map(lambda p, d: p - cl.lr * d, params, direction)
    return params, mom


def _objective_grad(objective):
    """Keyed value-and-grad of a non-identity ClientObjective: the per-step
    key is folded by ``_OBJECTIVE_FOLD`` so the objective's noise stream
    (consistency views, token dropout) is decoupled from the Hutchinson
    probe and every other consumer of the step key (DESIGN.md §12)."""
    from repro.core.objectives import _OBJECTIVE_FOLD
    vg = jax.value_and_grad(objective.loss)

    def grad3(params, micro, key):
        return vg(params, micro, jax.random.fold_in(key, _OBJECTIVE_FOLD))
    return grad3


def _client_loop(loss_fn, grad_fn, spec: EngineSpec, shard_plan=None,
                 objective=None):
    """H local steps, vmap-over-M inside a lax.scan over H.

    Returns ``run(params_m, mom_m, pstate, micro, keys, h_m=None) ->
    (params_m, mom_m, pstate, last_grads, losses)`` with micro/keys leading
    (H, M) dims and losses shaped (H, M). ``h_m`` is an optional TRACED (M,)
    int32 per-client step budget (the controller's round-addressable H_m,
    DESIGN.md §10): same masking machinery as the static ``local_steps``
    vector but with the bound read from state — no recompile as it moves.

    ``objective`` (an optional ``objectives.ClientObjective``) swaps the
    differentiated loss: a non-identity objective is consulted with the
    per-step key (semi-supervised losses are stochastic); ``None`` or an
    identity objective leaves the unkeyed ``grad_fn`` call — and hence the
    emitted program — bit-exactly as before (DESIGN.md §12). The D̂
    curvature probes keep using the supervised ``loss_fn``: Assumption-4
    scaling tracks the geometry of the task loss, not the regularizer.
    """
    cl, pc = spec.client, spec.precond
    semi = objective is not None and not objective.is_identity()
    obj_grad = _objective_grad(objective) if semi else None

    def local_step_one_client(params, mom, pstate, micro, key):
        """One scaled step on one client. pstate: the client's view of D."""
        if semi:
            loss, grads = obj_grad(params, micro, key)
        else:
            loss, grads = grad_fn(params, micro)
        grads = _clip(grads, cl.grad_clip)
        if cl.scaling == "local" and pc.kind != "identity":
            stat = (PC.hutchinson_diag(loss_fn, params, micro, key)
                    if pc.uses_hutchinson else PC.grad_stat(grads))
            if pc.rule == "linear" and not pc.uses_hutchinson:
                stat = jax.tree.map(jnp.abs, grads)
            pstate = PC.update(pc, pstate, stat)
        params, mom = _apply_update(params, mom, grads, pstate, spec)
        return params, mom, pstate, loss, grads

    global_d = cl.scaling == "global"

    def run(params_m, mom_m, pstate, micro, keys, h_m=None):
        H = jax.tree.leaves(micro)[0].shape[0]
        M = jax.tree.leaves(params_m)[0].shape[0]
        masked = _needs_masking(cl, H, M) or h_m is not None
        bound = h_m if h_m is not None \
            else (jnp.asarray(cl.local_steps, jnp.int32)
                  if cl.local_steps is not None else None)

        def scan_body(carry, xs):
            params_m, mom_m, pstate, grads_c = carry
            if masked:
                micro_m, ks, h_idx = xs
                active = h_idx < bound  # (M,)
            else:
                micro_m, ks = xs  # (M, ...) microbatch slice, (M,) keys
            if global_d:
                fn = lambda p, m, mc, k: local_step_one_client(
                    p, m, pstate, mc, k)
                new_params, new_mom, _, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, micro_m, ks)
                new_pstate = pstate
            else:
                fn = local_step_one_client
                new_params, new_mom, new_pstate, losses, grads = jax.vmap(fn)(
                    params_m, mom_m, pstate, micro_m, ks)
            if masked:
                # heterogeneous H_m: clients past their budget freeze —
                # params/mom/grads (and per-client D) keep their step-H_m
                # values, so x_{m,H} = x_{m,H_m} at the sync barrier
                sel = lambda n, o: jax.tree.map(
                    lambda a, b: jnp.where(
                        active.reshape((M,) + (1,) * (a.ndim - 1)), a, b),
                    n, o)
                new_params = sel(new_params, params_m)
                new_mom = sel(new_mom, mom_m)
                grads = sel(grads, grads_c)
                if not global_d:
                    new_pstate = sel(new_pstate, pstate)
            return (new_params, new_mom, new_pstate, grads), losses

        grads0 = jax.tree.map(jnp.zeros_like, params_m)
        xs = (micro, keys, jnp.arange(H, dtype=jnp.int32)) if masked \
            else (micro, keys)
        (params_m, mom_m, pstate, last_grads), losses = jax.lax.scan(
            scan_body, (params_m, mom_m, pstate, grads0), xs)
        return params_m, mom_m, pstate, last_grads, losses

    if cl.use_fused_kernel:
        return local_step_one_client, _fused_run(loss_fn, grad_fn, spec, run,
                                                 shard_plan,
                                                 objective=objective)
    return local_step_one_client, run


def _local_flat_ops(params_m, local):
    """Flat ops of the client-parallel fast path: one global ``FlatLayout``
    (replicated leaves within a client) and the bare fused kernel."""
    from repro.kernels import ops as kops
    layout = FlatLayout.for_tree(params_m, batch_dims=1)
    flat_m = lambda t: layout.flatten(t, batch_dims=1)
    unflat_m = lambda b: layout.unflatten(b, batch_dims=1)
    bd = 1 if local else 0
    flat_d = lambda t: layout.flatten(t, batch_dims=bd)
    unflat_d = lambda b: layout.unflatten(b, batch_dims=bd)
    return flat_m, unflat_m, flat_d, unflat_d, kops.fused_local_step


def _shard_flat_ops(plan, local):
    """Flat ops of the shard-mapped fast path (DESIGN.md §7): per-shard flat
    buffers over the plan's model/FSDP axes, flatten/unflatten and the fused
    kernel all inside ``shard_map`` (in_specs == out_specs == the storage
    shardings, so no resharding collective can appear in the local step).
    The client axis keeps its tree-path semantics: the M dim rides the plan's
    client entry; per-client ``t`` is sharded over it."""
    from jax.experimental.shard_map import shard_map
    from repro.kernels import ops as kops
    mesh, lay, cl_entry = plan.mesh, plan.layout, plan.client
    lead_m = (cl_entry,)
    lead_d = lead_m if local else ()
    flat_m = lambda t: lay.flatten(t, mesh, lead=lead_m)
    unflat_m = lambda b: lay.unflatten(b, mesh, lead=lead_m)
    flat_d = lambda t: lay.flatten(t, mesh, lead=lead_d)
    unflat_d = lambda b: lay.unflatten(b, mesh, lead=lead_d)
    fs_m = lay.flat_spec(lead_m)

    def fused_step(p, m, g, d=None, h=None, t=None, s=None, **kw):
        update_d = kw.get("update_d", False)
        operands, in_specs = [p, m, g], [fs_m, fs_m, fs_m]
        if d is not None:
            operands.append(d)
            in_specs.append(fs_m if d.ndim == 2 else lay.flat_spec(()))
        if h is not None:
            operands.append(h)
            in_specs.append(fs_m)
        if t is not None:
            operands.append(t)
            in_specs.append(jax.sharding.PartitionSpec(cl_entry))
        flags = (d is not None, h is not None, t is not None)

        def body(*args):
            it = iter(args)
            p_, m_, g_ = next(it), next(it), next(it)
            d_ = next(it) if flags[0] else None
            h_ = next(it) if flags[1] else None
            t_ = next(it) if flags[2] else None
            po, mo, do = kops.fused_local_step(p_, m_, g_, d_, h_, t_, s, **kw)
            return (po, mo, do) if update_d else (po, mo)

        out_specs = (fs_m,) * (3 if update_d else 2)
        outs = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=out_specs, check_rep=False)(*operands)
        return outs[0], outs[1], (outs[2] if update_d else None)

    return flat_m, unflat_m, flat_d, unflat_d, fused_step


def _fused_run(loss_fn, grad_fn, spec: EngineSpec, tree_run, shard_plan=None,
               objective=None):
    """The flat-buffer fused client loop (DESIGN.md §7).

    Same contract as the tree ``run``, but the whole client state rides as
    per-client flat fp32 buffers ``(M, n_total)`` — flattened here at round
    start, unflattened only at the sync barrier — and each local step is ONE
    ``kernels.ops.fused_local_step`` Pallas call covering all M clients and
    every ``PrecondConfig`` kind: the D̂ update (rule-2 / rule-3 / AdaGrad,
    const or debias β_t via scalar-prefetched per-client ``t``) fuses with the
    momentum + scaled parameter update in a single pass.  Bit-identical (fp32)
    to the tree path for every kind × schedule × clip and all six METHODS
    (pinned in tests/test_fused_step.py); non-fp32 client state falls back to
    the tree path (the flat view is an fp32 buffer by contract).

    With ``shard_plan`` (a ``utils.flatten.ShardedFlatPlan``, built by the
    launch layer from the plan's NamedShardings) the SAME loop runs per model
    shard: flat buffers become the shard-major per-device blocks of
    ``ShardFlatLayout`` and flatten / the kernel / unflatten run inside
    ``shard_map`` over the plan's model/FSDP axes, so the fast path serves
    model-/FSDP-sharded plans with zero flat-buffer collectives (pinned in
    tests/test_fused_sharded.py).
    """
    cl, pc = spec.client, spec.precond
    has_d = pc.kind != "identity"
    # "local" here = D advances inside the loop (global D updates at sync)
    local = cl.scaling == "local" and has_d
    # semi-supervised objective: the fused Pallas update is grad-source
    # agnostic — only the (keyed) grad call changes, so the fast path stays
    # engaged under every objective (DESIGN.md §12)
    semi = objective is not None and not objective.is_identity()
    obj_grad = _objective_grad(objective) if semi else None

    def run(params_m, mom_m, pstate, micro, keys, h_m=None):
        if not (all_float32(params_m) and all_float32(mom_m)
                and (not has_d or all_float32(pstate["d"]))):
            return tree_run(params_m, mom_m, pstate, micro, keys, h_m=h_m)
        H = jax.tree.leaves(micro)[0].shape[0]
        M = jax.tree.leaves(params_m)[0].shape[0]
        masked = _needs_masking(cl, H, M) or h_m is not None
        bound = h_m if h_m is not None \
            else (jnp.asarray(cl.local_steps, jnp.int32)
                  if cl.local_steps is not None else None)
        flat_m, unflat_m, flat_d, unflat_d, fused_step = \
            _shard_flat_ops(shard_plan, local) if shard_plan is not None \
            else _local_flat_ops(params_m, local)

        carry0 = {"p": flat_m(params_m), "m": flat_m(mom_m)}
        carry0["g"] = jnp.zeros_like(carry0["p"])     # carried sync grads
        if has_d:
            carry0["d"] = flat_d(pstate["d"])
        if local:
            carry0["t"] = pstate["t"]                 # per-client (M,) i32

        def scan_body(carry, xs):
            if masked:
                micro_m, ks, h_idx = xs
                active = h_idx < bound
            else:
                micro_m, ks = xs
            params_tree = unflat_m(carry["p"])
            if semi:
                losses, grads = jax.vmap(obj_grad)(params_tree, micro_m, ks)
            else:
                losses, grads = jax.vmap(grad_fn)(params_tree, micro_m)
            if cl.grad_clip:
                # tree-level clip, exactly as the tree path: the CLIPPED
                # grads are what the carry freezes for the sync-time D stat
                grads = jax.vmap(lambda gt: _clip(gt, cl.grad_clip))(grads)
            G = flat_m(grads)
            hstat = None
            if local and pc.uses_hutchinson:
                stats = jax.vmap(lambda p_, mc, k_: PC.hutchinson_diag(
                    loss_fn, p_, mc, k_))(params_tree, micro_m, ks)
                hstat = flat_m(stats)
            p_new, m_new, d_new = fused_step(
                carry["p"], carry["m"], G, carry.get("d"), hstat,
                carry.get("t"), None, gamma=cl.lr, beta1=cl.momentum,
                weight_decay=cl.weight_decay, alpha=pc.alpha, beta2=pc.beta2,
                kind=pc.kind, clip=pc.clip, schedule=pc.schedule,
                update_d=local)
            new = dict(carry)
            new["p"], new["m"], new["g"] = p_new, m_new, G
            if local:
                new["d"] = d_new
                new["t"] = carry["t"] + 1
            if masked:
                aw = active[:, None]
                for k2 in ("p", "m", "g") + (("d",) if local else ()):
                    new[k2] = jnp.where(aw, new[k2], carry[k2])
                if local:
                    new["t"] = jnp.where(active, new["t"], carry["t"])
            return new, losses

        xs = (micro, keys, jnp.arange(H, dtype=jnp.int32)) if masked \
            else (micro, keys)
        carry, losses = jax.lax.scan(scan_body, carry0, xs)
        params_m = unflat_m(carry["p"])
        mom_m = unflat_m(carry["m"])
        last_grads = unflat_m(carry["g"])
        if local:
            pstate = {"d": unflat_d(carry["d"]), "t": carry["t"]}
        return params_m, mom_m, pstate, last_grads, losses

    return run


def _needs_masking(cl: ClientLoopSpec, H: int, M: int) -> bool:
    """True iff the per-client H_m vector actually truncates some client.

    Uniform H_m == H (or ``local_steps=None``) short-circuits to the exact
    pre-heterogeneity program — the bit-for-bit contract of DESIGN.md §5,
    pinned by tests/test_heterogeneity.py. Shape errors are raised at trace
    time, where H and M are static.
    """
    hs = cl.local_steps
    if hs is None:
        return False
    if len(hs) != M:
        raise ValueError(f"local_steps has {len(hs)} entries for {M} clients")
    if max(hs) > H:
        raise ValueError(f"local_steps max {max(hs)} exceeds the round's "
                         f"H={H} microbatches")
    return any(h != H for h in hs)


# --------------------------------------------------------------------------- #
# Compression (DESIGN.md §4)
# --------------------------------------------------------------------------- #


def _k_count(k: float, n: int) -> int:
    """Static kept-entry count for a leaf of n elements (at least 1).

    Half-up rounding: Python ``round`` banker's-rounds halves to even
    (round(2.5) == 2), which made k = 0.5 on an odd-n leaf keep ⌊k·n⌋.
    """
    return max(1, min(n, int(math.floor(k * n + 0.5))))


def _compress_leaf(spec: CompressionSpec, x, key, k_frac=None):
    """Apply one compression operator to a (M, ...) leaf of round deltas.

    Per-client semantics throughout: topk/randk select EXACTLY k·n entries
    per client row, int8-stochastic uses a per-client absmax/127 scale.
    Returns the decoded (server-side) fp32 view of what crossed the wire,
    same shape as x.

    ``k_frac`` (optional traced f32 scalar) overrides ``spec.k`` for
    topk/randk with the controller's round-addressable kept fraction
    (DESIGN.md §10): selection goes through stable ranks so the count is a
    traced value and the program never recompiles as k moves. Both paths
    break score ties toward the lower index, so a frozen ``k_frac`` equal to
    a binary-exact ``spec.k`` selects the identical entry set bitwise.
    """
    M = x.shape[0]
    flat = x.reshape(M, -1)
    n = flat.shape[1]
    if spec.op in ("topk", "randk"):
        # randk = topk on uniform scores: same selection code, random ranking
        scores = jnp.abs(flat) if spec.op == "topk" \
            else jax.random.uniform(key, flat.shape)
        if k_frac is None:
            # exact-k: scatter the top_k index set. (Thresholding with
            # `scores >= thresh` kept EVERY tied entry — k=0.5 on a
            # 4-element all-equal row kept 4/4 — corrupting the wire
            # accounting and randk's n/kc unbiased rescale.)
            kc = _k_count(spec.k, n)
            idx = jax.lax.top_k(scores, kc)[1]
            mask = jnp.zeros(flat.shape, jnp.bool_).at[
                jnp.arange(M)[:, None], idx].set(True)
            inv = n / kc
        else:
            # traced count: entry kept iff its stable descending rank < kc
            kc = jnp.clip(jnp.floor(k_frac * n + 0.5).astype(jnp.int32), 1, n)
            order = jnp.argsort(-scores, axis=1)      # stable: ties low-first
            ranks = jnp.argsort(order, axis=1)
            mask = ranks < kc
            inv = n / kc.astype(flat.dtype)
        kept = jnp.where(mask, flat, 0.0)
        if spec.op == "randk" and not spec.error_feedback:
            # unbiased rescale E[C(x)] = x — only without EF: the dim/k
            # amplification is non-contractive and blows up the residual
            kept = kept * inv
        return kept.reshape(x.shape)
    # int8-stochastic: E[floor(v + U[0,1))] = v — unbiased QDQ
    absmax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    scale = absmax / 127.0
    u01 = jax.random.uniform(key, flat.shape)
    if spec.use_fused_kernel:
        from repro.kernels import ops as kops
        _, dec = kops.quantize_update(flat, u01, scale)
    else:
        # one source of truth for the QDQ formula: the kernel's jnp oracle
        # (the Pallas kernel is pinned bit-identical to it)
        from repro.kernels import ref as kref
        _, dec = kref.quantize_update_ref(flat, u01, scale)
    return dec.reshape(x.shape)


def compress_tree(spec: CompressionSpec, deltas, key, k_frac=None):
    """Compress a pytree of (M, ...) round deltas; per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(deltas)
    keys = jax.random.split(jax.random.fold_in(key, 17), len(leaves))
    return jax.tree.unflatten(
        treedef,
        [_compress_leaf(spec, x, k, k_frac) for x, k in zip(leaves, keys)])


def measured_wire_bytes(comp: CompressionSpec, compressed,
                        elem_bytes: int = 4):
    """Encoded client→server payload measured from the ACTUAL arrays
    ``compress_tree`` emitted (its decoded (M, ...) views) — the ground truth
    ``bytes_on_wire``'s analytic accounting is pinned against
    (tests/test_compression.py).

    Per client: topk/randk count the surviving nonzero entries, each a
    (fp32 value, int32 index) pair; int8-stochastic moves 1 byte/element plus
    one fp32 scale per leaf; identity specs move every element at
    ``elem_bytes``. Returns an int64 numpy array of shape (M,). Caveat: a
    kept-but-exactly-zero delta entry is indistinguishable from a dropped one
    in the decoded view, so topk/randk counts are exact only for continuous
    deltas (which is what the engine compresses).
    """
    import numpy as np
    leaves = jax.tree.leaves(compressed)
    M = leaves[0].shape[0]
    total = np.zeros((M,), np.int64)
    for leaf in leaves:
        flat = np.asarray(leaf).reshape(M, -1)
        n = flat.shape[1]
        if comp.is_identity():
            total += n * elem_bytes
        elif comp.op in ("topk", "randk"):
            total += (flat != 0).sum(axis=1).astype(np.int64) * (4 + 4)
        else:  # int8-stochastic
            total += n * 1 + 4
    return total


def bytes_on_wire(spec: EngineSpec, params) -> dict:
    """Analytic client→server sync payload per round for ONE client.

    ``params`` is a single-replica pytree (arrays or ShapeDtypeStructs, no
    leading M dim). Accounting: topk/randk send (fp32 value, int32 index)
    pairs; int8-stochastic sends 1 byte/element + one fp32 scale per leaf;
    uncompressed legs move ``sync_dtype`` bytes (fp32 when unset). Momentum,
    when averaged (``average_momentum`` under an averaging server), always
    moves uncompressed.

    Personal (client-resident) leaves move NOTHING: they are stripped from
    every leg — delta, momentum, and the server m/v sync — before counting,
    so the reported payload is exactly the synced subset's (the synced
    leaves' accounting is unchanged by personalization; DESIGN.md §12).
    """
    params = strip_personal(spec.sync.personal, params)
    sy, comp = spec.sync, spec.sync.compression
    elem = jnp.dtype(sy.sync_dtype).itemsize if sy.sync_dtype else 4
    delta = raw = 0
    for leaf in jax.tree.leaves(params):
        n = 1
        for s in leaf.shape:
            n *= int(s)
        raw += n * 4
        if comp.is_identity():
            delta += n * elem
        elif comp.op in ("topk", "randk"):
            delta += _k_count(comp.k, n) * (4 + 4)
        else:  # int8-stochastic
            delta += n * 1 + 4
    mom = raw if (spec.server.kind == "average"
                  and sy.average_momentum) else 0
    if mom and sy.sync_dtype:
        mom = mom // 4 * elem
    out = {"delta_bytes": delta, "momentum_bytes": mom,
           "total_bytes": delta + mom, "uncompressed_bytes": raw + mom,
           "compression_x": round((raw + mom) / max(delta + mom, 1), 2)}
    if spec.server.kind == "adaptive":
        # the server m/v sync leg (replica agreement on the adaptive state,
        # arXiv:2109.05109) — a server→server cost, reported separately and
        # NOT folded into the client→server total_bytes above
        sv = spec.server
        elem_s = jnp.dtype(sv.sync_dtype).itemsize if sv.sync_dtype else 4
        s_raw = s_comp = 0
        for leaf in jax.tree.leaves(params):
            n = 1
            for s in leaf.shape:
                n *= int(s)
            s_raw += 2 * n * 4                  # fp32 m + v
            if sv.sync_k < 1.0:
                # shared top-|m| index set: (m, v) value pair + one index
                s_comp += _k_count(sv.sync_k, n) * (2 * elem_s + 4)
            else:
                s_comp += 2 * n * elem_s
        out["server_state_bytes"] = s_comp
        out["server_state_uncompressed_bytes"] = s_raw
    return out


# --------------------------------------------------------------------------- #
# SyncStrategy
# --------------------------------------------------------------------------- #


def staleness_weights(spec: AsyncSpec, round_idx, b_eff=None):
    """Normalized weights over the delta FIFO's B slots (ages τ = 0..B−1).

    w_τ ∝ s(τ)·[round_idx ≥ τ]: slot τ holds the delta aggregated τ rounds
    ago, which does not exist before round τ (the buffer starts zeroed), so
    early rounds renormalize over the populated prefix. Weights always sum to
    1 (pinned in tests/test_heterogeneity.py); with B = 1 the single fresh
    slot gets weight 1 — plain delta averaging.

    ``b_eff`` (optional traced i32 scalar in [1, B]) is the controller's
    effective staleness depth (DESIGN.md §10): ages >= b_eff are masked to 0,
    shrinking the applied window inside the statically allocated FIFO with no
    recompile. ``None`` is the bit-exact static program.
    """
    B = spec.buffer_rounds
    ages = jnp.arange(B, dtype=jnp.float32)
    s = jnp.ones((B,)) if spec.weighting == "constant" \
        else (1.0 + ages) ** (-spec.poly_a)
    w = s * (ages <= round_idx)
    if b_eff is not None:
        w = w * (ages < b_eff)
    return w / jnp.maximum(w.sum(), jnp.finfo(jnp.float32).tiny)


def participation_weights(spec: SyncSpec, key, n_clients: int):
    """Per-client sync weights: uniform 1/M, or 1/n_part on a sampled subset
    (FedAvg-style client sampling); weights always sum to 1. Half-up count:
    Python round() banker's-rounds (participation=0.5, M=5 sampled 2)."""
    M = n_clients
    n_part = max(1, int(math.floor(spec.participation * M + 0.5)))
    if n_part < M:
        perm = jax.random.permutation(jax.random.fold_in(key, 3), M)
        return jnp.zeros((M,)).at[perm[:n_part]].set(1.0 / n_part)
    return jnp.full((M,), 1.0 / M)


def make_sync(spec: SyncSpec, key, n_clients: int):
    """The sync average: (M, ...) leaf -> (...) weighted mean.

    With ``sync_dtype`` set, the optimization barriers pin the low-precision
    representation so BOTH legs of the sync (reduce + broadcast-back) move
    sync_dtype bytes; the master-dtype cast happens locally after (quantized
    averaging — same family as the quantization line of related work [19,20];
    sync noise ~2^-8 relative for bf16).
    """
    M = n_clients
    w_part = participation_weights(spec, key, M)

    def _wmean(p):
        wb = w_part.reshape((M,) + (1,) * (p.ndim - 1)).astype(p.dtype)
        return (p * wb).sum(axis=0)

    if spec.sync_dtype:
        sd = jnp.dtype(spec.sync_dtype)

        def avg(p):
            q = jax.lax.optimization_barrier(p.astype(sd))
            a = _wmean(q)
            return jax.lax.optimization_barrier(a)
    else:
        avg = _wmean
    return avg


def _broadcast_back(params_m, avg):
    """Scatter the averaged value back to every client in sync dtype; cast to
    the master dtype locally (cross-device FedAvg semantics: non-participants
    are overwritten too). ``avg`` may be a None-stripped synced tree
    (personalization): personal positions keep each client's own leaf."""
    return _merge_personal(
        avg, params_m,
        lambda a, p: jnp.broadcast_to(a[None], (p.shape[0],) + a.shape
                                      ).astype(p.dtype))


# --------------------------------------------------------------------------- #
# ServerUpdate
# --------------------------------------------------------------------------- #


def _compress_server_state(spec: ServerSpec, m, v):
    """Compress the server m/v trees for the replica-agreement sync leg
    (arXiv:2109.05109): the adaptive state every replica must share is kept
    in its compressed form, so the per-round server-state traffic stops
    scaling with the full fp32 trees (``bytes_on_wire``'s
    ``server_state_bytes``). ``sync_k`` keeps ONE shared largest-|m| index
    set per leaf for both trees — a dropped coordinate contributes no step
    and its v falls back to the ``v_init`` floor, preserving the τ²
    adaptivity floor semantics; ``sync_dtype`` QDQ-round-trips both trees
    behind optimization barriers (same discipline as the sync average)."""
    if spec.sync_k < 1.0:
        v0 = spec.v_init if spec.v_init is not None else spec.tau ** 2

        def mask_leaf(mm):
            fm = mm.reshape(-1)
            kc = _k_count(spec.sync_k, fm.size)
            idx = jax.lax.top_k(jnp.abs(fm), kc)[1]
            return jnp.zeros(fm.shape, jnp.bool_).at[idx].set(True) \
                .reshape(mm.shape)

        masks = jax.tree.map(mask_leaf, m)
        m = jax.tree.map(lambda mm, ma: jnp.where(ma, mm, 0.0), m, masks)
        v = jax.tree.map(
            lambda vv, ma: jnp.where(ma, vv, jnp.asarray(v0, vv.dtype)),
            v, masks)
    if spec.sync_dtype:
        sd = jnp.dtype(spec.sync_dtype)
        qdq = lambda a: jax.lax.optimization_barrier(a.astype(sd)) \
            .astype(a.dtype)
        m = jax.tree.map(qdq, m)
        v = jax.tree.map(qdq, v)
    return m, v


def _adaptive_server_update(spec: ServerSpec, server, x_prev, delta):
    """m/v/x update of Algorithm 2 [42] on the pseudo-gradient Δ."""
    m = jax.tree.map(lambda m_, d: spec.beta1 * m_ + (1 - spec.beta1) * d,
                     server["m"], delta)
    if spec.opt == "adagrad":
        v = jax.tree.map(lambda v_, d: v_ + d * d, server["v"], delta)
    elif spec.opt == "adam":
        v = jax.tree.map(
            lambda v_, d: spec.beta2 * v_ + (1 - spec.beta2) * d * d,
            server["v"], delta)
    else:  # yogi
        v = jax.tree.map(
            lambda v_, d: v_ - (1 - spec.beta2) * d * d
            * jnp.sign(v_ - d * d), server["v"], delta)
    if not spec.sync_identity():
        m, v = _compress_server_state(spec, m, v)
    x = jax.tree.map(
        lambda x_, m_, v_: x_ + spec.eta * m_ / (jnp.sqrt(v_) + spec.tau),
        x_prev, m, v)
    return x, {"m": m, "v": v}


# --------------------------------------------------------------------------- #
# The round
# --------------------------------------------------------------------------- #


def build_round_step(loss_fn: Callable, spec: EngineSpec, shard_plan=None,
                     objective=None):
    """loss_fn(params, microbatch) -> scalar.

    Returns ``round_step(state, batch, key)`` where each batch leaf is
    (M, H, ...): H microbatches per client per round. Returns (state, metrics).
    Metrics: loss, loss_per_client, client_drift (+ step_norm for adaptive
    servers).

    ``shard_plan`` (optional ``utils.flatten.ShardedFlatPlan``) switches the
    ``use_fused_kernel`` fast path onto per-shard flat buffers via
    ``shard_map`` — the launch layer builds it for model-/FSDP-sharded plans
    (DESIGN.md §7); it is ignored when the client loop is unfused.

    ``objective`` (optional ``objectives.ClientObjective``) replaces the
    differentiated client loss with a semi-supervised one (DESIGN.md §12);
    ``None`` or an identity (supervised) objective leaves every code path —
    and the emitted program — bit-exactly as before. ``spec.sync.personal``
    names client-resident leaves: those never enter the sync average, the
    delta/compression/EF/buffer pipeline, the adaptive server, or the
    broadcast-back — each client keeps its own copy, like the per-client D
    under local scaling. Personalizing D itself therefore requires
    ``scaling="local"`` (or an identity preconditioner): a GLOBAL D is by
    definition shared state, so combining it with a personalization mask is
    a build-time error rather than a silent wire leak.
    """
    grad_fn = jax.value_and_grad(loss_fn)
    cl, sy, sv, pc = spec.client, spec.sync, spec.server, spec.precond
    personal = sy.personal
    if personal and cl.scaling == "global" and pc.kind != "identity":
        raise ValueError(
            "personalization with a GLOBAL preconditioner: the shared D is "
            "updated from cross-client sync gradients, which would leak the "
            "personal leaves' gradients over the wire. Use scaling='local' "
            "(per-client D, never synced) or pc kind='identity'.")
    strip = lambda t: strip_personal(personal, t)
    _, client_run = _client_loop(loss_fn, grad_fn, spec, shard_plan,
                                 objective=objective)
    ctrl = spec.controller
    if ctrl.enabled:
        # the controller owns the knobs it schedules — conflicting static
        # settings are build-time errors, not silent overrides
        if cl.local_steps is not None:
            raise ValueError("controller and static local_steps are "
                             "exclusive: the controller owns H_m")
        if sy.participation < 1.0:
            raise ValueError("controller requires full participation: its "
                             "gradient-noise estimate needs every client's "
                             "delta (and skipped stragglers are rescaled as "
                             "the sampled subset)")
        if ctrl.buffer_max > 0 and \
                sy.asynchrony.buffer_rounds != ctrl.buffer_max:
            raise ValueError(
                f"controller buffer_max={ctrl.buffer_max} must equal the "
                f"allocated AsyncSpec.buffer_rounds="
                f"{sy.asynchrony.buffer_rounds} (b_eff masks within the "
                f"static FIFO)")

    def round_step(state, batch, key):
        M = jax.tree.leaves(state["params"])[0].shape[0]
        H = jax.tree.leaves(batch)[0].shape[1]

        # ---- Controller knobs for THIS round (DESIGN.md §10) ---------------
        # read from state["ctrl"] — the compiled program is knob-agnostic
        cstate = h_m_dyn = None
        if ctrl.enabled:
            if ctrl.h_max > H:
                raise ValueError(f"controller h_max={ctrl.h_max} exceeds the "
                                 f"round's H={H} microbatches")
            cstate = state["ctrl"]
            h_m_dyn = cstate["h_m"]

        # ---- ClientLoop: H local steps, vmap over M inside the scan --------
        keys = jax.random.split(key, (H, M))
        micro = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), batch)  # (H,M,..)
        mom0 = jax.tree.map(jnp.zeros_like, state["mom"]) \
            if cl.reset_momentum else state["mom"]
        if h_m_dyn is not None:
            params_m, mom_m, pstate, last_grads, losses = client_run(
                state["params"], mom0, state["precond"], micro, keys,
                h_m=h_m_dyn)
        else:
            params_m, mom_m, pstate, last_grads, losses = client_run(
                state["params"], mom0, state["precond"], micro, keys)

        drift_pre_sync = client_drift(params_m)

        # ---- Controller observations: raw per-client delta statistics ------
        ctrl_obs = None
        if ctrl.enabled:
            # synced leaves only: personal deltas are client-resident and
            # must not enter the controller's cross-client noise estimate
            x_ref0 = strip(jax.tree.map(lambda p: p[0], state["params"]))
            d_m = jax.tree.map(lambda p, x: p - x[None], strip(params_m),
                               x_ref0)
            d2_pc = sum(jnp.sum(jnp.reshape(d * d, (M, -1)), axis=1)
                        for d in jax.tree.leaves(d_m))           # (M,)
            dbar_sq = sum(jnp.vdot(b, b).real for b in jax.tree.leaves(
                jax.tree.map(lambda d: d.mean(axis=0), d_m)))
            ctrl_obs = {"delta_sq_mean": d2_pc.mean(),
                        "delta_sq_avg": dbar_sq,
                        "payload_sq": jnp.float32(0.0),
                        "resid_sq": jnp.float32(0.0)}

        # ---- SyncStrategy: the only cross-client traffic per round ---------
        avg = make_sync(sy, key, M)
        comp, asy = sy.compression, sy.asynchrony
        new_ef = delta_avg = comp_err = new_buffer = staleness = None
        # every tree below is the SYNCED view: ``strip`` (identity for the
        # empty personalization mask) None-strips the client-resident leaves,
        # so no average / delta / compression / buffer op ever touches them
        # (DESIGN.md §12) — ``params_avg`` is a synced-leaf tree recombined
        # with the untouched personal leaves at broadcast-back
        if comp.is_identity() and asy.is_identity():
            # bit-for-bit the uncompressed synchronous program (DESIGN.md
            # §4/§5 contract) — no delta reconstruction, no residual/buffer
            # state
            params_avg = jax.tree.map(avg, strip(params_m))
        else:
            # delta form: Δ_m = x_{m,H} − x_t (clients start each round at
            # the common broadcast point, so x_t = params[0])
            x_ref = strip(jax.tree.map(lambda p: p[0], state["params"]))
            u_m = jax.tree.map(lambda p, x: p - x[None], strip(params_m),
                               x_ref)
            if comp.is_identity():
                c_m = u_m
            else:
                if comp.error_feedback:
                    u_m = jax.tree.map(jnp.add, u_m, state["ef"])
                k_dyn = cstate["k"] if (ctrl.enabled
                                        and comp.op in ("topk", "randk")) \
                    else None
                c_m = compress_tree(comp, u_m, key, k_frac=k_dyn)
                if comp.error_feedback:
                    new_ef = jax.tree.map(jnp.subtract, u_m, c_m)
                comp_err = sum(jnp.vdot(u - c, u - c).real for u, c in zip(
                    jax.tree.leaves(u_m), jax.tree.leaves(c_m)))
                if ctrl_obs is not None:
                    # the compressor's actual input/residual energies feed
                    # the controller's EF-residual-norm guard
                    ctrl_obs["payload_sq"] = sum(
                        jnp.vdot(u, u).real for u in jax.tree.leaves(u_m))
                    ctrl_obs["resid_sq"] = comp_err
            delta_avg = jax.tree.map(avg, c_m)
            if ctrl.enabled and ctrl.buffer_max > 0:
                # controller-skipped stragglers (h_m = 0) contributed Δ = 0:
                # rescale the mean to the reporting subset, exactly the
                # 1/n_part weighting of FedAvg client sampling
                n_act = jnp.maximum(
                    jnp.sum((h_m_dyn > 0).astype(jnp.float32)), 1.0)
                delta_avg = jax.tree.map(
                    lambda d: d * (M / n_act).astype(d.dtype), delta_avg)
            if not asy.is_identity():
                # FedBuff-style staleness buffer (DESIGN.md §5): enqueue the
                # fresh aggregated delta, apply the staleness-weighted
                # combination of the FIFO
                b_eff = cstate["b_eff"] if (ctrl.enabled
                                            and ctrl.buffer_max > 0) else None
                w = staleness_weights(asy, state["round"], b_eff=b_eff)
                new_buffer = jax.tree.map(
                    lambda b, d: jnp.concatenate(
                        [d[None].astype(b.dtype), b[:-1]], axis=0),
                    state["buffer"], delta_avg)
                delta_avg = jax.tree.map(
                    lambda b: jnp.tensordot(w.astype(b.dtype), b, axes=1),
                    new_buffer)
                staleness = jnp.sum(
                    w * jnp.arange(asy.buffer_rounds, dtype=jnp.float32))
            params_avg = jax.tree.map(
                lambda x, d: x + d.astype(x.dtype), x_ref, delta_avg)

        if sv.kind == "average":
            # personal leaves keep each client's own value (no broadcast)
            params_m = _broadcast_back(params_m, params_avg)
            params_avg = jax.tree.map(lambda x: x[0], params_m)
            if sy.average_momentum:
                mom_m = _merge_personal(
                    strip(mom_m), mom_m,
                    lambda s, m: jnp.broadcast_to(
                        avg(s)[None], m.shape).astype(m.dtype))

        # ---- D update at sync (global scaling; Algorithm 1 line 4) ---------
        if cl.scaling == "global" and pc.kind != "identity":
            g_last = last_grads  # (M, ...) — grads of the sync step
            if cl.stat_source == "avg_grad":
                g_avg = jax.tree.map(avg, g_last)  # participation+dtype apply
                if pc.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1, 0], micro)
                    stat = PC.hutchinson_diag(loss_fn, params_avg, sync_micro,
                                              jax.random.fold_in(key, 7))
                elif pc.rule == "linear":
                    stat = jax.tree.map(jnp.abs, g_avg)
                else:
                    stat = PC.grad_stat(g_avg)
            else:  # avg_local
                if pc.uses_hutchinson:
                    sync_micro = jax.tree.map(lambda x: x[-1], micro)  # (M,..)
                    hk = jax.random.split(jax.random.fold_in(key, 7), M)
                    stats = jax.vmap(lambda p, mc, k: PC.hutchinson_diag(
                        loss_fn, p, mc, k))(params_m, sync_micro, hk)
                elif pc.rule == "linear":
                    stats = jax.tree.map(jnp.abs, g_last)
                else:
                    stats = PC.grad_stat(g_last)
                stat = jax.tree.map(lambda s: s.mean(axis=0), stats)
            pstate = PC.update(pc, pstate, stat)

        if h_m_dyn is not None or _needs_masking(cl, H, M):
            # heterogeneous H_m: steps past a client's budget froze its state;
            # average only the executed steps, and report each client's loss
            # at ITS final step H_m−1, not the global step H−1. (For a
            # controller-skipped client, H_m = 0, its rows drop from the mean
            # and the clamped index reports its frozen round-start loss.)
            h_m = h_m_dyn if h_m_dyn is not None \
                else jnp.asarray(cl.local_steps, jnp.int32)
            act = jnp.arange(H, dtype=jnp.int32)[:, None] < h_m[None, :]
            loss_mean = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1)
            loss_per_client = jnp.take_along_axis(
                losses, jnp.maximum(h_m - 1, 0)[None, :], axis=0)[0]
        else:
            loss_mean = losses.mean()
            loss_per_client = losses[-1]
        metrics = {
            "loss": loss_mean,
            "loss_per_client": loss_per_client,
            "client_drift": drift_pre_sync,
        }
        if comp_err is not None:
            metrics["compression_err"] = comp_err  # Σ‖u_m − C(u_m)‖²
        if staleness is not None:
            metrics["staleness"] = staleness  # E_w[τ] of the applied delta
        if ctrl.enabled:
            # realized knobs of THIS round + the raw observations, so a
            # numpy replay (tests/_reference_controller.py) can reproduce
            # the whole trajectory from logs alone
            metrics["ctrl_h_m"] = h_m_dyn
            metrics["ctrl_h_t"] = cstate["h_t"]
            metrics["ctrl_k"] = cstate["k"]
            metrics["ctrl_b_eff"] = cstate["b_eff"] if ctrl.buffer_max > 0 \
                else jnp.int32(0)  # 0 = depth not managed by the controller
            metrics["delta_sq_mean"] = ctrl_obs["delta_sq_mean"]
            metrics["delta_sq_avg"] = ctrl_obs["delta_sq_avg"]
            metrics["payload_sq"] = ctrl_obs["payload_sq"]

        # ---- ServerUpdate ---------------------------------------------------
        new_state = {"round": state["round"] + 1, "precond": pstate}
        if new_ef is not None:
            new_state["ef"] = new_ef
        if new_buffer is not None:
            new_state["buffer"] = new_buffer
        if ctrl.enabled:
            # roll the knobs forward for the NEXT round (pure, jit-traced;
            # checkpointing the state pytree checkpoints the controller)
            new_cstate, _ = CTRL.controller_step(ctrl, cstate, ctrl_obs)
            new_state["ctrl"] = new_cstate
            metrics["ctrl_gns_ema"] = new_cstate["gns_ema"]
        if sv.kind == "adaptive":
            x_prev = strip(jax.tree.map(lambda p: p[0], state["params"]))
            if delta_avg is not None:
                # compressed path: Δ is exactly the averaged compressed delta
                # (params_avg = x_prev + Δ would re-add/re-subtract x_prev)
                delta = jax.tree.map(
                    lambda d, x: d.astype(x.dtype), delta_avg, x_prev)
            else:
                delta = jax.tree.map(
                    lambda a, x: a.astype(x.dtype) - x, params_avg, x_prev)
            x_new, server = _adaptive_server_update(sv, state["server"],
                                                    x_prev, delta)
            params_m = _broadcast_back(params_m, x_new)
            new_state["server"] = server
            metrics["step_norm"] = jnp.sqrt(sum(
                jnp.vdot(a - b, a - b).real for a, b in zip(
                    jax.tree.leaves(x_new), jax.tree.leaves(x_prev))))
        new_state["params"] = params_m
        new_state["mom"] = mom_m
        return new_state, metrics

    return round_step
