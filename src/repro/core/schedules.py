"""Step-size and β_t schedules, including the theory-driven choices of
Corollaries 1-3."""
from __future__ import annotations

import jax.numpy as jnp


def constant(gamma):
    return lambda t: gamma


def inv_sqrt(gamma0, warmup=0):
    def f(t):
        t = jnp.maximum(t, 1)
        g = gamma0 / jnp.sqrt(t)
        if warmup:
            g = jnp.where(t < warmup, gamma0 * t / warmup, g)
        return g
    return f


def cosine(gamma0, total, floor=0.0):
    def f(t):
        frac = jnp.clip(t / total, 0.0, 1.0)
        return floor + 0.5 * (gamma0 - floor) * (1 + jnp.cos(jnp.pi * frac))
    return f


def corollary1_beta(rule: str, gamma, mu, alpha, Gamma):
    """β_{t+1} lower bound from Corollary 1 that keeps the D-drift within
    (1 + γμ/2Γ): rule (2) -> 1 - γμα²/Γ³ ; rule (3) -> 1 - γμα/4Γ²."""
    if rule == "squared":
        return max(0.0, 1.0 - gamma * mu * alpha**2 / Gamma**3)
    return max(0.0, 1.0 - gamma * mu * alpha / (4.0 * Gamma**2))
