"""SAVIC — the paper's contribution: Local SGD with adaptivity via scaling.

Public API:
    PrecondConfig, SavicConfig     — configuration
    savic.init_state / build_round_step — Algorithm 1
    fedopt.*                       — the FedOpt baseline of [42]
    theory.*                       — Theorem 1/2 predictors
"""
from repro.core.preconditioner import PrecondConfig  # noqa
from repro.core.savic import SavicConfig, build_round_step, init_state  # noqa
from repro.core import fedopt, theory, schedules  # noqa
