"""SAVIC — the paper's contribution: Local SGD with adaptivity via scaling.

Public API:
    PrecondConfig, SavicConfig     — configuration
    engine.*                       — the pluggable round engine
                                     (ClientLoop × SyncStrategy × ServerUpdate)
    savic.init_state / build_round_step — Algorithm 1 (engine preset)
    fedopt.*                       — the FedOpt baseline of [42] (engine preset)
    theory.*                       — Theorem 1/2 predictors
"""
from repro.core.preconditioner import PrecondConfig  # noqa
from repro.core.controller import ControllerSpec  # noqa
from repro.core.engine import AsyncSpec, CompressionSpec, EngineSpec  # noqa
from repro.core.objectives import ClientObjective, ObjectiveSpec  # noqa
from repro.core.savic import SavicConfig, build_round_step, init_state  # noqa
from repro.core import (controller, engine, fedopt, objectives,  # noqa
                        theory, schedules)
