"""SAVIC — Algorithm 1: Local SGD with preconditioning via scaling.

A *round* = H local steps on each of M clients followed by one synchronization
(parameter averaging) — the H-th step is the averaged one, exactly matching
Algorithm 1's sync timestep. The preconditioner D̂ is updated only at sync and
is identical on every client (*global scaling*, the analyzed setting); the
experimental *local scaling* variant (per-client D updated every local step)
is also implemented.

Since the round-engine refactor this module is a thin method definition over
``core/engine.py``: SAVIC = locally-scaled heavy-ball ClientLoop × weighted /
quantized SyncStrategy × identity-averaging ServerUpdate. The engine emits the
exact program the pre-refactor monolith did (regression-pinned in
tests/test_engine.py); the state pytree and public API are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import engine
from repro.core.preconditioner import PrecondConfig


@dataclasses.dataclass(frozen=True)
class SavicConfig:
    gamma: float = 0.1                 # step size γ
    beta1: float = 0.9                 # heavy-ball momentum (paper's exps: 0.9)
    scaling: str = "global"            # "global" (Algorithm 1) | "local"
    # D-stat at sync: "avg_grad" (H from the client-averaged sync gradient) |
    # "avg_local" (average of per-client stats)
    stat_source: str = "avg_grad"
    average_momentum: bool = True      # average momentum buffers at sync
    weight_decay: float = 0.0
    grad_clip: float = 0.0             # global-norm clip per local step (0=off)
    # flat-buffer fused client loop: one Pallas pass per local step for every
    # preconditioner kind, bit-identical in fp32 (DESIGN.md §7)
    use_fused_kernel: bool = False
    # sync compression (beyond-paper; cf. the quantization line of related
    # work [19,20]): all-reduce params/momentum in this dtype ("" = full)
    sync_dtype: str = ""
    # partial participation (beyond-paper; the compared Algorithm 2 of [42]
    # samples a client subset per round): fraction of clients whose updates
    # enter the sync average; non-participants keep local state but are
    # overwritten by the average (cross-device FedAvg semantics). 1.0 = all.
    participation: float = 1.0
    # sync delta compression (topk/randk/int8-stochastic, optional EF
    # residual; engine SyncStrategy layer, DESIGN.md §4)
    compression: engine.CompressionSpec = engine.CompressionSpec()
    # systems heterogeneity: per-client local-step vector H_m (None = uniform;
    # engine ClientLoop masking, DESIGN.md §5)
    local_steps: tuple = None
    # staleness-buffered server (FedBuff-style delta FIFO, DESIGN.md §5)
    asynchrony: engine.AsyncSpec = engine.AsyncSpec()


def engine_spec(pc_cfg: PrecondConfig, sv_cfg: SavicConfig) -> engine.EngineSpec:
    """SavicConfig × PrecondConfig -> the engine's three-layer spec."""
    return engine.EngineSpec(
        client=engine.ClientLoopSpec(
            lr=sv_cfg.gamma, momentum=sv_cfg.beta1, scaling=sv_cfg.scaling,
            stat_source=sv_cfg.stat_source, weight_decay=sv_cfg.weight_decay,
            grad_clip=sv_cfg.grad_clip,
            use_fused_kernel=sv_cfg.use_fused_kernel,
            local_steps=sv_cfg.local_steps),
        sync=engine.SyncSpec(
            participation=sv_cfg.participation, sync_dtype=sv_cfg.sync_dtype,
            average_momentum=sv_cfg.average_momentum,
            compression=sv_cfg.compression,
            asynchrony=sv_cfg.asynchrony),
        server=engine.ServerSpec(kind="average"),
        precond=pc_cfg)


def init_state(key, init_params_fn, pc_cfg: PrecondConfig, sv_cfg: SavicConfig,
               n_clients: int):
    """Build the SAVIC train state. x_0^m = x_0 (identical start, Algorithm 1)."""
    return engine.init_state(key, init_params_fn, engine_spec(pc_cfg, sv_cfg),
                             n_clients)


def build_round_step(loss_fn: Callable, pc_cfg: PrecondConfig,
                     sv_cfg: SavicConfig):
    """loss_fn(params, microbatch) -> scalar.

    Returns ``round_step(state, batch, key)`` where each batch leaf is
    (M, H, ...): H microbatches per client per round. Returns (state, metrics).
    """
    return engine.build_round_step(loss_fn, engine_spec(pc_cfg, sv_cfg))


def _drift(params_m):
    """(1/M)Σ‖x^m − x̂‖² — the V_t of the analysis (0 right after sync)."""
    return engine.client_drift(params_m)


def average_params(state):
    return engine.average_params(state)
