"""Preconditioners under the paper's unified Assumption 4.

The paper analyses any diagonal scaling D̂ with ``αI ⪯ D̂ ⪯ ΓI`` built from one
of two EMA rules plus a positivity clip:

  rule (2):  (D^t)² = β_t (D^{t-1})² + (1-β_t) (H^t)²      (Adam / RMSProp /
                                                            AdaHessian / AdaGrad)
  rule (3):   D^t   = β_t  D^{t-1}   + (1-β_t)  H^t        (OASIS)
  rule (4):  (D̂)_ii = max{α, |D_ii|}   or   |D_ii| + α

with H^t one of
  * diag(g ⊙ g)                       — gradient second moment (Adam family)
  * diag(v ⊙ ∇²f v), v ~ Rademacher   — Hutchinson diagonal-Hessian estimate
                                        (OASIS / AdaHessian), computed with one
                                        extra HVP, never a full Hessian.

β_t schedules: constant (RMSProp/OASIS) or Adam's debiasing
β_t = (β - β^{t+1}) / (1 - β^{t+1}).  AdaGrad is the β_t→accumulate limit
(D² += H², no decay), included because the compared baseline [42] uses it.

All state lives in a plain dict pytree so it shards/checkpoints like params:
``{"d": tree, "t": i32}`` where ``d`` stores D (rule 3) or D² (rule 2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

KINDS = ("identity", "adam", "rmsprop", "adagrad", "oasis", "adahessian")


@dataclasses.dataclass(frozen=True)
class PrecondConfig:
    kind: str = "adam"
    beta2: float = 0.999
    alpha: float = 1e-8            # rule-(4) floor — the paper's α
    clip: str = "max"              # "max" (eq. 4) | "add"
    # β_t schedule: "const" | "debias" (Adam's (β-β^{t+1})/(1-β^{t+1}))
    beta_schedule: Optional[str] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind {self.kind}; expected one of {KINDS}")

    @property
    def rule(self) -> str:
        # eq. (2) squared EMA vs eq. (3) linear EMA
        return "linear" if self.kind == "oasis" else "squared"

    @property
    def schedule(self) -> str:
        if self.beta_schedule:
            return self.beta_schedule
        return "debias" if self.kind in ("adam", "adahessian") else "const"

    @property
    def uses_hutchinson(self) -> bool:
        return self.kind in ("oasis", "adahessian")


def init_state(cfg: PrecondConfig, params):
    """D^0 = I (satisfies Assumption 4 with α ≤ 1 ≤ Γ)."""
    if cfg.kind == "identity":
        return {"t": jnp.int32(0)}
    d = jax.tree.map(lambda p: jnp.ones_like(p, dtype=jnp.float32), params)
    return {"d": d, "t": jnp.int32(0)}


def beta_t(cfg: PrecondConfig, t):
    """β_{t+1} for the update at step t (0-based)."""
    b = cfg.beta2
    if cfg.kind == "adagrad":
        return None  # accumulate
    if cfg.schedule == "const":
        return jnp.float32(b)
    tt = t.astype(jnp.float32) + 1.0   # 1-based update index
    return (b - b ** tt) / (1.0 - b ** tt)


def grad_stat(grads):
    """H² for the Adam family: diag(g⊙g) (returned squared)."""
    return jax.tree.map(lambda g: (g.astype(jnp.float32)) ** 2, grads)


def hutchinson_diag(loss_fn: Callable, params, batch, key):
    """diag(v ⊙ ∇²f(x) v) with Rademacher v — one HVP via jvp-of-grad."""
    leaves = jax.tree.leaves(params)
    keys = jax.random.split(key, len(leaves))
    kit = iter(keys)
    v = jax.tree.map(
        lambda p: jax.random.rademacher(next(kit), p.shape,
                                        jnp.float32).astype(p.dtype), params)
    g_fn = jax.grad(lambda p: loss_fn(p, batch))
    _, hvp = jax.jvp(g_fn, (params,), (v,))
    return jax.tree.map(lambda vi, hi: (vi.astype(jnp.float32)
                                        * hi.astype(jnp.float32)), v, hvp)


def update(cfg: PrecondConfig, state, stat):
    """One D update from a stat tree.

    ``stat`` semantics: for rule (2) kinds, ``stat`` is H² (already squared);
    for rule (3) (OASIS), ``stat`` is H itself (may be negative — the clip
    handles sign).
    """
    if cfg.kind == "identity":
        return {"t": state["t"] + 1}
    t = state["t"]
    if cfg.kind == "adagrad":
        d = jax.tree.map(lambda d2, h2: d2 + h2, state["d"], stat)
    elif cfg.rule == "squared":
        b = beta_t(cfg, t)
        d = jax.tree.map(lambda d2, h2: b * d2 + (1.0 - b) * h2,
                         state["d"], stat)
    else:  # linear (OASIS)
        b = beta_t(cfg, t)
        d = jax.tree.map(lambda dd, h: b * dd + (1.0 - b) * h,
                         state["d"], stat)
    return {"d": d, "t": t + 1}


def dhat(cfg: PrecondConfig, state, leaf_of=None):
    """The clipped diagonal D̂ (rule 4), as a tree (or one leaf)."""

    def one(d):
        mag = jnp.sqrt(d) if cfg.rule == "squared" or cfg.kind == "adagrad" \
            else jnp.abs(d)
        if cfg.clip == "max":
            return jnp.maximum(cfg.alpha, mag)
        return mag + cfg.alpha

    if cfg.kind == "identity":
        return None
    if leaf_of is not None:
        return one(leaf_of)
    return jax.tree.map(one, state["d"])


def precondition(cfg: PrecondConfig, state, grads):
    """D̂^{-1} g — the scaled direction of Algorithm 1."""
    if cfg.kind == "identity":
        return grads
    dh = dhat(cfg, state)
    return jax.tree.map(lambda g, d: (g.astype(jnp.float32) / d).astype(g.dtype),
                        grads, dh)


def bounds(cfg: PrecondConfig, state):
    """(min, max) eigenvalue of D̂ across the tree — Lemma 1 check (α ≤ · ≤ Γ)."""
    if cfg.kind == "identity":
        return jnp.float32(1.0), jnp.float32(1.0)
    dh = dhat(cfg, state)
    mins = jnp.stack([x.min() for x in jax.tree.leaves(dh)])
    maxs = jnp.stack([x.max() for x in jax.tree.leaves(dh)])
    return mins.min(), maxs.max()


def theory_beta_lower_bound(cfg: PrecondConfig, gamma, mu, Gamma):
    """Corollary 1's β_{t+1} lower bound keeping the norm-drift ≤ (1+γμ/2Γ)."""
    a = cfg.alpha
    if cfg.rule == "squared":
        return 1.0 - gamma * mu * a**2 / Gamma**3
    return 1.0 - gamma * mu * a / (4.0 * Gamma**2)
