"""Fused SAVIC local step — Pallas TPU kernel.

The paper's inner loop is elementwise and memory-bound:

    m' = β₁ m + g
    D̂  = max(α, √d)   (rule-2 state)  or  max(α, |d|)  (rule-3 state)
    p' = p − γ m' / D̂

Unfused, XLA emits ~6 HBM reads + 4 writes per element across several loop
nests; fused we do 4 reads (p, m, g, d) + 2 writes (p', m') in one pass —
~1.7× less HBM traffic on the optimizer step, which runs H times per round on
every client. Blocks are flat (BLOCK,) slices, BLOCK = 8·128·16 lanes so each
VMEM working set is ~6·BLOCK·4B ≈ 400 KiB ≪ 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 16


def _kernel(p_ref, m_ref, g_ref, d_ref, po_ref, mo_ref, *, gamma, beta1,
            alpha, squared):
    m = beta1 * m_ref[...] + g_ref[...]
    d = d_ref[...]
    mag = jnp.sqrt(d) if squared else jnp.abs(d)
    dhat = jnp.maximum(alpha, mag)
    po_ref[...] = p_ref[...] - gamma * m / dhat
    mo_ref[...] = m


@functools.partial(jax.jit,
                   static_argnames=("gamma", "beta1", "alpha", "squared",
                                    "interpret"))
def scaled_update_flat(p, m, g, d, *, gamma, beta1, alpha, squared=True,
                       interpret=False):
    """Flat fp32 arrays (n,) -> (p', m'). Pads to BLOCK internally."""
    n = p.shape[0]
    npad = (BLOCK - n % BLOCK) % BLOCK
    if npad:
        pad = lambda x, v: jnp.concatenate([x, jnp.full((npad,), v, x.dtype)])
        p, m, g = pad(p, 0), pad(m, 0), pad(g, 0)
        d = pad(d, 1.0)  # keep D̂ away from 0 in the padding
    grid = (p.shape[0] // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    kern = functools.partial(_kernel, gamma=gamma, beta1=beta1, alpha=alpha,
                             squared=squared)
    po, mo = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype)] * 2,
        interpret=interpret,
    )(p, m, g, d)
    return po[:n], mo[:n]
