"""Fused local-step kernels — Pallas TPU.

Two generations live here:

* ``scaled_update_flat`` — the original fused SAVIC step on one flat fp32
  array: ``m' = β₁m + g``, ``D̂ = clip(mag(d))``, ``p' = p − γ m'/D̂``.  Kept as
  the public per-leaf kernel (``ops.scaled_update``) and as the "pre-PR
  kernel path" baseline in ``benchmarks/run.py --only kernels``.

* ``fused_step_flat`` — the flat-buffer kernel FAMILY (DESIGN.md §7): the
  whole generic-scaling local step of the paper's unified Assumption-4 rule
  in ONE pass over the per-client flat buffer ``(M, n)``.  Fuses the D̂
  update — rule-2 squared EMA (Adam/RMSProp), rule-3 linear EMA (OASIS),
  AdaGrad accumulate, β_t const or Adam-debias (``t`` rides as a scalar
  prefetch) — together with the momentum + scaled parameter update, for
  every ``PrecondConfig`` kind including identity.  Per element that is
  4–5 HBM reads (p, m, g, d[, h]) + 3 writes (p', m', d') where the per-leaf
  path paid 6+ reads / 4 writes across three launches (momentum pass,
  per-leaf kernel, separate D̂ EMA pass).  The grid is ``(M, n/BLOCK)`` so
  one ``pallas_call`` covers every client's step; per-client scalars (step
  counter ``t``, grad-clip scale ``s``) are scalar-prefetch operands indexed
  by ``program_id(0)``.

The kernel body calls ``ref.fused_step_math`` — the pure-jnp oracle is the
single source of truth for the formula, and the engine's unfused tree path is
pinned bit-identical to it (tests/test_fused_step.py).

Padding contract (audited per rule, pinned at n % BLOCK ∈ {0, 1, BLOCK−1}):

* ``fused_step_flat`` does NOT pad.  The grid's tail block is partial and
  Pallas handles it implicitly (reads of the out-of-range lanes see runtime
  padding, their stores are dropped) — safe for EVERY rule because the step
  is elementwise: no value crosses lanes, and tail lanes never reach an
  output.  This matters in the hot loop: an explicit ``jnp.pad`` before a
  custom call materializes a full copy of every operand (and ``[:n]`` a copy
  of every output) per local step.
* the legacy ``scaled_update_flat`` keeps its explicit pads (it predates the
  flat-buffer path and is the benchmark's pre-PR baseline): p/m/g → 0 and
  d → 1.0, which keeps D̂ = 1 in the pad under BOTH the rule-2 √d and the
  rule-3 |d| magnitudes, so pad lanes stay finite for every (clip, α ≥ 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as kref

BLOCK = 8 * 128 * 16


def _block_for(n: int, block: int) -> int:
    """Lane-aligned block: small arrays get one 128-multiple block instead of
    padding all the way to BLOCK (identical results — elementwise kernel)."""
    aligned = -(-n // 128) * 128
    return min(block, aligned)


def _pad1(x, n_pad, value):
    npad = n_pad - x.shape[-1]
    if not npad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, npad)]
    return jnp.pad(x, widths, constant_values=value)


# --------------------------------------------------------------------------- #
# original per-leaf kernel (rule-4 clip "max" only, D fixed)
# --------------------------------------------------------------------------- #


def _kernel(p_ref, m_ref, g_ref, d_ref, po_ref, mo_ref, *, gamma, beta1,
            alpha, squared):
    m = beta1 * m_ref[...] + g_ref[...]
    d = d_ref[...]
    mag = jnp.sqrt(d) if squared else jnp.abs(d)
    dhat = jnp.maximum(alpha, mag)
    po_ref[...] = p_ref[...] - gamma * m / dhat
    mo_ref[...] = m


@functools.partial(jax.jit,
                   static_argnames=("gamma", "beta1", "alpha", "squared",
                                    "interpret"))
def scaled_update_flat(p, m, g, d, *, gamma, beta1, alpha, squared=True,
                       interpret=False):
    """Flat fp32 arrays (n,) -> (p', m'). Pads to a lane-aligned block
    internally (see the module padding contract: p/m/g → 0, d → 1.0 keeps
    D̂ = 1 in the pad for BOTH the rule-2 √d and the rule-3 |d| magnitude)."""
    n = p.shape[0]
    blk = _block_for(n, BLOCK)
    n_pad = -(-n // blk) * blk
    p, m, g = (_pad1(x, n_pad, 0) for x in (p, m, g))
    d = _pad1(d, n_pad, 1.0)
    grid = (n_pad // blk,)
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    kern = functools.partial(_kernel, gamma=gamma, beta1=beta1, alpha=alpha,
                             squared=squared)
    po, mo = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype)] * 2,
        interpret=interpret,
    )(p, m, g, d)
    return po[:n], mo[:n]


# --------------------------------------------------------------------------- #
# fused flat-buffer kernel family: one pass per local step, every D̂ rule
# --------------------------------------------------------------------------- #


def _fused_kernel(t_ref, s_ref, *refs, n_in, gamma, beta1, weight_decay,
                  alpha, beta2, kind, clip, schedule, update_d, has_d,
                  has_h, clipped, needs_t):
    i = pl.program_id(0)
    it = iter(refs[:n_in])
    p, m, g = next(it)[...], next(it)[...], next(it)[...]
    d = next(it)[...] if has_d else None
    h = next(it)[...] if has_h else None
    t = t_ref[i] if needs_t else None
    s = s_ref[i] if clipped else None
    p_new, m_new, d_new = kref.fused_step_math(
        p, m, g, d, h, t, s, gamma=gamma, beta1=beta1,
        weight_decay=weight_decay, alpha=alpha, beta2=beta2, kind=kind,
        clip=clip, schedule=schedule, update_d=update_d)
    outs = refs[n_in:]
    outs[0][...] = p_new
    outs[1][...] = m_new
    if update_d:
        outs[2][...] = d_new


@functools.partial(jax.jit,
                   static_argnames=("gamma", "beta1", "weight_decay", "alpha",
                                    "beta2", "kind", "clip", "schedule",
                                    "update_d", "block", "interpret"))
def fused_step_flat(p, m, g, d=None, h=None, t=None, s=None, *, gamma, beta1,
                    weight_decay=0.0, alpha, beta2=0.999, kind, clip="max",
                    schedule="const", update_d=False, block=BLOCK,
                    interpret=False):
    """One fused local step on per-client flat buffers.

    Shapes: ``p/m/g`` (M, n) fp32; ``d`` (M, n) for local scaling, (n,) for
    global (client-shared D̂), None for the identity kind; ``h`` (M, n)
    external stat (Hutchinson kinds) or None for the in-kernel grad² stat;
    ``t`` (M,) i32 per-client step counters (scalar prefetch; required for the
    debias schedule); ``s`` (M,) f32 per-client grad-clip scales or None.

    Returns ``(p', m', d')`` with ``d'`` None unless ``update_d`` (which
    requires a local, (M, n)-shaped ``d``).
    """
    M, n = p.shape
    has_d = d is not None
    has_h = h is not None
    global_d = has_d and d.ndim == 1
    clipped = s is not None
    needs_t = update_d and schedule == "debias" and kind != "adagrad"
    if update_d and (not has_d or global_d):
        raise ValueError("update_d needs a per-client (M, n) d buffer")
    if needs_t and t is None:
        raise ValueError("debias schedule needs per-client t")

    blk = _block_for(n, block)
    # no explicit padding: the tail block is partial and Pallas masks it
    # (see the module padding contract) — an explicit pad would copy every
    # operand per local step
    operands = [p, m, g]
    row_spec = pl.BlockSpec((1, blk), lambda i, j, t_ref, s_ref: (i, j))
    in_specs = [row_spec] * 3
    if has_d:
        operands.append(d)
        in_specs.append(pl.BlockSpec((blk,), lambda i, j, t_ref, s_ref: (j,))
                        if global_d else row_spec)
    if has_h:
        operands.append(h)
        in_specs.append(row_spec)
    if t is None:
        t = jnp.zeros((M,), jnp.int32)
    if s is None:
        s = jnp.ones((M,), jnp.float32)

    n_out = 3 if update_d else 2
    kern = functools.partial(
        _fused_kernel, n_in=len(operands), gamma=gamma, beta1=beta1,
        weight_decay=weight_decay, alpha=alpha, beta2=beta2, kind=kind,
        clip=clip, schedule=schedule, update_d=update_d, has_d=has_d,
        has_h=has_h, clipped=clipped, needs_t=needs_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M, -(-n // blk)),
        in_specs=in_specs,
        out_specs=[row_spec] * n_out,
    )
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((M, n), jnp.float32)] * n_out,
        interpret=interpret,
    )(t, s, *operands)
    po, mo = outs[0], outs[1]
    do = outs[2] if update_d else None
    return po, mo, do
