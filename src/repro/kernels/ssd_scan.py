"""Mamba2 SSD intra-chunk kernel — Pallas TPU.

Computes, per (batch, chunk, head) grid cell, the chunk-local SSD quantities
(the MXU-heavy part of state-space duality):

    cum      = cumsum(dA)                          (Q,)
    L        = exp(cum_i - cum_j) · 1[i>=j]        (Q, Q)
    Y_diag   = ((C Bᵀ) ⊙ L) (x·dt)                 (Q, P)
    S_chunk  = Bᵀ diag(exp(cum_Q - cum)) (x·dt)    (N, P)
    total    = exp(cum_Q)                          scalar

The sequential inter-chunk recurrence (nc steps, O(N·P) each) stays a host
``lax.scan`` — it is trivially cheap and latency-bound, not kernel-worthy.
Grid (B·nc, H): each cell's VMEM = Q·N·2 + Q·P·2 + Q·Q floats ≈ 0.9 MiB at
Q=256, N=128, P=64 — well inside VMEM, MXU contractions all ≥128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, tot_ref, *, Q):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    A = a_ref[0]                                     # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)

    dA = dt * A                                      # (Q,)
    cum = jnp.cumsum(dA)                             # (Q,)
    xdt = x * dt[:, None]                            # (Q, P)

    diff = cum[:, None] - cum[None, :]               # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    mask = ii >= jj
    Lm = jnp.exp(jnp.where(mask, diff, -1e30)) * mask  # mask pre-exp (no inf)

    G = Cm @ Bm.T                                    # (Q, Q)  MXU
    y_ref[0, :, 0, :] = ((G * Lm) @ xdt).astype(y_ref.dtype)

    decay_out = jnp.exp(cum[-1] - cum)               # (Q,)
    s_ref[0, 0] = (Bm.T @ (xdt * decay_out[:, None])).astype(s_ref.dtype)
    tot_ref[0, 0] = jnp.exp(cum[-1]).astype(tot_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk(xh, dt, A, Bm, Cm, *, chunk, interpret=False):
    """xh (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,H,N), S % chunk == 0.

    Returns (Y_diag (B,S,H,P), S_chunk (B,nc,H,N,P), total (B,nc,H)) — feed to
    the host inter-chunk scan (models/ssm.ssd_chunked does the same math in
    pure JAX; kernels/ref.py wraps it as the oracle).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = chunk
    nc = S // Q
    grid = (B * nc, H)

    def idx4(i, h):       # (B,S,H,{P,N}) blocked to (1,Q,1,*)
        return (i // nc, i % nc, h, 0)

    def idx3(i, h):       # (B,S,H) blocked to (1,Q,1)
        return (i // nc, i % nc, h)

    y, s, tot = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), idx4),
            pl.BlockSpec((1, Q, 1), idx3),
            pl.BlockSpec((1,), lambda i, h: (h,)),
            pl.BlockSpec((1, Q, 1, N), idx4),
            pl.BlockSpec((1, Q, 1, N), idx4),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), idx4),
            pl.BlockSpec((1, 1, N, P), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, h: (i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B * nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B * nc, H), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dt, A, Bm, Cm)
    return y, s.reshape(B, nc, H, N, P), tot.reshape(B, nc, H)


def ssd_kernel_forward(xh, dt, A, Bm, Cm, chunk, interpret=False):
    """Full SSD using the Pallas intra-chunk kernel + host inter-chunk scan.
    Drop-in equal to models.ssm.ssd_chunked (tested against it)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    Yd, S_c, total = ssd_intra_chunk(xh, dt, A, Bm, Cm, chunk=chunk,
                                     interpret=interpret)

    def step(h, xs):
        s_c, tot = xs
        return tot[..., None, None] * h + s_c, h

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    # S_c is (B,nc,H,N,P) -> scan over nc with (B,H,P,N) states
    s_cs = S_c.transpose(1, 0, 2, 4, 3)              # (nc,B,H,P,N)
    tots = total.transpose(1, 0, 2)                  # (nc,B,H)
    h_fin, h_prevs = jax.lax.scan(step, h0, (s_cs, tots))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)       # (B,nc,H,P,N)

    dA = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]
    cum = jnp.cumsum(dA.reshape(B, nc, chunk, H), axis=2)
    decay_in = jnp.exp(cum)                          # (B,nc,Q,H)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, chunk, H, N)
    Y_off = jnp.einsum("bcihn,bcih,bchpn->bcihp", Cc, decay_in, h_prevs)
    y = Yd.reshape(B, nc, chunk, H, P) + Y_off
    return y.reshape(B, S, H, P), h_fin
