"""Blockwise (flash) causal attention — Pallas TPU kernel.

Layout: q (B, H, S, D), k/v (B, Hk, S, D) — GQA handled in the BlockSpec
index map (kv head = q head // rep), so no repeated-KV materialization.

Grid = (B, H, nq, nk) with the kv dim innermost/sequential ("arbitrary"):
running (m, l, acc) live in VMEM scratch and persist across the kv loop;
the output block is written on the last kv step. Causal + optional sliding
window handled by masking; fully-masked kv blocks are skipped with pl.when
(upper-triangle blocks cost nothing).

Block sizes default to (128, 128) — MXU-aligned; VMEM working set per step is
q(128·D) + k(128·D) + v(128·D) + scores(128·128) ≈ 0.4 MiB at D=128 fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq, bk, nk, window, softcap, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    diag_ok = k_start <= q_start + bq - 1           # any unmasked causal pair
    win_ok = True
    if window:
        win_ok = (q_start - (k_start + bk - 1)) < window

    @pl.when(jnp.logical_and(diag_ok, win_ok))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = q @ k.T                                  # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols <= rows
        if window:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr \
            + p @ v_ref[0, 0].astype(jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         bq=128, bk=128, interpret=False):
    """q (B,H,S,D), k/v (B,Hk,S,D) -> (B,H,S,D). Causal only (decoder LMs)."""
    assert causal, "only causal attention is implemented"
    B, H, S, D = q.shape
    Hk = k.shape[1]
    rep = H // Hk
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    grid = (B, H, nq, nk)

    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, window=window,
                             softcap=softcap, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        # jax renamed TPUCompilerParams -> CompilerParams across versions;
        # take whichever this jaxlib ships
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
