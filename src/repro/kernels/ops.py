"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in interpret mode — the kernel body
executes in Python on CPU, which is the validation path; on TPU the same calls
compile to Mosaic. ``REPRO_PALLAS_INTERPRET=0/1`` overrides autodetection.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import decode_step as _ds
from repro.kernels import flash_attention as _fa
from repro.kernels import quantize_update as _qu
from repro.kernels import scaled_update as _su
from repro.kernels import ssd_scan as _ssd
from repro.utils.tree import tree_from_paths


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() == "cpu"


def scaled_update(p, m, g, d, *, gamma, beta1, alpha, squared=True):
    """Fused SAVIC step on arbitrarily-shaped arrays."""
    shape = p.shape
    flat = lambda x: x.reshape(-1).astype(jnp.float32)
    po, mo = _su.scaled_update_flat(flat(p), flat(m), flat(g), flat(d),
                                    gamma=float(gamma), beta1=float(beta1),
                                    alpha=float(alpha), squared=squared,
                                    interpret=_interpret())
    return po.reshape(shape).astype(p.dtype), mo.reshape(shape).astype(m.dtype)


def scaled_update_tree(params, mom, d_tree, gamma, alpha, squared=True):
    """Tree version used by core/savic.py (beta1 pre-applied in mom)."""
    out_p, out_m = {}, {}
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(mom)
    flat_d = jax.tree.leaves(d_tree)
    treedef = jax.tree.structure(params)
    news = [scaled_update(p, jnp.zeros_like(m), m, d, gamma=gamma, beta1=0.0,
                          alpha=alpha, squared=squared)[0]
            for p, m, d in zip(flat_p, flat_m, flat_d)]
    return jax.tree.unflatten(treedef, news)


def fused_local_step(p, m, g, d=None, h=None, t=None, s=None, *, gamma, beta1,
                     weight_decay=0.0, alpha, beta2=0.999, kind, clip="max",
                     schedule="const", update_d=False):
    """One fused generic-scaling local step on (M, n) flat client buffers.

    The engine's ``use_fused_kernel`` fast path (DESIGN.md §7): fuses the D̂
    update (rule-2/rule-3/AdaGrad, const or debias β_t) with the momentum and
    scaled parameter update in ONE ``pallas_call`` covering all M clients.
    ``d`` is (M, n) for local scaling, (n,) for global, None for identity;
    ``h`` is the external (Hutchinson) stat; ``t``/``s`` are per-client step
    counters / grad-clip scales (scalar prefetch). Returns (p', m', d'|None).
    """
    return _su.fused_step_flat(p, m, g, d, h, t, s, gamma=float(gamma),
                               beta1=float(beta1),
                               weight_decay=float(weight_decay),
                               alpha=float(alpha), beta2=float(beta2),
                               kind=kind, clip=clip, schedule=schedule,
                               update_d=update_d, interpret=_interpret())


def quantize_update(x, u, scale):
    """Fused stochastic int8 encode + fp32 decode on arbitrarily-shaped arrays.

    ``u`` are U[0,1) draws shaped like x; ``scale`` broadcasts to x.shape
    (per-client absmax/127 in the engine). Returns (q int8, decoded fp32)
    with x's shape.
    """
    shape = x.shape
    flat = lambda a: jnp.broadcast_to(a, shape).reshape(-1).astype(jnp.float32)
    q, dec = _qu.quantize_update_flat(flat(x), flat(u), flat(scale),
                                      interpret=_interpret())
    return q.reshape(shape), dec.reshape(shape).astype(x.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    bq=128, bk=128):
    """(B,S,H,D) layout in, (B,S,H,D) out (transposes to kernel layout)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ot = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                  softcap=softcap, bq=bq, bk=bk,
                                  interpret=_interpret())
    return ot.transpose(0, 2, 1, 3)


def decode_attention(q, k, v, bias, *, softcap=0.0):
    """Fused single-query decode attention against one KV ring.

    q (B,H,D); k/v (B,C,Hk,D/Dv) in decode-cache layout; bias (B,C) additive
    fp32 mask (causal + window + ring validity, precomputed by the caller).
    Returns (B,H,Dv) fp32 — bitwise-equal to ``ref.decode_attention_ref``.
    """
    return _ds.decode_attention(q, k, v, bias, softcap=float(softcap),
                                interpret=_interpret())


def decode_sample(y, table, noise, *, scale, v_real, block=2048):
    """Fused unembed + gumbel-argmax sampling tail.

    y (B,d) final hidden; table (V,d); noise (B,V) fp32 (zeros = greedy).
    Returns token ids (B,) int32 without materialising the (B,V) logits —
    bitwise-equal to ``ref.decode_sample_ref``.
    """
    return _ds.decode_sample(y, table, noise, scale=float(scale),
                             v_real=int(v_real), block=block,
                             interpret=_interpret())


def ssd(xh, dt, A, Bm, Cm, *, chunk):
    """Chunked SSD via the Pallas intra-chunk kernel + host inter-chunk scan."""
    return _ssd.ssd_kernel_forward(xh, dt, A, Bm, Cm, chunk,
                                   interpret=_interpret())
