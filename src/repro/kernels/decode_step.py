"""Fused decode-step kernels — Pallas TPU.

Two kernels cover the decode hot loop's bandwidth-bound spots (DESIGN.md §8):

* ``decode_attention`` — single-query flash decode: one grid cell per
  (batch-slot, kv-head) reads the slot's whole C-deep KV ring plus a
  precomputed additive mask bias (causal/window/ring-validity — computed by
  the caller in O(C) jnp, which keeps the kernel agnostic to traced per-layer
  windows) and produces the attended output for that head group.

* ``decode_sample`` — the logits→token tail: unembed matmul against the
  (V, d) embedding table fused with a running blockwise argmax over vocab
  blocks, so the (B, V) logits are never materialised in HBM. ``noise`` is an
  additive (B, V) fp32 operand: zeros = greedy argmax; Gumbel draws =
  categorical sampling (the Gumbel-max trick — bitwise what
  ``jax.random.categorical`` computes).

Both kernel bodies source their math from ``kernels/ref.py`` (the
``fused_step_flat`` contract pattern), and the shared math uses
elementwise-mul + axis-sum contractions rather than ``jnp.dot`` so the
per-cell kernel blocks and the batched oracle reduce in the same order —
that is what makes fused == oracle *bitwise* on every backend (a dot-general
would pick shape-dependent accumulation orders; see tests/test_serve.py).

VMEM note: ``decode_attention`` holds one slot's full KV in VMEM — C·D·8
bytes fp32 per (k, v); fine up to the LONG_DECODE_WINDOW ring (8192·64·4·2
≈ 4 MiB) but not for an unwindowed 500k cache — long contexts must decode
through ``decode_window``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as kref

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams across versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


# --------------------------------------------------------------------------- #
# single-query decode attention
# --------------------------------------------------------------------------- #


def _attn_kernel(q_ref, k_ref, v_ref, b_ref, o_ref, *, softcap):
    q = q_ref[0, 0]            # (rep, D)
    k = k_ref[0, :, 0, :]      # (C, D)
    v = v_ref[0, :, 0, :]      # (C, Dv)
    bias = b_ref[0]            # (C,)
    o_ref[0, 0] = kref.decode_attention_math(q, k, v, bias, softcap)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention(q, k, v, bias, *, softcap=0.0, interpret=False):
    """q (B,H,D), k/v (B,C,Hk,D/Dv) cache layout, bias (B,C) fp32 additive
    mask -> (B,H,Dv) fp32."""
    B, H, D = q.shape
    C, Hk = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    rep = H // Hk
    qr = q.reshape(B, Hk, rep, D)
    kern = functools.partial(_attn_kernel, softcap=softcap)
    out = pl.pallas_call(
        kern,
        grid=(B, Hk),
        in_specs=[
            pl.BlockSpec((1, 1, rep, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, C, 1, D), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C, 1, Dv), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, C), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, Dv), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, rep, Dv), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(qr, k, v, bias)
    return out.reshape(B, H, Dv)


# --------------------------------------------------------------------------- #
# fused unembed + sampling tail
# --------------------------------------------------------------------------- #


def _sample_kernel(y_ref, t_ref, n_ref, best_ref, arg_ref, *, blk, v_real,
                   scale):
    j = pl.program_id(0)
    logits = kref.decode_sample_math(y_ref[...], t_ref[...], n_ref[...],
                                     scale)                       # (B, blk)
    vidx = j * blk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(vidx < v_real, logits, NEG_INF)
    m = logits.max(axis=1)                                        # (B,)
    a = (j * blk + jnp.argmax(logits, axis=1)).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        best_ref[0] = m
        arg_ref[0] = a

    @pl.when(j > 0)
    def _update():
        prev = best_ref[0]
        upd = m > prev            # strict: earlier block wins ties, like argmax
        arg_ref[0] = jnp.where(upd, a, arg_ref[0])
        best_ref[0] = jnp.where(upd, m, prev)


@functools.partial(jax.jit, static_argnames=("scale", "v_real", "block",
                                             "interpret"))
def decode_sample(y, table, noise, *, scale, v_real, block=2048,
                  interpret=False):
    """y (B,d) final hidden, table (V,d), noise (B,V) fp32 -> token ids (B,).

    token[b] = argmax_v<v_real (y[b]·table[v])*scale + noise[b,v]. The vocab
    grid is sequential ("arbitrary"): a running (best, arg) pair lives in the
    output blocks across vocab steps.
    """
    B, d = y.shape
    V = table.shape[0]
    block = min(block, V)
    assert V % block == 0, (V, block)
    kern = functools.partial(_sample_kernel, blk=block, v_real=v_real,
                             scale=scale)
    _, arg = pl.pallas_call(
        kern,
        grid=(V // block,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),
            pl.BlockSpec((block, d), lambda j: (j, 0)),
            pl.BlockSpec((B, block), lambda j: (0, j)),
        ],
        out_specs=[pl.BlockSpec((1, B), lambda j: (0, 0)),
                   pl.BlockSpec((1, B), lambda j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, B), jnp.float32),
                   jax.ShapeDtypeStruct((1, B), jnp.int32)],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(y, table, noise)
    return arg[0]
