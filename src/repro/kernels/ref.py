"""Pure-jnp oracles for every Pallas kernel (the contract each kernel must
match under assert_allclose across shape/dtype sweeps — see tests/test_kernels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scaled_update_ref(p, m, g, d, *, gamma, beta1, alpha, squared=True):
    m_new = beta1 * m + g
    mag = jnp.sqrt(d) if squared else jnp.abs(d)
    dhat = jnp.maximum(alpha, mag)
    return p - gamma * m_new / dhat, m_new


def quantize_update_ref(x, u, scale):
    """Stochastic int8 QDQ: q = clip(floor(x/s + u), ±127), dec = q·s."""
    s = jnp.broadcast_to(scale, x.shape).astype(jnp.float32)
    safe = jnp.where(s > 0, s, 1.0)
    v = jnp.where(s > 0, x.astype(jnp.float32) / safe, 0.0)
    qf = jnp.clip(jnp.floor(v + u), -127.0, 127.0)
    return qf.astype(jnp.int8), (qf * s).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,H,S,D), k/v (B,Hk,S,D) -> (B,H,S,D). Dense fp32 softmax."""
    B, H, S, D = q.shape
    Hk = k.shape[1]
    rep = H // Hk
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * D**-0.5, kf)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)
    mask = i[None, :] <= i[:, None]
    if window:
        mask &= (i[:, None] - i[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)


def ssd_ref(xh, dt, A, Bm, Cm):
    """Naive sequential SSD recurrence (see models/ssm.ssd_reference)."""
    from repro.models.ssm import ssd_reference
    return ssd_reference(xh, dt, A, Bm, Cm)
