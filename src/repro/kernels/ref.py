"""Pure-jnp oracles for every Pallas kernel (the contract each kernel must
match under assert_allclose across shape/dtype sweeps — see tests/test_kernels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scaled_update_ref(p, m, g, d, *, gamma, beta1, alpha, squared=True):
    m_new = beta1 * m + g
    mag = jnp.sqrt(d) if squared else jnp.abs(d)
    dhat = jnp.maximum(alpha, mag)
    return p - gamma * m_new / dhat, m_new


def fused_step_math(p, m, g, d, h, t, s, *, gamma, beta1, weight_decay,
                    alpha, beta2, kind, clip, schedule, update_d):
    """One generic-scaling local step — the paper's unified Assumption-4 rule.

    The single source of truth for the fused flat-buffer kernel
    (``scaled_update.fused_step_flat`` runs this per block; DESIGN.md §7).
    The D math itself is NOT re-implemented: this delegates to
    ``preconditioner.update``/``dhat`` on the bare buffers (they are valid
    single-leaf pytrees), so the fused kernel and the engine's unfused tree
    path share one copy of the Assumption-4 formulas — which is what makes
    the trajectories agree bitwise in fp32, and keeps a future rule/schedule
    change from silently diverging.

    ``d``/``h``/``t``/``s`` may be None when the mode doesn't use them
    (identity kind; in-kernel grad² stat; const schedule; no grad clip);
    ``t``/``s`` must already broadcast against ``p`` (scalar in the kernel,
    ``(M, 1)`` in the reference). Returns ``(p', m', d')`` with ``d'`` None
    unless ``update_d``.
    """
    from repro.core import preconditioner as PC
    cfg = PC.PrecondConfig(kind=kind, beta2=beta2, alpha=alpha, clip=clip,
                           beta_schedule=schedule)
    if s is not None:
        g = g * s                       # engine._clip's per-client scale
    d_new = None
    if update_d:                        # local scaling: D advances every step
        stat = (g ** 2) if h is None else h   # grad_stat | external Hutchinson
        tt = t if t is not None else jnp.int32(0)   # unused by const/adagrad
        d_new = PC.update(cfg, {"d": d, "t": tt}, stat)["d"]
        d = d_new
    if weight_decay:
        g = g + weight_decay * p
    m_new = beta1 * m + g
    if kind == "identity":
        p_new = p - gamma * m_new
    else:
        p_new = p - gamma * (m_new / PC.dhat(cfg, None, leaf_of=d))
    return p_new, m_new, d_new


def fused_step_ref(p, m, g, d=None, h=None, t=None, s=None, *, gamma, beta1,
                   weight_decay=0.0, alpha, beta2=0.999, kind, clip="max",
                   schedule="const", update_d=False):
    """(M, n) reference for the fused kernel: per-row t/s broadcast over n."""
    t2 = None if t is None else t[:, None]
    s2 = None if s is None else s[:, None]
    return fused_step_math(p, m, g, d, h, t2, s2, gamma=gamma, beta1=beta1,
                           weight_decay=weight_decay, alpha=alpha, beta2=beta2,
                           kind=kind, clip=clip, schedule=schedule,
                           update_d=update_d)


def quantize_update_ref(x, u, scale):
    """Stochastic int8 QDQ: q = clip(floor(x/s + u), ±127), dec = q·s."""
    s = jnp.broadcast_to(scale, x.shape).astype(jnp.float32)
    safe = jnp.where(s > 0, s, 1.0)
    v = jnp.where(s > 0, x.astype(jnp.float32) / safe, 0.0)
    qf = jnp.clip(jnp.floor(v + u), -127.0, 127.0)
    return qf.astype(jnp.int8), (qf * s).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,H,S,D), k/v (B,Hk,S,D) -> (B,H,S,D). Dense fp32 softmax."""
    B, H, S, D = q.shape
    Hk = k.shape[1]
    rep = H // Hk
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * D**-0.5, kf)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)
    mask = i[None, :] <= i[:, None]
    if window:
        mask &= (i[:, None] - i[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)


def decode_attention_math(q, k, v, bias, softcap):
    """Single-query decode attention for one (batch-slot, kv-head) cell.

    q (..., R, D) query heads sharing one kv head; k (..., C, D),
    v (..., C, Dv); bias (..., C) additive fp32 mask (causal/window/ring
    validity, from models.layers._mask_bias). The single source of truth for
    ``decode_step.decode_attention``: contractions are elementwise-mul +
    axis-sum (not dot_general) so the per-cell kernel blocks and the batched
    oracle accumulate in the same order — fused == unfused *bitwise*.
    """
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    kf = k.astype(jnp.float32)
    s = (qf[..., :, None, :] * kf[..., None, :, :]).sum(-1)       # (..., R, C)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + bias[..., None, :].astype(jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    vf = v.astype(jnp.float32)
    return (w[..., :, :, None] * vf[..., None, :, :]).sum(-2)     # (..., R, Dv)


def decode_attention_ref(q, k, v, bias, *, softcap=0.0):
    """q (B,H,D), k/v (B,C,Hk,D/Dv) cache layout, bias (B,C) -> (B,H,Dv)."""
    B, H, D = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    qr = q.reshape(B, Hk, rep, D)
    kr = k.transpose(0, 2, 1, 3)                                  # (B,Hk,C,D)
    vr = v.transpose(0, 2, 1, 3)
    out = decode_attention_math(qr, kr, vr, bias[:, None, :], softcap)
    return out.reshape(B, H, -1)


def decode_sample_math(y, table, noise, scale):
    """One vocab-block logit tile: (y·table_v)*scale + noise.

    y (B,d), table (blk,d), noise (B,blk) -> (B,blk) fp32. Mul+sum
    contraction for the same bitwise reason as ``decode_attention_math``.
    """
    s = (y.astype(jnp.float32)[:, None, :]
         * table.astype(jnp.float32)[None, :, :]).sum(-1)
    return s * scale + noise.astype(jnp.float32)


def decode_sample_ref(y, table, noise, *, scale, v_real, block=2048):
    """Blockwise argmax over the vocab, walking blocks in kernel order (the
    strict ``>`` running compare reproduces full-argmax first-index
    tie-breaking). Returns token ids (B,) int32."""
    V = table.shape[0]
    block = min(block, V)
    assert V % block == 0, (V, block)
    vidx = jnp.arange(V)
    best = jnp.full((y.shape[0],), -jnp.inf, jnp.float32)
    arg = jnp.zeros((y.shape[0],), jnp.int32)
    for j in range(V // block):
        sl = slice(j * block, (j + 1) * block)
        logits = decode_sample_math(y, table[sl], noise[:, sl], scale)
        logits = jnp.where(vidx[None, sl] < v_real, logits, -1e30)
        m = logits.max(axis=1)
        a = (j * block + jnp.argmax(logits, axis=1)).astype(jnp.int32)
        upd = m > best
        arg = jnp.where(upd, a, arg)
        best = jnp.where(upd, m, best)
    return arg


def ssd_ref(xh, dt, A, Bm, Cm):
    """Naive sequential SSD recurrence (see models/ssm.ssd_reference)."""
    from repro.models.ssm import ssd_reference
    return ssd_reference(xh, dt, A, Bm, Cm)
