"""Pallas TPU kernels for the perf-critical compute spots.

<name>.py    pl.pallas_call + BlockSpec implementations
ops.py       jit'd public wrappers (interpret-mode autodetect on CPU)
ref.py       pure-jnp oracles the kernels are tested against
"""
from repro.kernels import ops, ref  # noqa
