"""Fused stochastic int8 quantize–dequantize — Pallas TPU kernel.

The sync compression layer (``engine.CompressionSpec(op="int8-stochastic")``)
encodes each client→server round delta as int8 with a per-(client, leaf)
fp32 scale and immediately decodes to fp32 for the weighted sync average:

    v   = x / s            (0 where s == 0)
    q   = clip(floor(v + u), −127, 127)     u ~ U[0, 1)  ⇒  E[q·s] = x
    dec = q · s

Unfused, XLA emits separate div/floor/clip/mul loop nests (~5 HBM reads +
3 writes per element); fused we do 3 reads (x, u, s) + 2 writes (q, dec) in
one pass. Blocks mirror ``scaled_update.py``: flat (BLOCK,) slices with
BLOCK = 8·128·16 lanes, ~5·BLOCK·4B ≈ 330 KiB VMEM working set ≪ 16 MiB.

The U[0,1) draws are an explicit input stream — NOT ``pltpu.prng_random_bits``
— so the kernel is bit-reproducible against the inline jnp path in
``engine._compress_leaf`` (differential-tested in tests/test_compression.py)
and runs in interpret mode on CPU. On TPU the scale (constant per call site)
would move to SMEM and the uniforms to the on-core PRNG.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128 * 16


def _kernel(x_ref, u_ref, s_ref, q_ref, dec_ref):
    x, s = x_ref[...], s_ref[...]
    safe = jnp.where(s > 0, s, 1.0)
    v = jnp.where(s > 0, x / safe, 0.0)
    qf = jnp.clip(jnp.floor(v + u_ref[...]), -127.0, 127.0)
    q_ref[...] = qf.astype(jnp.int8)
    dec_ref[...] = qf * s


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_update_flat(x, u, s, *, interpret=False):
    """Flat fp32 arrays (n,) -> (q int8, dec fp32). Pads to BLOCK internally.

    ``q`` is the wire payload (1 byte/element), ``dec`` the server-side fp32
    view entering the sync average.
    """
    n = x.shape[0]
    npad = (BLOCK - n % BLOCK) % BLOCK
    if npad:
        pad = lambda a, v: jnp.concatenate([a, jnp.full((npad,), v, a.dtype)])
        x, u, s = pad(x, 0), pad(u, 0), pad(s, 0)  # s=0 padding decodes to 0
    grid = (x.shape[0] // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    q, dec = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.int8),
                   jax.ShapeDtypeStruct(x.shape, jnp.float32)],
        interpret=interpret,
    )(x, u, s)
    return q[:n], dec[:n]
